"""Property-based tests (hypothesis) on system invariants:

* scheduler: no over-allocation, conservation, eventual completion,
  accounting completeness, determinism, contiguity of every allocation;
* sharding: divisibility policy never produces an invalid PartitionSpec;
* data pipeline: packing conservation + restore determinism;
* MoE dispatch: capacity bounds respected for random router outcomes.
"""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:             # container has no hypothesis wheel
    from _mini_hypothesis import given, settings, strategies as st

from repro.cluster import (
    Cluster, JobState, Node, Partition, ResourceRequest,
)

# ---------------------------------------------------------------- slurm ----

job_strategy = st.tuples(
    st.integers(min_value=1, max_value=4),      # nodes
    st.integers(min_value=0, max_value=9),      # priority
    st.integers(min_value=1, max_value=120),    # run_time
    st.integers(min_value=1, max_value=150),    # time_limit
    st.booleans(),                              # contiguous
)


def build(jobspecs, mode):
    nodes = [Node(name=f"n{i}", cpus=8, mem_mb=16384, gres={"tpu": 4},
                  coord=(i // 4, i % 4)) for i in range(8)]
    parts = [Partition(name="p", nodes=tuple(n.name for n in nodes),
                       default=True)]
    c = Cluster(nodes, parts, sched_mode=mode)
    for i, (n, prio, rt, tl, cont) in enumerate(jobspecs):
        c.submit(f"j{i}", ResourceRequest(
            nodes=n, gres_per_node={"tpu": 4}, cpus_per_node=2,
            mem_mb_per_node=2048, time_limit_s=tl, contiguous=cont),
            priority=prio, run_time_s=rt)
    return c


@settings(max_examples=60, deadline=None)
@given(st.lists(job_strategy, min_size=1, max_size=12),
       st.sampled_from(["easy", "conservative", "fifo"]))
def test_scheduler_invariants(jobspecs, mode):
    c = build(jobspecs, mode)

    # invariant 1: at every event, no node over-allocated
    def check_nodes():
        for n in c.nodes.values():
            assert n.alloc_cpus <= n.cpus
            assert n.alloc_mem_mb <= n.mem_mb
            for g, amt in n.alloc_gres.items():
                assert amt <= n.gres[g]
            # conservation: allocations match the running-job set
            assert len(n.running_jobs) == 0 or n.alloc_cpus > 0

    check_nodes()
    for _ in range(10_000):
        if not c.tick():
            break
        check_nodes()

    # invariant 2: every job reached a terminal state (capacity fits all)
    for j in c.jobs.values():
        assert j.state.finished, (j.job_id, j.state, j.reason)

    # invariant 3: accounting has exactly one record per job
    ids = sorted(r.job_id for r in c.accounting)
    assert ids == sorted(c.jobs)

    # invariant 4: runtimes respect limits
    for r in c.accounting:
        if r.state in ("COMPLETED", "TIMEOUT"):
            assert r.elapsed <= c.jobs[r.job_id].req.time_limit_s + 1e-9

    # invariant 5: contiguous allocations form exact rectangles
    for j in c.jobs.values():
        if j.req.contiguous and j.nodes_alloc:
            coords = [c.nodes[nm].coord for nm in j.nodes_alloc]
            rows = {r for r, _ in coords}
            cols = {cl for _, cl in coords}
            assert len(rows) * len(cols) == len(coords)


@settings(max_examples=20, deadline=None)
@given(st.lists(job_strategy, min_size=1, max_size=8))
def test_scheduler_deterministic(jobspecs):
    tr = []
    for _ in range(2):
        c = build(jobspecs, "easy")
        c.run()
        tr.append([(r.job_id, r.start, r.end, r.state, r.nodes)
                   for r in c.accounting])
    assert tr[0] == tr[1]


# ------------------------------------------------------------- sharding ----

@settings(max_examples=50, deadline=None)
@given(
    st.tuples(st.integers(1, 8), st.integers(1, 8)),        # mesh (data, model)
    st.sampled_from(["dp", "tp", "fsdp", "fsdp_tp"]),
    st.lists(st.integers(1, 512), min_size=1, max_size=3),  # tensor shape
)
def test_param_pspec_always_valid(mesh_shape, strategy_name, shape):
    """The divisibility policy never assigns an axis a non-dividing size,
    and never uses a mesh axis twice."""
    from repro.core.parallelism import get_strategy
    from repro.core.sharding import param_pspec
    from repro.models.spec import ParamSpec

    class FakeMesh:
        def __init__(self, d, m):
            self.shape = {"data": d, "model": m}
            self.axis_names = ("data", "model")

    mesh = FakeMesh(*mesh_shape)
    axes_pool = ["ffn", "heads", "vocab", "d_model", "experts", None]
    axes = tuple(axes_pool[i % len(axes_pool)] for i in range(len(shape)))
    ps = ParamSpec(shape=tuple(shape), axes=axes)
    spec = param_pspec(ps, mesh, get_strategy(strategy_name))

    def axes_of(s):
        return s if isinstance(s, tuple) else (s,)

    used = [a for s in spec if s is not None for a in axes_of(s)]
    assert len(used) == len(set(used))                     # no axis reuse
    for dim, s in zip(shape, spec):
        if s is not None:
            total = 1
            for a in axes_of(s):
                total *= mesh.shape[a]
            assert dim % total == 0                        # divisibility


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 1024))
def test_batch_partition_divides(data, model, batch):
    from repro.core.parallelism import get_strategy
    from repro.core.sharding import batch_partition

    class FakeMesh:
        def __init__(self, d, m):
            self.shape = {"data": d, "model": m}
            self.axis_names = ("data", "model")

    baxes = batch_partition(FakeMesh(data, model), batch,
                            get_strategy("fsdp_tp"))
    if baxes is not None:
        total = int(np.prod([{"data": data, "model": model}.get(a, 1)
                             for a in baxes]))
        assert batch % total == 0
    else:
        assert batch % data != 0       # only fails when nothing divides


# ---------------------------------------------------------------- data ----

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8),
       st.sampled_from([64, 128, 256]))
def test_packed_stream_properties(seed, batch, seq):
    from repro.data import DataConfig, PackedStream
    cfg = DataConfig(vocab_size=1024, seq_len=seq, global_batch=batch,
                     seed=seed)
    s = PackedStream(cfg)
    b1 = s.next_batch()
    assert b1["tokens"].shape == (batch, seq)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 1024
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # restore determinism: state after batch1 replays batch2 exactly
    state = s.state()
    b2 = s.next_batch()
    s2 = PackedStream(cfg)
    s2.restore(state)
    b2r = s2.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])


# ----------------------------------------------------------------- moe ----

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4]), st.sampled_from([1, 2]))
def test_moe_dispatch_capacity_bound(seed, E, k):
    """No expert ever receives more than its capacity; every dispatched
    token appears in exactly one capacity slot per selected expert."""
    import jax.numpy as jnp
    from repro.configs.base import MoEConfig, ModelConfig
    from repro.models.moe import moe_apply

    rng = np.random.default_rng(seed)
    cfg = ModelConfig(
        name="t", family="moe", source="", num_layers=1, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64, head_dim=16,
        mlp_type="gelu",
        moe=MoEConfig(num_experts=E, top_k=k, d_ff=64, every=1,
                      group_size=32))
    d, f = 32, 64
    p = {"router": jnp.asarray(rng.standard_normal((d, E)), jnp.float32) * .1,
         "w1": jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32) * .1,
         "w2": jnp.asarray(rng.standard_normal((E, f, d)), jnp.float32) * .1}
    x = jnp.asarray(rng.standard_normal((2, 32, 32)), jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert 0.0 <= float(aux["moe_overflow"]) <= 1.0
