"""Request-lifecycle tracing & SLO observability.

Acceptance properties:

* spans nest (explicit parents, track inheritance), retention is a ring
  buffer, and the injectable clock fully determines timestamps;
* the Chrome trace export is valid JSON with monotonically ordered
  timestamps and resolvable parent/child links (what Perfetto loads);
* Prometheus label-value escaping survives hostile tenant names;
* histogram quantiles interpolate within the terminal bucket;
* SLO attainment counters split met/violated exactly at the tier target;
* a preempted-then-resumed serving request yields ONE request trace with
  TWO decode spans (residency segments) plus PREEMPT/RESUME markers;
* cluster jobs emit PENDING/RUNNING/PREEMPTED state spans on the virtual
  clock, and ``sdiag`` reports scheduler/admission/SLO statistics.
"""
import json

import numpy as np
import pytest

from repro.monitoring import MetricsRegistry, SLOTarget, Tracer
from repro.monitoring.metrics import _labels_text
from repro.monitoring.trace import (
    METRIC_SERVE_ITL, METRIC_SERVE_QUEUE_WAIT, METRIC_SERVE_TTFT,
    METRIC_SLO_TTFT_MET, METRIC_SLO_TTFT_VIOLATIONS,
)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


# ------------------------------------------------------------ span core ----

def test_spans_nest_and_inherit_track():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    root = tr.begin("request 0", cat="request", track=("serving:a", "req 0"))
    clk.advance(1.0)
    child = tr.begin("PREFILL", cat="prefill", parent=root)
    assert child.track == root.track           # inherited
    assert child.parent == root.sid
    clk.advance(0.5)
    tr.end(child)
    tr.end(root)
    assert child.start == 1.0 and child.end == 1.5
    assert root.duration == 1.5
    # double-end is a no-op
    tr.end(child)
    assert child.end == 1.5
    assert [s.name for s in tr.spans(cat="prefill")] == ["PREFILL"]


def test_explicit_ts_overrides_clock():
    tr = Tracer(clock=FakeClock(100.0))
    sp = tr.begin("job 1", ts=5.0)
    tr.event("SUBMIT", sp, ts=5.0)
    tr.end(sp, ts=9.0)
    assert sp.start == 5.0 and sp.end == 9.0
    assert sp.events[0].ts == 5.0


def test_ring_buffer_bounds_retention():
    tr = Tracer(clock=FakeClock(), max_spans=4)
    for i in range(10):
        tr.end(tr.begin(f"s{i}"))
    done = tr.spans()
    assert len(done) == 4
    assert [s.name for s in done] == ["s6", "s7", "s8", "s9"]


def test_span_contextmanager_and_open_spans():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("work", meta=1) as sp:
        assert sp in tr.open_spans()
        clk.advance(2.0)
    assert sp.end == 2.0 and not tr.open_spans()


# --------------------------------------------------------- chrome export ----

def test_chrome_export_golden(tmp_path):
    clk = FakeClock()
    tr = Tracer(clock=clk)
    root = tr.begin("request 0", cat="request", track=("serving:a", "req 0"))
    tr.event("SUBMIT", root)
    clk.advance(0.001)
    child = tr.begin("PREFILL", parent=root)
    clk.advance(0.002)
    tr.end(child)
    tr.end(root)
    path = tmp_path / "trace.json"
    data = tr.export_chrome(str(path))
    on_disk = json.loads(path.read_text())     # valid JSON round-trip
    assert on_disk == json.loads(json.dumps(data))
    evs = [e for e in data["traceEvents"] if e["ph"] != "M"]
    meta = [e for e in data["traceEvents"] if e["ph"] == "M"]
    # process/thread named for the track
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    # monotonically ordered timestamps
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    # parent/child: child's X event links to root's sid, same lane, and
    # the child interval is contained in the parent interval
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    rx, cx = xs["request 0"], xs["PREFILL"]
    assert cx["args"]["parent_sid"] == rx["args"]["sid"]
    assert (rx["pid"], rx["tid"]) == (cx["pid"], cx["tid"])
    assert rx["ts"] <= cx["ts"]
    assert cx["ts"] + cx["dur"] <= rx["ts"] + rx["dur"]
    # the instant event rides on the root span
    (inst,) = [e for e in evs if e["ph"] == "i"]
    assert inst["name"] == "SUBMIT"
    assert inst["args"]["span_sid"] == rx["args"]["sid"]


def test_chrome_export_includes_open_spans():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    tr.begin("unfinished")
    clk.advance(1.0)
    data = tr.export_chrome()
    (ev,) = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert ev["args"]["incomplete"] is True and ev["dur"] == 1e6
    assert not tr.export_chrome(include_open=False)["traceEvents"]


def test_validate_trace_script(tmp_path):
    import sys
    sys.path.insert(0, "scripts")
    try:
        import validate_trace
    finally:
        sys.path.pop(0)
    tr = Tracer(clock=FakeClock())
    tr.end(tr.begin("ok"))
    good = tmp_path / "good.json"
    tr.export_chrome(str(good))
    assert validate_trace.validate(str(good)) == []
    bad = tmp_path / "bad.json"
    data = tr.export_chrome()
    data["traceEvents"][-1]["args"]["parent_sid"] = 999
    bad.write_text(json.dumps(data))
    assert validate_trace.validate(str(bad))


# ----------------------------------------------------- metrics satellites ----

def test_label_value_escaping_round_trip():
    text = _labels_text({"tenant": 'acme "prod"\\team\nx'})
    assert text == '{tenant="acme \\"prod\\"\\\\team\\nx"}'
    # the exposition line must not contain a raw newline or bare quote
    assert "\n" not in text


def test_escaped_labels_expose_parses():
    reg = MetricsRegistry()
    reg.counter("c", "h").inc(tenant='a"b\\c')
    lines = reg.expose().splitlines()
    (sample,) = [ln for ln in lines if ln.startswith("c{")]
    assert sample == 'c{tenant="a\\"b\\\\c"} 1.0'


def test_quantile_interpolates_within_bucket():
    reg = MetricsRegistry()
    h = reg.histogram("h")
    for _ in range(100):
        h.observe(0.3)                  # lands in the (0.1, 0.5] bucket
    # interpolation reports a value inside the bucket, not the 0.5 bound
    assert 0.1 < h.quantile(0.5) < 0.5
    assert h.quantile(0.99) < 0.5
    # +Inf terminal bucket: report the last finite boundary
    h2 = reg.histogram("h2")
    h2.observe(1e6)
    assert h2.quantile(0.5) == h2.buckets[-2]


def test_registry_timer_records_into_histogram():
    reg = MetricsRegistry()
    with reg.timer("op_seconds", "op latency", stage="x"):
        pass
    h = reg.histogram("op_seconds")
    assert h.count(stage="x") == 1
    assert 0.0 <= h.sum(stage="x") < 1.0


def test_dashboard_renders_histogram_rows():
    reg = MetricsRegistry()
    reg.gauge("cluster_util").set(0.5)
    h = reg.histogram("lat_seconds")
    for v in (0.2, 0.3, 0.4):
        h.observe(v, tenant="a")
    out = reg.dashboard()
    row = [ln for ln in out.splitlines() if "lat_seconds" in ln]
    assert len(row) == 1
    assert "n=3" in row[0] and "p50=" in row[0] and "p99=" in row[0]
    assert 'tenant="a"' in row[0]


# ------------------------------------------------------------ SLO series ----

def test_slo_counters_split_at_target():
    tr = Tracer(clock=FakeClock(),
                slo_targets={"high": SLOTarget(ttft_s=1.0, itl_s=0.2),
                             "scavenger": SLOTarget()})
    for s in (0.5, 1.0, 1.5):          # met, met (boundary), violated
        tr.slo.ttft(s, "a", "high")
    met = tr.metrics.counter(METRIC_SLO_TTFT_MET)
    viol = tr.metrics.counter(METRIC_SLO_TTFT_VIOLATIONS)
    assert met.value(tenant="a", qos="high") == 2
    assert viol.value(tenant="a", qos="high") == 1
    # best-effort tier: series recorded, no attainment counters
    tr.slo.ttft(99.0, "b", "scavenger")
    hist = tr.metrics.histogram(METRIC_SERVE_TTFT)
    assert hist.count(tenant="b", qos="scavenger") == 1
    assert viol.value(tenant="b", qos="scavenger") == 0


def test_slo_itl_is_token_weighted():
    tr = Tracer(clock=FakeClock())
    tr.slo.itl(0.01, "a", "normal", n=8)       # one fused chunk, 8 tokens
    hist = tr.metrics.histogram(METRIC_SERVE_ITL)
    assert hist.count(tenant="a", qos="normal") == 8
    assert hist.sum(tenant="a", qos="normal") == pytest.approx(0.08)


def test_slo_report_lists_tenants():
    tr = Tracer(clock=FakeClock())
    tr.slo.ttft(0.1, "alice", "high")
    tr.slo.itl(0.01, "alice", "high", n=4)
    report = tr.slo.format_report()
    assert "alice" in report and "high" in report and "TTFT" in report


# ----------------------------------------------------- engine integration ----

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_reduced_config
    from repro.models import init_params
    cfg = get_reduced_config("stablelm-3b")
    return cfg, init_params(cfg, 0)


def test_preempted_request_traces_two_decode_segments(tiny_model, tmp_path):
    """Preempt -> resume shows up as ONE request trace with TWO decode
    spans plus PREEMPT/RESUME markers, and the SLO series populate."""
    from repro.serving import AdmissionController, DecodeEngine, Request

    cfg, params = tiny_model
    rng = np.random.default_rng(7)
    tracer = Tracer()
    ctrl = AdmissionController(tracer=tracer)
    ctrl.add_tenant("research", shares=1)
    ctrl.add_tenant("prod", shares=10)
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       admission=ctrl, tracer=tracer)
    scavs = [Request(rid=i,
                     prompt=rng.integers(0, cfg.vocab_size, 8).astype(
                         np.int32),
                     max_new_tokens=16, tenant="research", qos="scavenger")
             for i in range(2)]
    for r in scavs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    hi = Request(rid=2,
                 prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                 max_new_tokens=4, tenant="prod", qos="high")
    eng.submit(hi)
    eng.run_to_completion()
    (victim,) = [r for r in scavs if r.preemptions == 1]

    track = ("serving:research", f"req {victim.rid}")
    (root,) = tracer.spans(name=f"request {victim.rid}")
    assert root.track == track and root.attrs["qos"] == "scavenger"
    decodes = tracer.spans(name="DECODE", track=track)
    assert len(decodes) == 2                   # two residency segments
    assert all(d.parent == root.sid for d in decodes)
    assert decodes[0].attrs["stop"] == "PREEMPT"
    marks = [e.name for e in root.events]
    assert marks.count("PREEMPT") == 1 and marks.count("RESUME") == 1
    assert marks[0] == "SUBMIT" and marks[-1] == "FINISH"
    # two queue waits (initial + requeue), ONE ttft (resume is not a
    # first token)
    qw = tracer.metrics.histogram(METRIC_SERVE_QUEUE_WAIT)
    assert qw.count(tenant="research", qos="scavenger") >= 3   # 2 + victim
    ttft = tracer.metrics.histogram(METRIC_SERVE_TTFT)
    assert ttft.count(tenant="research", qos="scavenger") == 2
    assert ttft.count(tenant="prod", qos="high") == 1
    itl = tracer.metrics.histogram(METRIC_SERVE_ITL)
    assert itl.count(tenant="research", qos="scavenger") > 0
    # every lifecycle state reached the trace, and the export is valid
    names = {s.name for s in tracer.spans()}
    assert {"QUEUED", "PREFILL", "DECODE", "decode_chunk"} <= names
    path = tmp_path / "serve_trace.json"
    data = tracer.export_chrome(str(path))
    ts = [e["ts"] for e in data["traceEvents"] if e["ph"] != "M"]
    assert ts == sorted(ts) and len(ts) > 10


def test_untraced_engine_has_no_trace_state(tiny_model):
    """tracer=None pays nothing: no span dicts, no SLO series."""
    from repro.serving import DecodeEngine, Request

    cfg, params = tiny_model
    eng = DecodeEngine(cfg, params, num_slots=1, cache_len=64)
    r = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                max_new_tokens=4)
    eng.submit(r)
    eng.run_to_completion()
    assert r._trace == {} and r._t_admit is None
    assert eng.metrics.histogram(METRIC_SERVE_TTFT).count() == 0


# ---------------------------------------------------- cluster integration ----

def _small_cluster(n_nodes=4):
    from repro.cluster import Cluster, Node, Partition
    nodes = [Node(name=f"n{i:02d}", cpus=16, mem_mb=65536,
                  gres={"tpu": 4}, coord=(0, i)) for i in range(n_nodes)]
    parts = [Partition(name="gpu", nodes=tuple(n.name for n in nodes),
                       default=True)]
    return Cluster(nodes, parts)


def test_cluster_jobs_emit_state_spans_on_virtual_clock():
    from repro.cluster import ResourceRequest

    c = _small_cluster()
    tracer = Tracer()
    c.tracer = tracer
    req = ResourceRequest(nodes=4, gres_per_node={"tpu": 4},
                          cpus_per_node=1, mem_mb_per_node=1024,
                          time_limit_s=36_000)
    (sc,) = c.submit("scav", req, user="bob", qos="scavenger",
                     run_time_s=1000)
    c.clock = 250.0
    (hi,) = c.submit("prod", req, user="alice", qos="high", run_time_s=50)
    c.run()

    track = ("cluster:root", f"job {sc}")
    (root,) = tracer.spans(name=f"job {sc}")
    assert root.track == track and root.attrs["state"] == "COMPLETED"
    states = [(s.name, s.start, s.end)
              for s in tracer.spans(cat="state", track=track)]
    names = [n for n, _, _ in states]
    # PENDING -> RUNNING -> PREEMPTED -> PENDING(requeued) -> RUNNING
    assert names == ["PENDING", "RUNNING", "PREEMPTED", "PENDING",
                     "RUNNING"]
    # virtual-clock timestamps: first RUNNING segment spans [0, 250)
    assert states[1][1] == 0.0 and states[1][2] == 250.0
    assert states[2] == ("PREEMPTED", 250.0, 250.0)   # zero-length marker
    # the high job's trace closes COMPLETED with a RUNNING segment
    (hroot,) = tracer.spans(name=f"job {hi}")
    assert hroot.attrs["state"] == "COMPLETED"
    # scheduler passes were traced and timed
    assert tracer.spans(name="schedule_pass")
    assert c.sched_stats["passes"] > 0
    assert c.sched_stats["total_us"] >= c.sched_stats["max_us"] > 0


def test_sdiag_reports_all_sections(tiny_model):
    from repro.cluster import ResourceRequest, commands
    from repro.serving import AdmissionController, DecodeEngine, Request

    c = _small_cluster(n_nodes=1)
    tracer = Tracer()
    c.tracer = tracer
    c.submit("j", ResourceRequest(nodes=1, gres_per_node={"tpu": 4},
                                  cpus_per_node=1, mem_mb_per_node=1024,
                                  time_limit_s=3600), run_time_s=10)
    c.run()
    cfg, params = tiny_model
    ctrl = AdmissionController(tracer=tracer)
    eng = DecodeEngine(cfg, params, num_slots=1, cache_len=64,
                       admission=ctrl, tracer=tracer)
    eng.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                       max_new_tokens=4, qos="high"))
    eng.run_to_completion()
    out = commands.sdiag(cluster=c, tracer=tracer, admission=ctrl)
    assert "Main schedule statistics" in out
    assert "Total cycles:" in out and "Jobs started:     1" in out
    assert "Admission controller statistics" in out
    assert "Picks:            1" in out
    assert "Serving SLO" in out and "default" in out and "high" in out
    assert commands.sdiag() == "sdiag: nothing to report"
