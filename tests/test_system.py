"""End-to-end system tests: the full paper workflow — provision a cluster
(§4), submit DL jobs through SLURM commands (§5), run real JAX work through
the Mesh bridge, monitor (§6), checkpoint/resume after a requeue."""
import numpy as np
import pytest

from repro.cluster import (
    JobState, NodeState, ResourceRequest, commands, provision, tpu_pod_spec,
    validate,
)
from repro.cluster.meshbridge import mesh_for_job
from repro.configs import RunConfig, get_reduced_config
from repro.configs.base import InputShape
from repro.monitoring import MetricsRegistry
from repro.optim import OptimizerConfig
from repro.training.trainer import Trainer, TrainerConfig

SHAPE = InputShape("e2e", 64, 2, "train")


def _train_script(steps=4, ckpt_dir=None, metrics=None):
    """The guide's §5.2.4 train.py, as a cluster job script."""
    def script(job, alloc):
        from repro.cluster.meshbridge import mesh_for_job
        cfg = get_reduced_config("stablelm-3b")
        run = RunConfig(strategy="dp", microbatches=1, remat="none")
        mesh = mesh_for_job(script.cluster, job)
        t = Trainer(cfg, run, mesh, SHAPE,
                    OptimizerConfig(warmup_steps=2, decay_steps=50),
                    TrainerConfig(steps=steps, log_every=100,
                                  ckpt_every=2 if ckpt_dir else 0,
                                  ckpt_dir=ckpt_dir),
                    metrics=metrics)
        history = t.train(log=lambda *_: None)
        return history
    return script


def test_full_workflow_provision_submit_train_account():
    # 1. provision (the paper's §4 DeepOps flow) + validate (§4 step 8)
    spec = tpu_pod_spec(hosts_x=2, hosts_y=2)
    cluster = provision(spec, real_mode=True)
    report = validate(cluster, spec)
    assert report.ok, str(report)

    # 2. submit a real training job via sbatch (§5.2.3)
    script = _train_script(steps=3)
    script.cluster = cluster
    msg = commands.sbatch(cluster, name="deep_learning_job", nodes=4,
                          gres="tpu:4", mem="4G", time="01:00:00",
                          script=script, run_time_s=60)
    jid = int(msg.split()[-1])

    # 3. the scheduler started it; the script ran through the Mesh bridge
    job = cluster.jobs[jid]
    assert job.state == JobState.RUNNING
    assert job.exit_code == 0, job.comment
    history = job.result
    assert len(history) == 3
    assert np.isfinite(history[-1]["loss"])

    # 4. run to completion + sacct shows it (§6)
    cluster.run()
    out = commands.sacct(cluster)
    assert "deep_learning_job" in out and "COMPLETED" in out


def test_checkpoint_resume_after_requeue(tmp_path):
    """Node drain -> requeue -> the job resumes from its checkpoint
    (the guide's whole reason for checkpoints in §5.2.5)."""
    cfg = get_reduced_config("stablelm-3b")
    run = RunConfig(strategy="dp", microbatches=1, remat="none")
    from repro.launch.mesh import make_mesh
    mesh = make_mesh(1, 1)
    opt = OptimizerConfig(warmup_steps=2, decay_steps=50)

    # first incarnation: 4 steps, checkpoint every 2
    t1 = Trainer(cfg, run, mesh, SHAPE, opt,
                 TrainerConfig(steps=4, ckpt_every=2,
                               ckpt_dir=str(tmp_path), log_every=100))
    h1 = t1.train(log=lambda *_: None)

    # continuous run to 8 steps (ground truth)
    t_full = Trainer(cfg, run, mesh, SHAPE, opt,
                     TrainerConfig(steps=8, log_every=100))
    h_full = t_full.train(log=lambda *_: None)

    # second incarnation ("requeued"): resumes at step 4, trains to 8
    t2 = Trainer(cfg, run, mesh, SHAPE, opt,
                 TrainerConfig(steps=8, ckpt_every=0,
                               ckpt_dir=str(tmp_path), log_every=100))
    h2 = t2.train(log=lambda *_: None)
    assert t2.step == 8
    assert h2[0]["step"] == 5                      # resumed, not restarted
    # the resumed run reproduces the continuous run's loss trajectory
    np.testing.assert_allclose(h2[-1]["loss"], h_full[-1]["loss"],
                               rtol=1e-4)


def test_job_failure_is_accounted_and_isolated():
    spec = tpu_pod_spec(hosts_x=2, hosts_y=1)
    cluster = provision(spec, real_mode=True)

    def bad_script(job, alloc):
        raise RuntimeError("OOM: tried to materialize the logits")

    (jid,) = cluster.submit(
        "crash", ResourceRequest(nodes=1, gres_per_node={"tpu": 4}),
        script=bad_script, run_time_s=1)
    assert cluster.jobs[jid].exit_code == 1
    assert "OOM" in cluster.jobs[jid].comment
    cluster.run()
    assert cluster.jobs[jid].state == JobState.FAILED
    # the cluster keeps serving other jobs
    (ok,) = cluster.submit(
        "fine", ResourceRequest(nodes=1, gres_per_node={"tpu": 4}),
        run_time_s=1)
    cluster.run()
    assert cluster.jobs[ok].state == JobState.COMPLETED


def test_metrics_flow_from_training_to_prometheus():
    spec = tpu_pod_spec(hosts_x=1, hosts_y=1)
    cluster = provision(spec, real_mode=True)
    metrics = MetricsRegistry()
    cluster.metrics = metrics

    script = _train_script(steps=2, metrics=metrics)
    script.cluster = cluster
    commands.srun(cluster, script, nodes=1, gres="tpu:4")
    text = metrics.expose()
    assert "train_tokens" in text
    assert "train_step_seconds_bucket" in text
    assert metrics.counter("train_tokens").value() == 2 * 64 * 2


def test_gang_scheduling_two_pods_share_cluster():
    """Two jobs with disjoint rectangles run concurrently (the cluster
    advantage of §2.4.4 'Collaboration and Scalability')."""
    spec = tpu_pod_spec(hosts_x=4, hosts_y=2)
    cluster = provision(spec)
    (a,) = cluster.submit("a", ResourceRequest(
        nodes=4, gres_per_node={"tpu": 4}), run_time_s=10)
    (b,) = cluster.submit("b", ResourceRequest(
        nodes=4, gres_per_node={"tpu": 4}), run_time_s=10)
    assert cluster.jobs[a].state == JobState.RUNNING
    assert cluster.jobs[b].state == JobState.RUNNING
    assert not (set(cluster.jobs[a].nodes_alloc)
                & set(cluster.jobs[b].nodes_alloc))
