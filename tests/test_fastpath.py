"""Device-resident decode fast path: fused sampling / multi-token chunks
are bit-identical to the per-token host loop, bucketed prefill compiles
once per bucket (not per prompt length), the Pallas flash-decode kernel
matches the naive oracle, and tenancy semantics (preemption at chunk
boundaries, 10:1 fair-share convergence with per-chunk bulk charges,
wall-clock ledger decay, QOS ordering within a tenant queue) survive the
rebuild."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_reduced_config
from repro.kernels import ops
from repro.kernels.ref import decode_attention_ref
from repro.monitoring.metrics import METRIC_SERVE_PREEMPTIONS
from repro.policy import FairShareTree
from repro.serving import AdmissionController, DecodeEngine, Request

RNG = np.random.default_rng(42)


@pytest.fixture(scope="module")
def tiny_model():
    from repro.models import init_params
    cfg = get_reduced_config("stablelm-3b")
    return cfg, init_params(cfg, 0)


def _run(cfg, params, reqs, **engine_kw):
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64, **engine_kw)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    return eng


def _reqs(cfg, n=4, max_new=6, temperature=0.0, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4 + 3 * i).astype(
                        np.int32),
                    max_new_tokens=max_new + i, temperature=temperature)
            for i in range(n)]


# ---------------------------------------------------- fused decode chunks ----

def test_fused_greedy_bit_identical_to_host_path(tiny_model):
    """Acceptance: fused sampling == host path, token for token (greedy),
    for chunk sizes that do and don't divide the generation lengths."""
    cfg, params = tiny_model
    ref_reqs = _reqs(cfg)
    _run(cfg, params, ref_reqs, fused=False)
    for chunk in (1, 3, 8):
        got = _reqs(cfg)
        _run(cfg, params, got, decode_chunk=chunk)
        assert [r.output for r in got] == [r.output for r in ref_reqs], chunk


def test_fused_temperature_matches_host_key_stream(tiny_model):
    """With temperature > 0 the fused scan splits the PRNG key once per
    generated token exactly like the host sampler, so outputs are
    bit-identical when chunks align with the generation length."""
    cfg, params = tiny_model
    def one(**kw):
        req = _reqs(cfg, n=1, max_new=16, temperature=0.8)[0]
        _run(cfg, params, [req], seed=7, **kw)
        return req.output
    ref = one(fused=False)
    assert one(decode_chunk=1) == ref
    assert one(decode_chunk=8) == ref
    assert len(ref) == 16 and len(set(ref)) > 1   # actually sampled

    # mixed batch: a greedy slot and a sampled slot share chunks — the
    # host sampler splits the key once per token too, so streams align
    def mixed(**kw):
        rng = np.random.default_rng(5)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                            np.int32),
                        max_new_tokens=16, temperature=0.8 * i)
                for i in range(2)]
        eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64, seed=9,
                           **kw)
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.output for r in reqs]
    assert mixed(decode_chunk=8) == mixed(fused=False)


def test_fused_eos_stops_charging_mid_chunk(tiny_model):
    """Device-side stop masking: a slot hitting EOS inside a chunk stops
    generating (pad emissions are dropped) and stops charging."""
    cfg, params = tiny_model
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    probe = Request(rid=0, prompt=prompt, max_new_tokens=2)
    _run(cfg, params, [probe])
    eos = probe.output[1]                 # second greedy token
    ctrl = AdmissionController()
    req = Request(rid=1, prompt=prompt, max_new_tokens=50, eos_id=eos)
    eng = DecodeEngine(cfg, params, num_slots=1, cache_len=64,
                       decode_chunk=8, admission=ctrl)
    eng.submit(req)
    eng.run_to_completion()
    assert req.done and len(req.output) == 2 and req.output[-1] == eos
    # ledger saw 1 decode token (+ prefill rent), not the chunk's 8
    assert eng.metrics.counter("serve_tokens_generated").value() == 1


def test_fused_preemption_at_chunk_boundary(tiny_model):
    """Acceptance: with decode_chunk > 1 a blocked high-QOS request still
    evicts exactly one scavenger slot (at the next chunk boundary) and the
    victim resumes with its partial output retained."""
    cfg, params = tiny_model
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(3)]
    ctrl = AdmissionController()
    ctrl.add_tenant("research", shares=1)
    ctrl.add_tenant("prod", shares=10)
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       decode_chunk=4, admission=ctrl)
    scavs = [Request(rid=i, prompt=prompts[i], max_new_tokens=16,
                     tenant="research", qos="scavenger") for i in range(2)]
    for r in scavs:
        eng.submit(r)
    eng.step()                            # one chunk: 1 + 4 tokens each
    assert all(len(r.output) == 5 and not r.done for r in scavs)
    partial = {r.rid: list(r.output) for r in scavs}

    hi = Request(rid=2, prompt=prompts[2], max_new_tokens=4,
                 tenant="prod", qos="high")
    eng.submit(hi)
    eng.step()                            # chunk boundary: preempt now
    assert eng.metrics.counter(METRIC_SERVE_PREEMPTIONS).value() == 1
    evicted = [r for r in scavs if r.preemptions == 1]
    assert len(evicted) == 1
    victim = evicted[0]
    assert victim.output[:len(partial[victim.rid])] == partial[victim.rid]

    eng.run_to_completion()
    assert hi.done and all(r.done for r in scavs)
    # resume correctness: the interrupted run equals a solo greedy run
    solo = Request(rid=9, prompt=victim.prompt, max_new_tokens=16)
    _run(cfg, params, [solo], decode_chunk=4)
    assert victim.output == solo.output


def test_fairshare_10_to_1_with_chunked_bulk_charges():
    """Acceptance: the 10:1 +-15% token-split convergence holds when the
    ledger is charged once per 8-token chunk via charge_bulk (the fused
    engine's batching) instead of per token."""
    ctrl = AdmissionController()
    ctrl.add_tenant("big", shares=10)
    ctrl.add_tenant("small", shares=1)
    import itertools
    num_slots, chunk = 4, 8
    slots = [None] * num_slots
    tokens = {"big": 0, "small": 0}
    rid = itertools.count()

    def refill():
        for tenant in ("big", "small"):
            while ctrl.queued(tenant) < 4:
                rng = np.random.default_rng(next(rid))
                ctrl.submit(Request(
                    rid=next(rid), prompt=rng.integers(0, 32, 8).astype(
                        np.int32), max_new_tokens=chunk * 2, tenant=tenant))

    refill()
    for _ in range(400):
        for i in range(num_slots):
            if slots[i] is None:
                req = ctrl.next_request()
                if req is None:
                    break
                slots[i] = req
                ctrl.charge(req, kv_tokens=len(req.prompt))
        charges = []
        for i in range(num_slots):
            req = slots[i]
            if req is None:
                continue
            n = min(chunk, req.max_new_tokens - len(req.output))
            req.output.extend([0] * n)
            tokens[req.tenant] += n
            kv = sum(len(req.prompt) + len(req.output) - j for j in range(n))
            charges.append((req, n, kv))
            if len(req.output) >= req.max_new_tokens:
                slots[i] = None
                ctrl.release(req)
        ctrl.charge_bulk(charges)
        refill()
    ratio = tokens["big"] / tokens["small"]
    assert 10 / 1.15 <= ratio <= 10 * 1.15, (ratio, tokens)


# ------------------------------------------------------- bucketed prefill ----

def test_bucketed_prefill_compiles_once_per_bucket(tiny_model):
    """Acceptance: across 20 random prompt lengths the prefill compiles at
    most once per bucket — and emits the same tokens as exact-length
    prefill (the pad tail is provably masked)."""
    cfg, params = tiny_model
    rng = np.random.default_rng(11)
    lengths = [int(p) for p in rng.integers(2, 48, 20)]
    assert len(set(lengths)) > 6          # genuinely mixed lengths

    def reqs():
        return [Request(rid=i,
                        prompt=np.random.default_rng(100 + i).integers(
                            0, cfg.vocab_size, L).astype(np.int32),
                        max_new_tokens=2)
                for i, L in enumerate(lengths)]

    exact = reqs()
    _run(cfg, params, exact, decode_chunk=4)
    bucketed = reqs()
    eng = _run(cfg, params, bucketed, decode_chunk=4,
               prefill_buckets=(16, 32, 64))
    assert eng.prefill_buckets == (16, 32, 64)
    assert eng.prefill_compilations() <= len(eng.prefill_buckets)
    assert [r.output for r in bucketed] == [r.output for r in exact]


def test_buckets_refused_for_recurrent_or_ring_caches():
    """SSM/hybrid configs now bucket via FRONT padding (chunk-aligned
    pads are the SSD scan's identity), so only ring caches — whose
    wrapped slot layout has no pad region — still silently fall back to
    exact-length prefill."""
    from repro.models import init_params
    ssm_cfg = get_reduced_config("mamba2-780m")
    eng = DecodeEngine(ssm_cfg, init_params(ssm_cfg, 0), num_slots=1,
                       cache_len=32, prefill_buckets="auto")
    assert eng.prefill_buckets is not None and eng._front_pad
    win_cfg = dataclasses.replace(get_reduced_config("stablelm-3b"),
                                  sliding_window=8)
    eng = DecodeEngine(win_cfg, init_params(win_cfg, 0), num_slots=1,
                       cache_len=32, prefill_buckets="auto")
    assert eng.prefill_buckets is None


# ------------------------------------------------------------ flash decode ----

DECODE_CASES = [
    # (B, S, H, K, Dh, block_k)
    (2, 128, 4, 2, 64, 64),
    (1, 256, 8, 8, 64, 128),     # MHA
    (2, 128, 4, 1, 32, 64),      # MQA
    (1, 512, 4, 2, 128, 128),
    (3, 64, 2, 2, 16, 64),       # single kv block
    (2, 96, 3, 1, 32, 32),       # non-pow2 heads
]


@pytest.mark.parametrize("B,S,H,K,Dh,block", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_oracle(B, S, H, K, Dh, block, dtype):
    q = jnp.asarray(RNG.standard_normal((B, 1, H, Dh)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, K, Dh)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, K, Dh)), dtype)
    pos = jnp.asarray(RNG.integers(0, S, B), jnp.int32)
    out = ops.flash_decode(q, k, v, pos, block_k=block, interpret=True)
    ref = decode_attention_ref(q, k, v, pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


def test_flash_decode_through_engine_matches_reference(tiny_model):
    """The kernel-selection switch: use_pallas decode through the fused
    engine reproduces the reference path's greedy tokens."""
    cfg, params = tiny_model
    ref_reqs = _reqs(cfg, n=3)
    _run(cfg, params, ref_reqs, decode_chunk=8)
    got = _reqs(cfg, n=3)
    _run(cfg, params, got, decode_chunk=8,
         run=RunConfig(remat="none", use_pallas=True))
    assert [r.output for r in got] == [r.output for r in ref_reqs]


# --------------------------------------------------- ledger / queue orders ----

def test_wallclock_ledger_decay_forgives_old_hogs():
    """ROADMAP item: with no cluster event loop driving ``decay_to``, the
    opt-in wall clock decays the ledger, so an old hog's ancient usage
    stops dominating once fresh consumption lands."""
    clock = {"t": 0.0}
    ctrl = AdmissionController(
        tree=FairShareTree(half_life_s=100.0),
        wall_clock_decay=True, clock=lambda: clock["t"])
    ctrl.add_tenant("hog", shares=1)
    ctrl.add_tenant("fresh", shares=1)
    ctrl.tree.charge_tres("hog", {"tokens": 1000.0})
    assert ctrl.tree.fair_share_factor("hog") < 0.3   # punished while hot
    clock["t"] += 1000.0                  # 10 half-lives pass, no events
    fresh_req = Request(rid=0, prompt=np.zeros(4, np.int32),
                        tenant="fresh")
    ctrl.charge(fresh_req, tokens=10)     # any charge/pick ticks the clock
    assert ctrl.tree.usage["hog"] < 1.5   # 1000 * 2^-10: absolute decay
    # the decayed hog no longer dominates the root total, so its standing
    # recovers; without decay it would still hold ~99% of usage (~0.25)
    assert ctrl.tree.fair_share_factor("hog") > 0.85


def test_wallclock_decay_off_by_default():
    ctrl = AdmissionController(tree=FairShareTree(half_life_s=100.0))
    ctrl.tree.charge_tres("hog", {"tokens": 1000.0})
    ctrl.submit(Request(rid=0, prompt=np.zeros(4, np.int32), tenant="hog"))
    ctrl.next_request()
    assert ctrl.tree.usage["hog"] == pytest.approx(1000.0)


def test_qos_orders_within_tenant_queue():
    """ROADMAP item: a high-QOS request no longer waits behind a
    same-tenant scavenger one; FIFO still breaks ties within a QOS."""
    ctrl = AdmissionController()
    scav1 = Request(rid=0, prompt=np.zeros(4, np.int32), qos="scavenger")
    norm = Request(rid=1, prompt=np.zeros(4, np.int32), qos="normal")
    hi = Request(rid=2, prompt=np.zeros(4, np.int32), qos="high")
    scav2 = Request(rid=3, prompt=np.zeros(4, np.int32), qos="scavenger")
    for r in (scav1, norm, hi, scav2):
        ctrl.submit(r)
    order = [ctrl.next_request() for _ in range(4)]
    assert order == [hi, norm, scav1, scav2]


def test_requeued_victim_heads_its_qos_class():
    ctrl = AdmissionController()
    victim = Request(rid=0, prompt=np.zeros(4, np.int32), qos="scavenger")
    ctrl.submit(victim)
    assert ctrl.next_request() is victim
    later = Request(rid=1, prompt=np.zeros(4, np.int32), qos="scavenger")
    ctrl.submit(later)
    ctrl.release(victim)
    ctrl.requeue(victim)                  # original seq: ahead of `later`
    hi = Request(rid=2, prompt=np.zeros(4, np.int32), qos="high")
    ctrl.submit(hi)                       # ...but behind higher QOS
    assert [ctrl.next_request() for _ in range(3)] == [hi, victim, later]


# ------------------------------------------------------------ dry-run glue ----

def test_fused_serve_step_lowers(tiny_model):
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_mesh
    from repro.serving import (
        fused_serve_step_lowering_args, make_fused_serve_step,
    )
    cfg, _ = tiny_model
    run = RunConfig(strategy="dp", remat="none")
    mesh = make_mesh(1, 1)
    shape = InputShape("decode_smoke", 64, 2, "decode")
    step = make_fused_serve_step(cfg, run, mesh, 2, 64, num_tokens=4)
    args = fused_serve_step_lowering_args(cfg, run, mesh, shape)
    lowered = step.lower(*args)
    assert "while" in lowered.as_text() or "scan" in lowered.as_text()
