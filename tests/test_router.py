"""Elastic multi-replica serving: the prefix-affinity router.

* ``HashRing``: per-replica key spread stays within 2x of uniform and
  removing a replica remaps *only* that replica's keys (property test);
* routing: same-prefix requests co-locate on the affine replica, convoys
  spill to the least-loaded one, round-robin cycles;
* global admission: every replica bills the one shared FairShareTree
  (usage burned on replica 0 demotes the tenant on replica 1) and the
  one shared GrpTresLedger (a slot cap binds cluster-wide, not
  per-replica x N — unless ``grp_scope="replica"``);
* bit-identity: greedy output through 2 replicas == single engine,
  including across a mid-flight drain/resume cycle;
* ``benchmarks/run.py --compare`` names baseline benches the run skipped.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:             # container has no hypothesis wheel
    from _mini_hypothesis import given, settings, strategies as st

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.policy import QOS, default_qos_table
from repro.serving import (
    DecodeEngine, HashRing, Request, Router, affinity_key,
)


def _req(rid, prompt, tenant="default", qos="normal", max_new=4):
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=max_new, tenant=tenant, qos=qos)


class FakeEngine:
    """Duck-typed replica for jax-free routing tests: real admission
    controller, no device work.  ``start()`` pulls queue heads into
    fake slots so load/drain see in-flight requests."""

    def __init__(self, admission, num_slots=4):
        self.admission = admission
        self.num_slots = num_slots
        self.paging = None
        self.running = []

    def submit(self, req):
        self.admission.submit(req)

    def start(self):
        while len(self.running) < self.num_slots:
            req = self.admission.next_request()
            if req is None:
                break
            self.running.append(req)

    def active(self):
        return len(self.running)

    def pending(self):
        return self.admission.pending()

    def step(self):
        return 0

    def radix_occupancy(self):
        return {"nodes": 0, "evictable_pages": 0}

    def drain(self):
        drained = list(self.running)
        self.running.clear()
        for t in self.admission.tenants.values():
            drained.extend(t.queue)
            t.queue.clear()
        drained.sort(key=lambda r: r._seq)
        return drained


def fake_router(n=2, **kw):
    kw.setdefault("policy", "affinity")
    return Router(lambda adm: FakeEngine(adm), replicas=n, **kw)


# ------------------------------------------------------------ hash ring ----

@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=2, max_value=6),
       st.integers(min_value=0, max_value=10_000))
def test_ring_balance_and_minimal_remap(n_replicas, seed):
    """Consistent hashing's two contracts: (1) with 64 vnodes each
    replica owns within 2x of its uniform key share; (2) removing one
    replica remaps only the keys it owned."""
    ring = HashRing()
    for r in range(n_replicas):
        ring.add(r)
    rng = np.random.default_rng(seed)
    keys = [bytes(rng.integers(0, 256, 12, dtype=np.uint8).tobytes())
            for _ in range(400)]
    owner = {k: ring.lookup(k) for k in keys}
    uniform = len(keys) / n_replicas
    for rid in range(n_replicas):
        share = sum(1 for o in owner.values() if o == rid)
        assert share <= 2 * uniform, (rid, share, uniform)

    victim = int(rng.integers(0, n_replicas))
    ring.remove(victim)
    for k in keys:
        if owner[k] != victim:
            assert ring.lookup(k) == owner[k]   # survivors keep their keys
        else:
            assert ring.lookup(k) != victim


def test_ring_is_deterministic_across_instances():
    """SHA-1, not the per-process salted hash(): two rings built the
    same way route the same — restart-stable affinity."""
    a, b = HashRing(), HashRing()
    for r in (0, 1, 2):
        a.add(r)
        b.add(r)
    key = affinity_key(np.arange(16, dtype=np.int32), 16)
    assert a.lookup(key) == b.lookup(key)
    assert a.replicas == [0, 1, 2] and len(a) == 3


def test_affinity_key_is_first_complete_page():
    prompt = np.arange(40, dtype=np.int32)
    assert affinity_key(prompt, 16) == affinity_key(prompt[:16], 16)
    assert affinity_key(prompt, 16) != affinity_key(prompt + 1, 16)
    # shorter than one page: the whole prompt is the key
    assert affinity_key(prompt[:5], 16) == b"0,1,2,3,4"


# -------------------------------------------------------------- routing ----

def test_affinity_colocates_shared_prefixes():
    router = fake_router(n=3)
    shared = np.arange(32, dtype=np.int32)
    rids = {router.route(_req(i, shared)) for i in range(8)}
    assert len(rids) == 1                       # all on the affine replica
    assert rids == {router.ring.lookup(affinity_key(shared,
                                                    router.page_size))}
    for i in range(8):
        router.submit(_req(10 + i, shared))
    assert router.stats["routed"] == 8
    assert router.stats["affinity_hits"] == 16  # 8 route() + 8 submit()


def test_round_robin_cycles():
    router = fake_router(n=3, policy="rr")
    prompt = np.arange(8, dtype=np.int32)
    got = [router.route(_req(i, prompt)) for i in range(6)]
    assert got == [0, 1, 2, 0, 1, 2]


def test_overloaded_affine_replica_spills_to_least_loaded():
    router = fake_router(n=2, spill_factor=1.0)
    shared = np.arange(32, dtype=np.int32)
    affine = router.route(_req(0, shared))
    other = next(r for r in router.replicas if r != affine)
    # pile queued work past spill_factor * num_slots onto the affine one
    for i in range(10):
        router.replicas[affine].engine.submit(_req(100 + i, shared))
    assert router.load(affine) - router.load(other) > 1.0 * 4
    assert router.route(_req(1, shared)) == other
    assert router.stats["spills"] == 1
    # drain the convoy: affinity resumes
    router.replicas[affine].engine.drain()
    assert router.route(_req(2, shared)) == affine


# ---------------------------------------------- shared admission state ----

def test_replicas_share_one_fairshare_tree():
    """Usage burned through replica 0 demotes the tenant on replica 1:
    all per-replica controllers bill the same tree object."""
    router = fake_router(n=2)
    router.add_tenant("heavy", shares=1)
    router.add_tenant("light", shares=1)
    r0, r1 = (router.replicas[r] for r in sorted(router.replicas))
    assert r0.admission.tree is router.tree
    assert r1.admission.tree is router.tree

    prompt = np.arange(8, dtype=np.int32)
    r1.engine.submit(_req(1, prompt, tenant="heavy"))
    r1.engine.submit(_req(2, prompt, tenant="light"))
    # equal usage: FIFO tie-break picks the earlier arrival ("heavy")...
    assert r1.admission._best_tenant().name == "heavy"
    # ...until replica 0 bills tokens for "heavy" on the shared tree
    r0.admission.tree.charge_tres("heavy", {"tokens": 10_000.0})
    assert r1.admission._best_tenant().name == "light"


def _capped_table():
    table = default_qos_table()
    table["normal"] = QOS(name="normal", priority=table["normal"].priority,
                          grp_tres={"slots": 2})
    return table


def test_grp_tres_cap_binds_globally_across_replicas():
    """grp_scope="global" (default): 2 slots for the account means 2
    across the whole fleet — replica 1 refuses the third admission even
    though its own slots are free."""
    router = fake_router(n=2, qos_table=_capped_table())
    prompt = np.arange(8, dtype=np.int32)
    r0, r1 = (router.replicas[r] for r in sorted(router.replicas))
    assert r0.admission.grp_ledger is router.grp_ledger
    for i in range(2):
        r0.engine.submit(_req(i, prompt, tenant="acme"))
    r1.engine.submit(_req(2, prompt, tenant="acme"))
    r0.engine.start()                           # takes 2 slots on replica 0
    assert r0.engine.active() == 2
    assert router.grp_ledger.held("acme", "normal")["slots"] == 2.0
    assert r1.admission.next_request() is None  # global cap is exhausted
    r0.admission.release(r0.engine.running.pop())
    assert r1.admission.next_request() is not None


def test_grp_tres_cap_per_replica_scope():
    """grp_scope="replica": no shared ledger — the same workload admits
    on replica 1 because each controller counts only its own slots."""
    router = fake_router(n=2, qos_table=_capped_table(),
                         grp_scope="replica")
    assert router.grp_ledger is None
    prompt = np.arange(8, dtype=np.int32)
    r0, r1 = (router.replicas[r] for r in sorted(router.replicas))
    for i in range(2):
        r0.engine.submit(_req(i, prompt, tenant="acme"))
    r1.engine.submit(_req(2, prompt, tenant="acme"))
    r0.engine.start()
    assert r1.admission.next_request() is not None


# ------------------------------------------------- bit-identity (jax) ----

def _engines(cfg, params):
    def make(adm):
        return DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            admission=adm)
    return make


def test_two_replicas_bit_identical_to_single_engine():
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, 5 + i).astype(np.int32)
               for i in range(5)]

    ref = [Request(rid=i, prompt=p, max_new_tokens=4)
           for i, p in enumerate(prompts)]
    single = DecodeEngine(cfg, params, num_slots=2, cache_len=64)
    for r in ref:
        single.submit(r)
    single.run_to_completion()

    router = Router(_engines(cfg, params), replicas=2, policy="rr")
    got = [Request(rid=i, prompt=p, max_new_tokens=4)
           for i, p in enumerate(prompts)]
    for r in got:
        router.submit(r)
    router.run_to_completion()
    for g, s in zip(got, ref):
        assert g.done and g.output == s.output, (g.rid, g.output, s.output)


def test_drain_resumes_in_flight_requests_bit_identically():
    """The autoscaler's core contract: draining a replica mid-decode
    moves its in-flight requests (partial output retained) to the
    survivors and the final greedy outputs are unchanged."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab_size, 6 + i).astype(np.int32)
               for i in range(4)]

    ref = [Request(rid=i, prompt=p, max_new_tokens=6)
           for i, p in enumerate(prompts)]
    single = DecodeEngine(cfg, params, num_slots=2, cache_len=64)
    for r in ref:
        single.submit(r)
    single.run_to_completion()

    router = Router(_engines(cfg, params), replicas=2, policy="rr")
    got = [Request(rid=i, prompt=p, max_new_tokens=6)
           for i, p in enumerate(prompts)]
    placed = [router.submit(r) for r in got]    # rr: 0, 1, 0, 1
    router.step()                               # partial output everywhere
    victim = placed[1]
    on_victim = [r for r, rid in zip(got, placed) if rid == victim]
    assert router.load(victim) > 0
    partial = {r.rid: list(r.output) for r in on_victim}
    assert any(partial.values())                # genuinely mid-flight

    moved = router.remove_replica(victim)
    assert moved == len(on_victim)
    assert router.stats["drains"] == 1
    assert router.stats["resubmitted"] == moved
    for r in on_victim:                         # partial output retained
        assert list(r.output)[:len(partial[r.rid])] == partial[r.rid]

    router.run_to_completion()
    for g, s in zip(got, ref):
        assert g.done and g.output == s.output, (g.rid, g.output, s.output)
    assert all(r.preemptions >= 1 for r in on_victim)


# ------------------------------------------------------ bench baseline ----

def test_compare_warns_on_baseline_benches_missing_from_run(
        tmp_path, capsys):
    """Satellite: a partial run against a full baseline must name the
    benches it skipped on stderr (but still pass — CI gates subsets)."""
    from benchmarks.run import compare_against, write_results
    path = tmp_path / "baseline.json"
    write_results([("kept", 100.0, "x"), ("gone_a", 50.0, "y"),
                   ("gone_b", 80.0, "z")], str(path))
    assert compare_against([("kept", 101.0, "x")], str(path)) == 0
    err = capsys.readouterr().err
    assert "WARNING: 2 baseline bench(es) not in this run" in err
    assert "gone_a, gone_b" in err
    # full run: no warning
    assert compare_against([("kept", 100.0, "x"), ("gone_a", 50.0, "y"),
                            ("gone_b", 80.0, "z")], str(path)) == 0
    assert "WARNING" not in capsys.readouterr().err
