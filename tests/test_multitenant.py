"""Multi-tenant scheduling: account tree, fair-share factors, QOS limits,
preemption + requeue (with checkpoint restore), and the convergence
properties the subsystem exists for:

* with equal shares and persistent demand from two accounts, accumulated
  TRES usage stays within 10% of parity over a 10k-event simulation even
  when one tenant's jobs are 3x longer;
* a ``high`` QOS job preempts a ``scavenger`` job, which requeues and
  completes, with both segments visible in ``sacct``;
* starved accounts' priority rises as the dominant account's usage decays.
"""
import numpy as np
import pytest

from repro.cluster import (
    Cluster, FairShareTree, JobState, Node, Partition, PriorityWeights, QOS,
    ResourceRequest, commands, default_qos_table,
)
from repro.cluster.qos import PREEMPT_CANCEL, job_tres, tres_within


def small_cluster(n_nodes=4, qos_table=None, fairshare=None) -> Cluster:
    nodes = [Node(name=f"n{i:02d}", cpus=16, mem_mb=65536,
                  gres={"tpu": 4}, coord=(0, i)) for i in range(n_nodes)]
    parts = [Partition(name="gpu", nodes=tuple(n.name for n in nodes),
                       default=True)]
    return Cluster(nodes, parts, qos_table=qos_table, fairshare=fairshare)


def req(nodes=1, tpu=4, time_s=36_000):
    return ResourceRequest(nodes=nodes, gres_per_node={"tpu": tpu},
                           cpus_per_node=1, mem_mb_per_node=1024,
                           time_limit_s=time_s)


# ------------------------------------------------------- fair-share tree ----

def test_account_tree_norm_shares():
    t = FairShareTree()
    t.add_account("org", shares=1)
    t.add_account("a", parent="org", shares=3)
    t.add_account("b", parent="org", shares=1)
    assert t.norm_shares("org") == 1.0            # only child of root
    assert t.norm_shares("a") == pytest.approx(0.75)
    assert t.norm_shares("b") == pytest.approx(0.25)


def test_usage_charges_propagate_to_ancestors():
    t = FairShareTree()
    t.add_account("org")
    t.add_account("team", parent="org")
    charged = t.charge("team", req(nodes=2), elapsed_s=100.0, now=100.0)
    assert charged > 0
    assert t.usage["team"] == pytest.approx(charged)
    assert t.usage["org"] == pytest.approx(charged)
    assert t.usage["root"] == pytest.approx(charged)


def test_usage_decay_half_life():
    t = FairShareTree(half_life_s=100.0)
    t.add_account("a")
    t.charge("a", req(), elapsed_s=10.0, now=0.0)
    before = t.usage["a"]
    t.decay_to(100.0)                             # exactly one half-life
    assert t.usage["a"] == pytest.approx(before / 2)
    t.decay_to(300.0)                             # two more
    assert t.usage["a"] == pytest.approx(before / 8)


def test_fair_share_factor_classic_curve():
    t = FairShareTree()
    t.add_account("a", shares=1)
    t.add_account("b", shares=1)
    assert t.fair_share_factor("a") == 1.0        # no usage anywhere
    # a consumes everything -> a's factor collapses, b's stays high
    t.charge("a", req(nodes=4), elapsed_s=1000.0, now=0.0)
    assert t.fair_share_factor("a") == pytest.approx(
        2.0 ** (-1.0 / 0.5))                      # usage=1, shares=0.5
    assert t.fair_share_factor("b") == 1.0        # zero usage


def test_tres_weights_tpu_dominates():
    t = FairShareTree()
    r = ResourceRequest(nodes=1, gres_per_node={"tpu": 4}, cpus_per_node=8,
                        mem_mb_per_node=1024)
    cost = t.tres_cost_per_s(r)
    tpu_part = t.tres_weights["gres/tpu"] * 4
    assert tpu_part / cost > 0.9                  # TPU-seconds dominate


def test_job_tres_vector_and_limits():
    tres = job_tres(req(nodes=2, tpu=4))
    assert tres["gres/tpu"] == 8
    assert tres_within({}, tres, {"gres/tpu": 8})
    assert not tres_within({"gres/tpu": 4}, tres, {"gres/tpu": 8})


# ---------------------------------------------------- multifactor priority ----

def test_starved_account_outranks_dominant():
    c = small_cluster(n_nodes=1)
    c.fairshare.add_account("hog")
    c.fairshare.add_account("starved")
    (blocker,) = c.submit("blocker", req(), run_time_s=100)
    c.fairshare.charge("hog", req(nodes=4), elapsed_s=50_000.0, now=0.0)
    (h,) = c.submit("h", req(), account="hog", run_time_s=10)
    (s,) = c.submit("s", req(), account="starved", run_time_s=10)
    engine = c.priority_engine
    ph = engine.priority(c.jobs[h], c.clock, c.partitions, len(c.nodes))
    ps = engine.priority(c.jobs[s], c.clock, c.partitions, len(c.nodes))
    assert ps > ph
    c.run()
    assert c.jobs[s].start_time < c.jobs[h].start_time


def test_dominant_account_recovers_after_decay():
    """Usage is normalized to the total, so an idle ex-hog recovers as its
    decayed history shrinks relative to others' fresh usage."""
    t = FairShareTree(half_life_s=1000.0)
    t.add_account("hog")
    t.add_account("other")              # sibling splits the shares
    t.charge("hog", req(nodes=4), elapsed_s=10_000.0, now=0.0)
    low = t.fair_share_factor("hog")
    assert low < 0.5
    # hog idles for many half-lives while the sibling works: the hog's
    # share of total usage collapses and its factor rises again
    for k in range(1, 11):
        t.charge("other", req(nodes=4), elapsed_s=1000.0, now=k * 1000.0)
    assert t.norm_usage("hog") < 0.01
    assert t.fair_share_factor("hog") > 0.9
    assert t.fair_share_factor("other") < t.fair_share_factor("hog")


def test_qos_boost_orders_queue():
    c = small_cluster(n_nodes=1)
    c.submit("blocker", req(), run_time_s=100)
    (lo,) = c.submit("lo", req(), qos="scavenger", run_time_s=10)
    (hi,) = c.submit("hi", req(), qos="high", run_time_s=10)
    c.run()
    assert c.jobs[hi].start_time < c.jobs[lo].start_time


# ------------------------------------------------------------ preemption ----

def test_high_preempts_scavenger_requeues_and_completes():
    """The acceptance-criterion scenario, end to end."""
    c = small_cluster(n_nodes=4)
    (sc,) = c.submit("scav", req(nodes=4), user="bob", qos="scavenger",
                     run_time_s=1000, ckpt_interval_s=100)
    assert c.jobs[sc].state == JobState.RUNNING
    c.clock = 250.0
    (hi,) = c.submit("prod", req(nodes=4), user="alice", qos="high",
                     run_time_s=50)
    # eviction happened inside the submit's scheduling pass
    assert c.jobs[hi].state == JobState.RUNNING
    assert c.jobs[sc].state == JobState.PENDING
    assert c.jobs[sc].requeue_count == 1
    assert c.jobs[sc].progress_s == 200.0         # floor(250/100)*100
    c.run()
    assert c.jobs[sc].state == JobState.COMPLETED
    assert c.jobs[hi].state == JobState.COMPLETED
    # both segments accounted: PREEMPTED (250s) then COMPLETED (800s)
    segs = [r for r in c.accounting if r.job_id == sc]
    assert [r.state for r in segs] == ["PREEMPTED", "COMPLETED"]
    assert segs[0].elapsed == pytest.approx(250.0)
    assert segs[1].elapsed == pytest.approx(800.0)
    # and sacct shows both rows (count the name column, not "scavenger")
    out = commands.sacct(c)
    assert out.count("scav ") == 2 and "PREEMPTED" in out
    assert c.preemptions_total == 1


def test_preempt_mode_cancel_kills_victim():
    table = default_qos_table()
    table["scavenger"] = QOS("scavenger", priority=0,
                             preempt_mode=PREEMPT_CANCEL)
    c = small_cluster(n_nodes=2, qos_table=table)
    (sc,) = c.submit("scav", req(nodes=2), qos="scavenger", run_time_s=1000)
    (hi,) = c.submit("prod", req(nodes=2), qos="high", run_time_s=10)
    assert c.jobs[hi].state == JobState.RUNNING
    assert c.jobs[sc].state == JobState.CANCELLED
    assert c.jobs[sc].reason == f"PreemptedBy={hi}"
    c.run()
    assert c.jobs[sc].state == JobState.CANCELLED  # never resurrected


def test_preemption_evicts_only_needed_victims():
    c = small_cluster(n_nodes=4)
    ids = [c.submit(f"s{i}", req(nodes=1), qos="scavenger",
                    run_time_s=1000)[0] for i in range(4)]
    (hi,) = c.submit("hi", req(nodes=2), qos="high", run_time_s=10)
    assert c.jobs[hi].state == JobState.RUNNING
    evicted = [j for j in ids if c.jobs[j].state == JobState.PENDING]
    assert len(evicted) == 2                      # not all four
    assert c.preemptions_total == 2


def test_normal_cannot_preempt_normal():
    c = small_cluster(n_nodes=1)
    (a,) = c.submit("a", req(), qos="normal", run_time_s=1000)
    (b,) = c.submit("b", req(), qos="normal", priority=9, run_time_s=10)
    assert c.jobs[a].state == JobState.RUNNING    # b waits: no preemption
    assert c.jobs[b].state == JobState.PENDING
    assert c.preemptions_total == 0


def test_preempted_job_restores_from_checkpoint_store(tmp_path):
    from repro.checkpoint import store
    ckpt = str(tmp_path / "job-ckpts")
    # convention: the trainer saves step = seconds of completed work
    store.save(ckpt, step=450, tree={"w": np.zeros(2)})
    c = small_cluster(n_nodes=2)
    (sc,) = c.submit("train", req(nodes=2), qos="scavenger",
                     run_time_s=1000, checkpoint_dir=ckpt)
    c.clock = 500.0
    (hi,) = c.submit("prod", req(nodes=2), qos="high", run_time_s=10)
    assert c.jobs[sc].state == JobState.PENDING
    assert c.jobs[sc].progress_s == 450.0         # from the store, not lost
    c.run()
    assert c.jobs[sc].state == JobState.COMPLETED
    segs = [r for r in c.accounting if r.job_id == sc]
    assert segs[-1].elapsed == pytest.approx(550.0)   # only the remainder


# ------------------------------------------------------------ QOS limits ----

def test_grp_tres_limit_holds_jobs():
    table = default_qos_table()
    table["scavenger"] = QOS("scavenger", priority=0,
                             grp_tres={"gres/tpu": 8})
    c = small_cluster(n_nodes=4, qos_table=table)
    a = c.submit("a", req(nodes=1), qos="scavenger", run_time_s=100)[0]
    b = c.submit("b", req(nodes=1), qos="scavenger", run_time_s=100)[0]
    h = c.submit("c", req(nodes=1), qos="scavenger", run_time_s=100)[0]
    assert c.jobs[a].state == JobState.RUNNING
    assert c.jobs[b].state == JobState.RUNNING    # 8 TPUs held = the cap
    assert c.jobs[h].state == JobState.PENDING
    assert c.jobs[h].reason == "QOSGrpResourceLimit"
    c.tick()                                      # a+b end -> c admitted
    assert c.jobs[h].state == JobState.RUNNING


def test_grp_tres_is_per_account():
    table = default_qos_table()
    table["scavenger"] = QOS("scavenger", priority=0,
                             grp_tres={"gres/tpu": 4})
    c = small_cluster(n_nodes=4, qos_table=table)
    a = c.submit("a", req(nodes=1), qos="scavenger", account="acct1",
                 run_time_s=100)[0]
    b = c.submit("b", req(nodes=1), qos="scavenger", account="acct2",
                 run_time_s=100)[0]
    assert c.jobs[a].state == JobState.RUNNING
    assert c.jobs[b].state == JobState.RUNNING    # different account's cap


def test_qos_max_wall_rejected():
    table = default_qos_table()
    table["scavenger"] = QOS("scavenger", max_wall_s=100)
    c = small_cluster(qos_table=table)
    with pytest.raises(ValueError):
        c.submit("x", req(time_s=1000), qos="scavenger")


def test_unknown_qos_rejected():
    c = small_cluster()
    with pytest.raises(ValueError):
        c.submit("x", req(), qos="platinum")


# -------------------------------------------------------------- fairness ----

def test_fairshare_convergence_10k_events():
    """Equal shares + persistent demand from two accounts -> accumulated
    TRES usage parity within 10%, even though tenant B's jobs run 3x
    longer (a FIFO scheduler would converge to ~3x instead)."""
    c = small_cluster(n_nodes=4,
                      fairshare=FairShareTree(half_life_s=50_000.0))
    c.fairshare.add_account("tenant_a", shares=1)
    c.fairshare.add_account("tenant_b", shares=1)
    c.fairshare.add_user("ua", "tenant_a")
    c.fairshare.add_user("ub", "tenant_b")

    def refill():
        for user, acct, rt in (("ua", "tenant_a", 60.0),
                               ("ub", "tenant_b", 180.0)):
            pending = sum(1 for j in c._pending() if j.account == acct)
            while pending < 3:
                c.submit("work", req(nodes=1), user=user, run_time_s=rt)
                pending += 1

    refill()
    events = 0
    while events < 10_000:
        if not c.tick():
            break
        events += 1
        refill()
    assert events == 10_000

    spent = {"tenant_a": 0.0, "tenant_b": 0.0}
    for r in c.accounting:
        spent[r.account] += r.tres_charged
    ratio = spent["tenant_a"] / spent["tenant_b"]
    assert 0.9 <= ratio <= 1.1, (ratio, spent)


def test_unequal_shares_bias_service():
    """10:1 shares with identical demand -> the big tenant gets more of
    the cluster (sanity direction check on the same machinery)."""
    c = small_cluster(n_nodes=4,
                      fairshare=FairShareTree(half_life_s=20_000.0))
    c.fairshare.add_account("big", shares=10)
    c.fairshare.add_account("small", shares=1)

    def refill():
        for acct in ("big", "small"):
            pending = sum(1 for j in c._pending() if j.account == acct)
            while pending < 3:
                c.submit("w", req(nodes=2), account=acct, run_time_s=120.0)
                pending += 1

    refill()
    for _ in range(2000):
        if not c.tick():
            break
        refill()
    spent = {"big": 0.0, "small": 0.0}
    for r in c.accounting:
        if r.account in spent:
            spent[r.account] += r.tres_charged
    assert spent["big"] > spent["small"] * 1.5


# ------------------------------------------------------------------- HA ----

def test_ha_snapshot_preserves_fairshare_and_qos():
    c = small_cluster()
    c.fairshare.add_account("team", shares=7)
    c.fairshare.add_user("alice", "team")
    (a,) = c.submit("a", req(nodes=2), user="alice", run_time_s=30)
    c.tick()
    snap = c.snapshot()
    standby = Cluster.restore(snap)
    assert standby.fairshare.accounts["team"].shares == 7
    assert standby.fairshare.account_of("alice") == "team"
    assert standby.fairshare.usage["team"] == pytest.approx(
        c.fairshare.usage["team"])
    assert set(standby.qos_table) == set(c.qos_table)
    # the restored controller keeps scheduling with the same policy
    (b,) = standby.submit("b", req(), user="alice", qos="high", run_time_s=5)
    standby.run()
    assert standby.jobs[b].state == JobState.COMPLETED


# ------------------------------------------------------------ monitoring ----

def test_per_account_metrics_exported():
    from repro.monitoring import MetricsRegistry
    from repro.monitoring.metrics import (
        METRIC_ACCOUNT_FAIRSHARE, METRIC_ACCOUNT_USAGE, METRIC_PREEMPTIONS,
    )
    c = small_cluster(n_nodes=2)
    c.metrics = MetricsRegistry()
    c.fairshare.add_account("team")
    (sc,) = c.submit("s", req(nodes=2), account="team", qos="scavenger",
                     run_time_s=500)
    c.clock = 100.0
    c.submit("h", req(nodes=2), qos="high", run_time_s=10)
    c.run()
    assert c.metrics.gauge(METRIC_PREEMPTIONS).value() == 1
    assert c.metrics.gauge(METRIC_ACCOUNT_USAGE).value(account="team") > 0
    f = c.metrics.gauge(METRIC_ACCOUNT_FAIRSHARE).value(account="team")
    assert 0.0 < f < 1.0
    text = c.metrics.expose()
    assert 'slurm_account_tres_usage{account="team"}' in text
    assert 'slurm_preempted_segments{account="team",qos="scavenger"}' in text
