"""Speculative decoding: draft-and-verify inside the fused chunk.

Greedy bit-identity against plain paged serving for k in {1, 2, 4} and
across the engine's orthogonal modes (budgeted batching, prefix cache,
model draft source), EOS mid-accepted-run stopping, starvation-requeue
round-trips with speculation live, the rejection sampler's
distribution-preservation (frequency test), the n-gram draft machinery
(own-context self-match fallback, cross-request index LRU), finished
requests' generated pages landing in the radix index with a
prompt/generated hit split, the sdiag speculation section, and per-user
``tenant/user`` fair-share leaf associations.
"""
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.serving import DecodeEngine, Request
from repro.serving.spec import (
    ModelDraftSource, NgramDraftSource, NgramIndex, greedy_accept,
    rejection_sample,
)
from repro.serving.spec import _SlotNgrams


@pytest.fixture(scope="module")
def tiny_model():
    from repro.models import init_params
    cfg = get_reduced_config("stablelm-3b")
    return cfg, init_params(cfg, 0)


def _repeat_reqs(cfg, n=3, seed=3, **kw):
    """Repeat-heavy prompts (a base phrase looped) so prompt-lookup
    drafting has material to match."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        base = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([base] * 3),
                           max_new_tokens=12 + i, **kw))
    return out


def _run(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    assert all(r.done for r in reqs), [(r.rid, r.done) for r in reqs]
    return {r.rid: list(r.output) for r in reqs}


# ------------------------------------------------------- bit-identity ----

@pytest.mark.parametrize("k", [1, 2, 4])
def test_spec_greedy_identical(tiny_model, k):
    """THE speculation contract: greedy output is bit-identical to plain
    decoding at any draft length — acceptance compares the target's own
    argmax rows (computed on bitwise-identical logits via verify_tokens)
    against the drafts, so a wrong draft costs speed, never tokens."""
    cfg, params = tiny_model
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), _repeat_reqs(cfg))
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, speculate=k)
    assert _run(eng, _repeat_reqs(cfg)) == ref
    st = eng.spec_stats
    assert st["rounds"] > 0 and st["proposed"] > 0, st
    assert 0 <= st["accepted"] <= st["proposed"]
    assert st["proposed_by"].get("ngram", 0) == st["proposed"]


def test_spec_identical_under_budgeted_batching(tiny_model):
    """Speculation composes with continuous batching: decode lanes cost
    k+1 budget tokens each and verification fuses with the head prefill
    chunk in one dispatch — outputs still bit-identical."""
    cfg, params = tiny_model
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), _repeat_reqs(cfg))
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, speculate=2, max_batch_tokens=16)
    assert _run(eng, _repeat_reqs(cfg)) == ref
    assert eng.spec_stats["rounds"] > 0


def test_spec_identical_with_prefix_cache_and_generated_pages(tiny_model):
    """Speculation + radix prefix cache: identical outputs, and finished
    requests' generated tokens are indexed at FINISH — a later identical
    request reuses those pages, with the hit split attributing them to
    generated (not prompt) provenance."""
    cfg, params = tiny_model
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), _repeat_reqs(cfg))
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, speculate=2, prefix_cache=True)
    got = _run(eng, _repeat_reqs(cfg))
    assert got == ref
    # resubmit request 0's prompt extended by its own output: the match
    # now walks into pages indexed from generated tokens
    seq = np.concatenate([_repeat_reqs(cfg)[0].prompt,
                          np.asarray(ref[0], np.int32)])
    tail = Request(rid=9, prompt=seq, max_new_tokens=4)
    _run(eng, [tail])
    assert eng.prefix.generated_hits > 0, (
        eng.prefix.prompt_hits, eng.prefix.generated_hits)


def test_spec_identical_with_model_draft_source(tiny_model):
    """The draft-model source (own dense cache, decode_n scan) keeps the
    same contract: any disagreement is corrected by the verify row, so
    even an untrained random draft yields bit-identical output."""
    cfg, params = tiny_model
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), _repeat_reqs(cfg))
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, speculate=2, spec_source="model")
    assert _run(eng, _repeat_reqs(cfg)) == ref
    assert eng.spec_stats["proposed_by"].get("model", 0) > 0


def test_spec_oracle_draft_full_accept(tiny_model):
    """Oracle draft (the target itself): every proposal accepted, which
    exercises the full-accept catch-up path — the k-step draft scan never
    wrote draft k-1's own KV line, so a pending token must be replayed
    before the next draft — and output stays bit-identical."""
    cfg, params = tiny_model
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), _repeat_reqs(cfg))
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, speculate=3, spec_source="model")
    eng.spec = ModelDraftSource(cfg, eng.num_slots, eng.cache_len,
                                params=params, run=eng.run)
    assert _run(eng, _repeat_reqs(cfg)) == ref
    st = eng.spec_stats
    assert st["proposed"] > 0 and st["accepted"] == st["proposed"], st


def test_spec_eos_mid_accepted_run(tiny_model):
    """EOS inside an accepted run stops the request THERE: trailing
    accepted drafts are discarded (decode_n's emit-then-freeze walk,
    replayed host-side), so output matches non-speculative EOS decoding
    exactly."""
    cfg, params = tiny_model
    plain = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                              kv_page_size=8), _repeat_reqs(cfg))
    # pick a token the reference emits mid-stream and make it EOS
    eos = plain[2][len(plain[2]) // 2]
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8),
               _repeat_reqs(cfg, eos_id=eos))
    assert any(len(ref[r]) < len(plain[r]) for r in ref), \
        "EOS never fired; test is vacuous"
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, speculate=4)
    assert _run(eng, _repeat_reqs(cfg, eos_id=eos)) == ref


def test_spec_starvation_requeue_round_trip(tiny_model):
    """Page-pool pressure starves a speculating request mid-decode: it
    requeues (draft state released), resumes via chunked re-prefill (the
    draft source re-begins with the full context), and the final output
    still matches an unconstrained non-speculative run."""
    cfg, params = tiny_model
    reqs = _repeat_reqs(cfg, n=3)
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), _repeat_reqs(cfg, n=3))
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, kv_pages=8,  # 7 usable pages
                       speculate=2)
    assert _run(eng, reqs) == ref
    assert eng.metrics.counter("serve_page_starvations").value() >= 1, \
        "pool never starved; test is vacuous"


# ------------------------------------------------------- guards ----

def test_speculate_requires_paging_and_fused(tiny_model):
    cfg, params = tiny_model
    with pytest.raises(ValueError, match="kv[-_]paging"):
        DecodeEngine(cfg, params, num_slots=2, cache_len=64, speculate=2)
    with pytest.raises(ValueError, match="fused"):
        DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                     kv_page_size=8, fused=False, speculate=2)
    with pytest.raises(ValueError, match="spec_source"):
        DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                     kv_page_size=8, speculate=2, spec_source="psychic")


# ------------------------------------------------- acceptance rules ----

def test_greedy_accept_runs():
    t = np.array([5, 6, 7, 8])
    assert list(greedy_accept(t, np.array([5, 6, 7]))) == [5, 6, 7, 8]
    assert list(greedy_accept(t, np.array([5, 9, 7]))) == [5, 6]
    assert list(greedy_accept(t, np.array([9, 6, 7]))) == [5]
    assert list(greedy_accept(t[:1], np.array([], np.int32))) == [5]


def test_rejection_sample_preserves_distribution():
    """Frequency test: with point-mass drafts, each emitted position's
    marginal must be the target row's distribution — acceptance when the
    draft is likely, residual resampling when it is not."""
    rng = np.random.default_rng(0)
    p_accept = np.array([[0.7, 0.2, 0.1]])
    counts = np.zeros(3)
    trials = 4000
    for _ in range(trials):
        out = rejection_sample(rng, np.vstack([p_accept, p_accept]),
                               np.array([0]))
        counts[out[0]] += 1
    # first emitted token ~ target row regardless of the draft
    freq = counts / trials
    assert np.allclose(freq, p_accept[0], atol=0.03), freq
    # an impossible draft is always rejected, residual renormalized
    probs = np.array([[0.0, 0.5, 0.5], [1.0, 0.0, 0.0]])
    outs = {tuple(rejection_sample(rng, probs, np.array([0])))
            for _ in range(200)}
    assert all(len(o) == 1 and o[0] in (1, 2) for o in outs), outs
    # full acceptance emits the bonus token from the final row
    sure = np.array([[0.0, 1.0, 0.0], [0.0, 0.0, 1.0]])
    assert list(rejection_sample(rng, sure, np.array([1]))) == [1, 2]


# ----------------------------------------------------- n-gram drafts ----

def test_slot_ngrams_self_match_falls_back():
    """The context tail's gram always matches itself at the end — the
    lookup must fall back to the *previous* occurrence (or nothing)."""
    s = _SlotNgrams((3, 2), [1, 2, 3, 9, 1, 2, 3])
    assert list(s.match(4)) == [9, 1, 2, 3]      # earlier (1,2,3) -> 9...
    s2 = _SlotNgrams((3, 2), [1, 2, 3])
    assert s2.match(4) is None                    # only the self-match
    s.append([9])                                 # now ...3, 9 repeats
    assert list(s.match(2)) == [1, 2]


def test_ngram_index_last_wins_and_evicts():
    idx = NgramIndex(orders=(2,), max_continuation=4, capacity=3)
    idx.observe([1, 2, 7, 7, 7])
    assert list(idx.lookup([0, 1, 2])) == [7, 7, 7]
    idx.observe([1, 2, 8])                        # same gram, new tail
    assert list(idx.lookup([1, 2])) == [8]
    idx.observe([4, 5, 6, 7])                     # capacity 3: oldest out
    assert len(idx) <= 3
    assert idx.lookup([9, 9]) is None


def test_ngram_source_uses_cross_request_index():
    src = NgramDraftSource(orders=(2,))
    src.observe([1, 2, 3, 4, 5])                  # a finished request
    src.begin(0, [9, 1, 2])                       # new request, no self-rep
    assert list(src.draft(0, 3)) == [3, 4, 5]
    src.advance(0, [3, 4])
    assert list(src.draft(0, 2)) == [5]
    src.release(0)
    assert len(src.draft(0, 2)) == 0              # released slot: no drafts


# ------------------------------------------------------- surfaces ----

def test_sdiag_speculation_golden():
    from types import SimpleNamespace

    from repro.cluster import commands
    eng = SimpleNamespace(
        max_batch_tokens=None, speculate=4,
        spec_stats={"rounds": 10, "proposed": 40, "accepted": 30,
                    "emitted": 40, "proposed_by": {"ngram": 40}})
    assert commands.sdiag(engine=eng) == "\n".join([
        "Speculative decoding:",
        "\tDraft length (k): 4",
        "\tVerify rounds:    10",
        "\tProposed:         40 (ngram: 40)",
        "\tAccepted:         30 (75%)",
        "\tTokens/round:     4.00",
    ])
    # non-speculating engines contribute no section
    off = SimpleNamespace(max_batch_tokens=None, speculate=0,
                          spec_stats={})
    assert commands.sdiag(engine=off) == "sdiag: nothing to report"


# ------------------------------------------- per-user fair share ----

def test_per_user_leaf_associations(tiny_model):
    """Requests carrying a ``user`` bill a ``tenant/user`` leaf account
    (auto-associated at submit): two users of one tenant fair-share
    against each other inside the tenant's slice, and the tenant's own
    standing aggregates both."""
    cfg, params = tiny_model
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8)
    adm = eng.admission
    adm.add_tenant("acme", shares=4)
    reqs = [Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32),
                    max_new_tokens=4, tenant="acme",
                    user=("ann" if i % 2 == 0 else "bob"))
            for i in range(4)]
    _run(eng, reqs)
    tree = adm.tree
    assert tree.accounts["acme/ann"].parent == "acme"
    assert tree.accounts["acme/bob"].parent == "acme"
    assert tree.account_of("ann") == "acme/ann"
    for leaf in ("acme/ann", "acme/bob"):
        assert tree.usage.get(leaf, 0.0) > 0.0, leaf
    # leaf charges propagate: the tenant's usage covers both users'
    assert tree.usage["acme"] >= tree.usage["acme/ann"]
    assert tree.usage["acme"] >= tree.usage["acme/bob"]
    # sibling leaves split the tenant's normalized share
    assert tree.norm_shares("acme/ann") == pytest.approx(
        tree.norm_shares("acme") / 2)
    # userless requests on the same tenant still bill the tenant node
    assert adm.account_for(Request(rid=9, prompt=np.arange(3),
                                   tenant="acme")) == "acme"
