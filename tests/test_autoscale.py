"""Elastic autoscaling: scavenger replica jobs inside the SLURM sim.

* ``Cluster.capacity_now`` — the slurm_now-style probe: largest
  replica-shaped job that would start immediately, pure read;
* growth: the autoscaler fills idle nodes up to ``max_replicas`` with
  ``kind="serve_replica"`` scavenger jobs, one router replica each;
* drain: a high-QOS batch job preempts a placeholder through the
  cluster's own QOS machinery and the next tick drains that replica —
  queued requests resume on the survivors;
* yield: pending work that *cannot* preempt (a scavenger peer) still
  gets nodes — the tick proactively drains the emptiest replica;
* floor: ``min_replicas`` keeps serving even with every job knocked out.

All jax-free: routing/draining is exercised through the duck-typed
``FakeEngine`` replica (bit-identity of drained decode is covered by
``test_router.py`` on real engines).
"""
import numpy as np

from repro.cluster import (
    JOB_KIND_SERVE_REPLICA, Cluster, JobState, Node, Partition,
    ResourceRequest,
)
from repro.serving import Autoscaler, Request, Router

from test_router import FakeEngine


def make_cluster(n_nodes=4) -> Cluster:
    nodes = [Node(name=f"n{i:02d}", cpus=16, mem_mb=65536,
                  gres={"tpu": 4}, coord=(0, i)) for i in range(n_nodes)]
    parts = [Partition(name="serve", nodes=tuple(n.name for n in nodes),
                       default=True)]
    return Cluster(nodes, parts)


def replica_req(nodes=1):
    return ResourceRequest(nodes=nodes, gres_per_node={"tpu": 4},
                           cpus_per_node=1, mem_mb_per_node=1024,
                           time_limit_s=36_000)


def make_scaler(cluster, min_replicas=1, max_replicas=4):
    router = Router(lambda adm: FakeEngine(adm), replicas=0, policy="rr")
    scaler = Autoscaler(router, cluster, req=replica_req(),
                        min_replicas=min_replicas,
                        max_replicas=max_replicas)
    return router, scaler


def _req(rid):
    return Request(rid=rid, prompt=np.arange(8, dtype=np.int32),
                   max_new_tokens=4)


# ------------------------------------------------------- capacity probe ----

def test_capacity_now_is_a_pure_read():
    c = make_cluster(4)
    assert c.capacity_now(replica_req()) == 4
    assert c.capacity_now(replica_req(nodes=2)) == 4
    assert not c.jobs                           # probing submits nothing
    c.submit("batch", replica_req(nodes=3), run_time_s=1e6)
    assert c.capacity_now(replica_req()) == 1
    # "largest job that starts now": a 2-node ask still reports the one
    # idle node (the autoscaler compares the answer against req.nodes)
    assert c.capacity_now(replica_req(nodes=2)) == 1
    assert c.probe_stats["probes"] == 4
    assert c.probe_stats["last_nodes"] == 1


def test_scale_up_fills_idle_nodes():
    c = make_cluster(4)
    router, scaler = make_scaler(c, max_replicas=3)
    scaler.tick()
    assert len(router.replicas) == 3            # capped by max_replicas
    assert scaler.stats["scale_ups"] == 3
    jobs = [c.jobs[j] for j in scaler.jobs.values()]
    assert all(j.state == JobState.RUNNING for j in jobs)
    assert all(j.kind == JOB_KIND_SERVE_REPLICA for j in jobs)
    assert all(j.qos == "scavenger" for j in jobs)
    scaler.tick()                               # idempotent at the cap
    assert len(router.replicas) == 3
    # at the cap the loop never re-probes; the last reading was taken
    # just before the third scale-up (2 idle nodes at that moment)
    assert scaler.stats["last_probe"] == 2
    assert c.capacity_now(replica_req()) == 1   # one node actually idle


def test_preempted_replica_job_drains_through_router():
    """High-QOS batch work takes nodes back via the cluster's own
    preemption; the next tick notices the lost job and drains that
    replica — its queued requests land on the survivors."""
    c = make_cluster(2)
    router, scaler = make_scaler(c, max_replicas=2)
    scaler.tick()
    assert len(router.replicas) == 2
    reqs = [_req(i) for i in range(4)]
    placed = [router.submit(r) for r in reqs]   # rr: both replicas loaded
    assert set(placed) == {0, 1}

    c.submit("train", replica_req(), qos="high", run_time_s=1e6)
    assert c.preemptions_total == 1             # one placeholder requeued
    lost = [rid for rid, jid in scaler.jobs.items()
            if c.jobs[jid].state != JobState.RUNNING]
    assert len(lost) == 1
    scaler.tick()
    assert len(router.replicas) == 1
    assert scaler.stats["drains"] == 1
    survivor = next(iter(router.replicas))
    assert survivor not in lost
    # every request is still queued somewhere (drained ones re-routed)
    assert scaler.stats["requeued_requests"] == 2
    assert router.load(survivor) == 4


def test_yield_to_scavenger_peer_pressure():
    """A pending batch job that cannot preempt us (scavenger QOS) must
    not starve: the tick gives back the emptiest replica's nodes."""
    c = make_cluster(2)
    router, scaler = make_scaler(c, max_replicas=2)
    scaler.tick()
    assert len(router.replicas) == 2
    router.submit(_req(0))                      # rr -> replica 0 is busier
    jid = c.submit("sweep", replica_req(), qos="scavenger",
                   run_time_s=1e6)[0]
    assert c.jobs[jid].state == JobState.PENDING
    scaler.tick()
    assert len(router.replicas) == 1            # emptiest (idle) one gone
    assert router.load(next(iter(router.replicas))) == 1
    assert c.jobs[jid].state == JobState.RUNNING


def test_min_replicas_floor_survives_losing_every_job():
    c = make_cluster(2)
    router, scaler = make_scaler(c, min_replicas=1, max_replicas=2)
    scaler.tick()
    assert len(router.replicas) == 2
    c.submit("train", replica_req(nodes=2), qos="high", run_time_s=1e6)
    assert all(c.jobs[j].state != JobState.RUNNING
               for j in scaler.jobs.values())   # both placeholders lost
    scaler.tick()
    # one drained, but the floor keeps the last replica serving even
    # though its placeholder job is requeued/waiting
    assert len(router.replicas) == 1
    scaler.tick()
    assert len(router.replicas) == 1
    assert scaler.stats["scale_ups"] == 2       # no capacity to regrow
