"""Continuous batching with chunked prefill (``max_batch_tokens``):
chunk-boundary bit-identity against classic whole-prompt paged serving,
head-of-line regression (a short's first token lands while a long
prompt is still mid-prefill), mid-prefill preemption at a chunk
boundary round-tripping through the tenant queue, SSM front-padded
bucketed prefill, the sdiag serve-step utilization section, the
--compare disjoint-percentile-key warning, and the chunked serve-step
dry-run twin."""
import numpy as np
import pytest

from repro.configs import RunConfig, get_reduced_config
from repro.serving import AdmissionController, DecodeEngine, Request


@pytest.fixture(scope="module")
def tiny_model():
    from repro.models import init_params
    cfg = get_reduced_config("stablelm-3b")
    return cfg, init_params(cfg, 0)


def _reqs(cfg, n=4, seed=3, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        4 + 5 * i).astype(np.int32),
                    max_new_tokens=6 + i, **kw)
            for i in range(n)]


def _run(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    assert all(r.done for r in reqs), [(r.rid, r.done) for r in reqs]
    return {r.rid: list(r.output) for r in reqs}


# ------------------------------------------------------- bit-identity ----

@pytest.mark.parametrize("budget", [1, 8, 13])
def test_chunked_greedy_identical_to_whole_prompt(tiny_model, budget):
    """Chunked prefill is just prefill_suffix applied repeatedly: greedy
    outputs must be bit-identical to classic whole-prompt paged serving
    at ANY budget — 1 (degenerate single-token chunks), 8 (= page_size,
    page-aligned chunks), 13 (odd: packs mixed 8/4/1 buckets, so chunks
    start and end mid-page)."""
    cfg, params = tiny_model
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), _reqs(cfg))
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, max_batch_tokens=budget)
    got = _run(eng, _reqs(cfg))
    assert got == ref
    st = eng.serve_stats
    assert st["prefill_tokens"] == sum(len(r.prompt)
                                       for r in _reqs(cfg))
    assert st["prefill_chunks"] >= 1 and st["iterations"] >= 1
    # O(buckets) programs: every chunk of every request at every depth
    # reuses the per-bucket chunk/mixed programs
    assert eng.chunk_compilations() <= 2 * len(eng.chunk_buckets)


def test_chunked_identical_with_prefix_cache(tiny_model):
    """Budgeted admission composes with the radix prefix cache: a
    partial starts with the shared pages already mapped (pos_filled
    jumps past them) and only the suffix streams through chunks."""
    cfg, params = tiny_model
    rng = np.random.default_rng(9)
    shared = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)

    def reqs():
        return [Request(rid=i,
                        prompt=np.concatenate(
                            [shared, rng2.integers(
                                0, cfg.vocab_size,
                                3 + 5 * i).astype(np.int32)]),
                        max_new_tokens=5)
                for i in range(3)]

    rng2 = np.random.default_rng(4)
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), reqs())
    rng2 = np.random.default_rng(4)
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, prefix_cache=True,
                       max_batch_tokens=8)
    got = _run(eng, reqs())
    assert got == ref
    from repro.monitoring.metrics import METRIC_SERVE_PREFIX_HITS
    assert eng.metrics.counter(METRIC_SERVE_PREFIX_HITS).value() >= 1


# ------------------------------------------------- head-of-line removal ----

def test_short_first_token_lands_mid_long_prefill(tiny_model):
    """THE continuous-batching property: a short prompt's first token is
    produced while a long prompt sharing the engine is still mid-prefill
    — classic serving can't do this (admission prefills the whole prompt
    in one shot before any other work)."""
    cfg, params = tiny_model
    long = Request(rid=0, prompt=np.arange(48, dtype=np.int32) % 50,
                   max_new_tokens=4)
    short = Request(rid=1, prompt=np.arange(5, dtype=np.int32),
                    max_new_tokens=8)
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       kv_page_size=8, max_batch_tokens=16)
    eng.submit(long)
    eng.submit(short)
    saw_hol_removal = False
    for _ in range(100):
        n = eng.step()
        if short.output and not long.done:
            part = next((p for p in eng._partials
                         if p.req is long), None)
            if part is not None and part.pos_filled < len(long.prompt):
                saw_hol_removal = True
        if n == 0:
            break
    assert saw_hol_removal, "short waited for the long's whole prefill"
    assert long.done and short.done
    # and the outputs still match an uncontended classic run
    ref_long = Request(rid=0, prompt=long.prompt.copy(), max_new_tokens=4)
    ref_short = Request(rid=1, prompt=short.prompt.copy(),
                        max_new_tokens=8)
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                            kv_page_size=8), [ref_long, ref_short])
    assert ref == {0: list(long.output), 1: list(short.output)}


# ------------------------------------------- mid-prefill preemption ----

def test_preempt_mid_prefill_round_trips_at_chunk_boundary(tiny_model):
    """A scavenger request preempted MID-PREFILL (pool pressure from a
    high-QOS arrival) lands back in its tenant queue at a chunk
    boundary: pages free, holdings return to zero, and the resumed
    prefill replays the prompt to an identical greedy output."""
    cfg, params = tiny_model
    scav = Request(rid=0, prompt=(np.arange(56, dtype=np.int32) % 50),
                   max_new_tokens=4, qos="scavenger")
    hi = Request(rid=1, prompt=(np.arange(96, dtype=np.int32) % 50),
                 max_new_tokens=4, qos="high")
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=128,
                       kv_page_size=8, kv_pages=17,  # 16 usable pages
                       max_batch_tokens=16)
    eng.submit(scav)
    eng.step()
    eng.step()                      # scav mid-prefill (32/56 tokens)
    assert eng._partials and not scav.done
    eng.submit(hi)                  # higher QOS chunks first; its pages
    eng.run_to_completion()         # (13) + scav's can't coexist (16)
    assert scav.done and hi.done
    assert scav.preemptions >= 1
    assert eng.admission.stats["requeues"] >= 1
    ref = _run(DecodeEngine(cfg, params, num_slots=2, cache_len=128,
                            kv_page_size=8),
               [Request(rid=0, prompt=scav.prompt.copy(),
                        max_new_tokens=4),
                Request(rid=1, prompt=hi.prompt.copy(),
                        max_new_tokens=4)])
    assert ref == {0: list(scav.output), 1: list(hi.output)}


# ---------------------------------------------------- SSM front-pad ----

def test_ssm_front_padded_buckets_identical(tiny_model):
    """SSM configs no longer auto-disable bucketed prefill: the prompt
    front-pads to the bucket at a chunk-aligned offset whose masked
    positions are the SSD scan's identity, so outputs stay bit-identical
    to exact-length prefill — at O(buckets) compiles."""
    from repro.models import init_params
    cfg = get_reduced_config("mamba2-780m")
    params = init_params(cfg, 0)

    def reqs():
        rng = np.random.default_rng(5)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            3 + 7 * i).astype(np.int32),
                        max_new_tokens=5)
                for i in range(3)]

    ref_eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64)
    assert ref_eng.prefill_buckets is None
    ref = _run(ref_eng, reqs())
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       prefill_buckets="auto")
    assert eng.prefill_buckets is not None and eng._front_pad
    assert _run(eng, reqs()) == ref
    assert eng.prefill_compilations() <= len(eng.prefill_buckets)


# -------------------------------------------------- guards & surfaces ----

def test_budgeted_mode_requires_paging_and_fused(tiny_model):
    cfg, params = tiny_model
    with pytest.raises(ValueError, match="kv_page_size"):
        DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                     max_batch_tokens=32)
    with pytest.raises(ValueError, match="fused"):
        DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                     kv_page_size=8, fused=False, max_batch_tokens=32)


def test_sdiag_serve_step_utilization_golden():
    """The serve-step section duck-types the engine: iterations, budget
    fill ratio, and the decode/prefill token split."""
    from types import SimpleNamespace

    from repro.cluster import commands
    eng = SimpleNamespace(
        max_batch_tokens=64,
        serve_stats={"iterations": 10, "decode_tokens": 400,
                     "prefill_tokens": 200, "prefill_chunks": 5})
    out = commands.sdiag(engine=eng)
    assert out == "\n".join([
        "Serve-step utilization (token budget):",
        "\tIterations:       10",
        "\tToken budget:     64/step",
        "\tBudget fill:      600/640 (94%)",
        "\tDecode tokens:    400 (67%)",
        "\tPrefill tokens:   200 (33%, 5 chunks)",
    ])
    # engines without a token budget contribute no section
    classic = SimpleNamespace(max_batch_tokens=None, serve_stats={})
    assert commands.sdiag(engine=classic) == "sdiag: nothing to report"


def test_compare_warns_on_disjoint_percentile_keys(tmp_path, capsys):
    """Renaming a percentile key silently un-gates the benchmark; the
    gate must say so — naming BOTH key sets — instead of skipping the
    percentile comparison without a trace."""
    from benchmarks.run import compare_against, write_results
    path = tmp_path / "base.json"
    write_results([("b1", 100.0, "x", {"ttft_p99_ms": 5.0})], str(path))
    rc = compare_against(
        [("b1", 100.0, "x", {"ttft_p99_ms_budgeted": 5.0})], str(path))
    err = capsys.readouterr().err
    assert rc == 0                       # reported, never fails the gate
    assert "WARNING b1: no shared percentile keys" in err
    assert "ttft_p99_ms" in err and "ttft_p99_ms_budgeted" in err
    # shared keys still gate: no warning, regression caught
    rc = compare_against(
        [("b1", 100.0, "x", {"ttft_p99_ms": 9.0})], str(path))
    err = capsys.readouterr().err
    assert rc == 1 and "WARNING" not in err


def test_chunked_serve_step_lowers(tiny_model):
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_mesh
    from repro.serving import (
        chunked_serve_step_lowering_args, make_chunked_serve_step,
    )
    cfg, _ = tiny_model
    run = RunConfig(strategy="dp", remat="none")
    mesh = make_mesh(1, 1)
    shape = InputShape("decode_smoke", 64, 2, "decode")
    step = make_chunked_serve_step(cfg, run, mesh, 2, 64, page_size=8,
                                   num_tokens=4)
    args = chunked_serve_step_lowering_args(cfg, run, mesh, shape,
                                            chunk=16, page_size=8)
    lowered = step.lower(*args)
    assert "while" in lowered.as_text() or "scan" in lowered.as_text()
