"""commands.py output surfaces: squeue/sacct formatting round-trips (parse
the rendered table back and compare to controller state), plus golden-output
tests for the new sshare/sprio surfaces on a deterministic scenario."""
import pytest

from repro.cluster import (
    Cluster, JobState, Node, Partition, ResourceRequest, commands,
)


def small_cluster(n_nodes=4) -> Cluster:
    nodes = [Node(name=f"n{i:02d}", cpus=16, mem_mb=65536,
                  gres={"tpu": 4}, coord=(0, i)) for i in range(n_nodes)]
    parts = [Partition(name="gpu", nodes=tuple(n.name for n in nodes),
                       default=True)]
    return Cluster(nodes, parts)


def req(nodes=1, time_s=36_000):
    return ResourceRequest(nodes=nodes, gres_per_node={"tpu": 4},
                           cpus_per_node=1, mem_mb_per_node=1024,
                           time_limit_s=time_s)


def two_tenant_cluster() -> Cluster:
    """Deterministic scenario: one running, one pending, one preempted."""
    c = small_cluster()
    commands.sacctmgr_add_account(c, "prod", fairshare=10)
    commands.sacctmgr_add_account(c, "research", fairshare=1)
    commands.sacctmgr_add_user(c, "alice", "prod")
    commands.sacctmgr_add_user(c, "bob", "research")
    c.submit("sweep", req(nodes=4), user="bob", qos="scavenger",
             run_time_s=2000, ckpt_interval_s=100)
    c.clock = 500.0
    c.submit("train", req(nodes=4), user="alice", qos="high",
             run_time_s=1000)                   # preempts the sweep
    c.submit("queued", req(nodes=2), user="bob", qos="normal",
             run_time_s=300)
    return c


# ------------------------------------------------------------ round-trips ----

# squeue columns: JOBID PARTITION NAME USER ACCOUNT QOS ST TIME NODES WHERE
_SQUEUE_COLS = ((0, 8), (8, 20), (20, 40), (40, 50), (50, 60), (60, 71),
                (71, 75), (75, 87), (87, 94), (94, None))


def _cells(row, spans):
    return [row[a:b].strip() if b else row[a:].strip() for a, b in spans]


def test_squeue_round_trips_controller_state():
    c = two_tenant_cluster()
    lines = commands.squeue(c).splitlines()
    assert _cells(lines[0], _SQUEUE_COLS)[:7] == [
        "JOBID", "PARTITION", "NAME", "USER", "ACCOUNT", "QOS", "ST"]
    live = {j.job_id: j for j in c.jobs.values() if not j.state.finished}
    assert len(lines) - 1 == len(live)
    for row in lines[1:]:
        (jid, part, name, user, account, qos, st, t, nnodes,
         where) = _cells(row, _SQUEUE_COLS)
        job = live[int(jid)]
        assert part == job.partition
        assert name == job.name
        assert user == job.user
        assert account == job.account
        assert qos == job.qos
        assert st == job.state.value
        assert int(nnodes) == job.req.nodes
        if job.state == JobState.RUNNING:
            assert where == ",".join(job.nodes_alloc)
        else:
            assert where == f"({job.reason})"


# sacct columns: JobID JobName Partition Account QOS State Elapsed NNodes Exit
_SACCT_COLS = ((0, 8), (8, 28), (28, 40), (40, 50), (50, 61), (61, 73),
               (73, 85), (85, 93), (93, None))


def _parse_elapsed(text):
    days = 0
    if "-" in text:
        d, text = text.split("-")
        days = int(d)
    h, m, s = (int(p) for p in text.split(":"))
    return days * 86_400 + h * 3_600 + m * 60 + s


def test_sacct_round_trips_accounting_segments():
    c = two_tenant_cluster()
    c.run()
    lines = commands.sacct(c).splitlines()
    assert len(lines) - 1 == len(c.accounting)   # one row per segment
    for row, rec in zip(lines[1:], c.accounting):
        (jid, name, part, account, qos, state, elapsed, nnodes,
         exit_) = _cells(row, _SACCT_COLS)
        assert int(jid) == rec.job_id
        assert name == rec.name
        assert part == rec.partition
        assert account == rec.account
        assert qos == rec.qos
        assert state == rec.state
        assert _parse_elapsed(elapsed) == int(rec.elapsed)
        assert int(nnodes) == len(rec.nodes)
        assert exit_ == f"{rec.exit_code or 0}:0"


def test_sacct_filters_by_user_and_account():
    c = two_tenant_cluster()
    c.run()
    only_alice = commands.sacct(c, user="alice")
    assert "train" in only_alice and "sweep" not in only_alice
    only_research = commands.sacct(c, account="research")
    assert "sweep" in only_research and "train" not in only_research


# ---------------------------------------------------------------- goldens ----

def test_sshare_golden():
    """research burned 500s x 4 nodes x 4.05 weighted-TRES x 0.25 scavenger
    discount = 2025; with NormShares 0.0909 its factor is 2^-11 ~ 0.0005."""
    c = two_tenant_cluster()
    assert commands.sshare(c) == "\n".join([
        "Account        RawShares NormShares    RawUsage NormUsage FairShare",  # noqa: E501
        "root                   1     1.0000        2025    1.0000    0.5000",
        " prod                 10     0.9091           0    0.0000    1.0000",
        " research              1     0.0909        2025    1.0000    0.0005",
    ])


def test_sprio_golden():
    """Job 1 (requeued sweep): age 500s/7d*1000 ~ 1, fairshare 10000*0.0005,
    size 4/4 nodes * 500, partition tier 1000, scavenger QOS 0.  Job 3:
    normal QOS 500/1000 * 2000 = 1000, size 2/4 * 500 = 250."""
    c = two_tenant_cluster()
    assert commands.sprio(c) == "\n".join([
        "JOBID   USER      ACCOUNT    PRIORITY    AGE FAIRSHARE JOBSIZE PARTITION    QOS  NICE",  # noqa: E501
        "1       bob       research       1506      1         5     500      1000      0     0",  # noqa: E501
        "3       bob       research       2255      0         5     250      1000   1000     0",  # noqa: E501
    ])


def test_sacctmgr_show_surfaces():
    c = two_tenant_cluster()
    assoc = commands.sacctmgr_show_assoc(c)
    assert "prod" in assoc and "alice" in assoc
    qos = commands.sacctmgr_show_qos(c)
    assert "scavenger" in qos and "requeue" in qos
    assert "normal,scavenger" in qos          # high's preempt list


def test_scontrol_show_job_includes_tenancy():
    c = two_tenant_cluster()
    out = commands.scontrol_show_job(c, 1)
    assert "Account=research" in out
    assert "QOS=scavenger" in out
    assert "Restarts=1" in out                # it was preempted once


# --------------------------------------------------------- elastic tier ----

def test_sdiag_router_autoscaler_golden():
    """Deterministic elastic-tier scenario: a 2-node cluster fully
    scaled into (2 scavenger replica jobs), 4 shared-prefix requests all
    affine to replica 0 (SHA-1 ring placement is restart-stable, so the
    rendering is a true golden)."""
    import numpy as np

    from repro.monitoring.metrics import (
        METRIC_ROUTE_AFFINITY_HITS, METRIC_SERVE_REPLICA_LOAD,
    )
    from repro.serving import Autoscaler, Request, Router
    from test_router import FakeEngine

    c = small_cluster(2)
    router = Router(lambda adm: FakeEngine(adm), replicas=0,
                    policy="affinity")
    scaler = Autoscaler(router, c, req=req(), min_replicas=1,
                        max_replicas=2)
    scaler.tick()
    shared = np.arange(32, dtype=np.int32)
    for i in range(4):
        router.submit(Request(rid=i, prompt=shared, max_new_tokens=4))
    router.replicas[0].engine.start()          # 4 slots -> all active
    router.step()                              # refresh the gauges

    assert commands.sdiag(router=router, autoscaler=scaler) == "\n".join([
        "Prefix-affinity router:",
        "\tReplicas:         2",
        "\tPolicy:           affinity (spill factor 2)",
        "\tRouted:           4",
        "\tAffinity hits:    4 (100%)",
        "\tSpills:           0",
        "\tDrains:           0 (0 requests re-routed)",
        "\tReplica 0:        load 4 (4 active, 0 queued), 0 radix nodes",
        "\tReplica 1:        load 0 (0 active, 0 queued), 0 radix nodes",
        "",
        "Autoscaler (scavenger replicas):",
        "\tTicks:            1",
        "\tLast probe:       1 idle node(s) @ 1/replica",
        "\tScale-ups:        2",
        "\tDrains:           0 (0 requests requeued)",
        "\tReplica jobs:     0->job 1, 1->job 2",
    ])
    # the per-replica gauges behind sdiag's load lines
    m = router.metrics
    assert m.gauge(METRIC_SERVE_REPLICA_LOAD, "").value(replica="0") == 4.0
    assert m.gauge(METRIC_SERVE_REPLICA_LOAD, "").value(replica="1") == 0.0
    assert m.counter(METRIC_ROUTE_AFFINITY_HITS, "").value() == 4.0
