"""Deterministic fallback for the `hypothesis` API surface this repo uses.

The CI container does not ship hypothesis and the repo must not pip-install
at test time, so when the real library is absent the property tests fall
back to this mini-fuzzer: the same @given/@settings decorators, backed by a
seeded PRNG that draws `max_examples` pseudo-random examples.  No shrinking,
no database — just deterministic coverage of the same strategy space.
"""
from __future__ import annotations

import functools
import inspect
import random


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value=0, max_value=1 << 31):
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq):
    items = list(seq)
    return Strategy(lambda rng: items[rng.randrange(len(items))])


def tuples(*strats):
    return Strategy(lambda rng: tuple(s.example(rng) for s in strats))


def lists(strat, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [strat.example(rng) for _ in range(n)]
    return Strategy(draw)


def floats(min_value=0.0, max_value=1.0):
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._mini_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_mini_max_examples", 25)
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                vals = [s.example(rng) for s in strats]
                fn(*args, *vals, **kwargs)
        # hide the example parameters so pytest doesn't treat them as
        # fixtures (real hypothesis does the same)
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper
    return deco


class _St:
    integers = staticmethod(integers)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    tuples = staticmethod(tuples)
    lists = staticmethod(lists)
    floats = staticmethod(floats)


strategies = _St()
