"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the real device
count (1 CPU); only launch/dryrun.py fakes 512 devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def cpu_mesh():
    from repro.launch.mesh import make_mesh
    return make_mesh(1, 1)
