"""Prefix cache: allocator refcount semantics (incl. the property-test
satellite), radix match/insert/evict unit behavior, engine-level shared
prefix reuse (bit-identical greedy outputs, copy-on-write safety, LRU
eviction under pressure, amortized residency billing), the improved
paged-mode config errors, the --prefix-cache CLI implication, and the
benchmarks --update-baseline satellite."""
import dataclasses
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:             # container has no hypothesis wheel
    from _mini_hypothesis import given, settings, strategies as st

from repro.models.paging import NULL_PAGE, PageAllocator, pages_for
from repro.monitoring.metrics import (
    METRIC_SERVE_PREFIX_EVICTIONS, METRIC_SERVE_PREFIX_HITS,
    METRIC_SERVE_PREFIX_MISSES, METRIC_SERVE_PREFIX_REUSED_TOKENS,
)
from repro.serving import (
    AdmissionController, DecodeEngine, PrefixCache, Request,
)


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_reduced_config
    from repro.models import init_params
    cfg = get_reduced_config("stablelm-3b")
    return cfg, init_params(cfg, 0)


# ----------------------------------------------------------- allocator ----

def test_allocator_refcounts_share_and_release():
    a = PageAllocator(6)
    got = a.alloc(2)
    assert all(a.refcount(p) == 1 for p in got)
    a.ref(got)                             # second holder
    assert all(a.refcount(p) == 2 for p in got)
    a.free(got)                            # first holder leaves
    assert a.available() == 3 and a.in_use == 2
    a.free(got)                            # last holder: back to the pool
    assert a.available() == 5 and a.in_use == 0
    assert all(a.refcount(p) == 0 for p in got)


def test_allocator_refuses_ref_on_free_and_double_free():
    a = PageAllocator(4)
    (p,) = a.alloc(1)
    a.free([p])
    with pytest.raises(AssertionError):
        a.ref([p])                         # ref on a free page
    with pytest.raises(AssertionError):
        a.free([p])                        # double free


# --------------------------------------------------------- radix index ----

def _toks(*blocks, ps=4):
    """Build a token array from per-page block ids: block b yields
    ``ps`` tokens [b*10, b*10+1, ...] so distinct ids never collide."""
    out = []
    for b in blocks:
        out.extend(b * 10 + i for i in range(ps))
    return np.asarray(out, np.int32)


def test_radix_match_insert_and_fork():
    a = PageAllocator(16)
    pc = PrefixCache(a, page_size=4)
    toks = _toks(1, 2, 3)                  # 12 tokens, 3 complete pages
    pages = a.alloc(3)
    assert pc.match(toks) == []
    assert pc.insert(toks, pages) == 3
    assert [n.page for n in pc.match(toks)] == pages[:2]  # strict prefix
    longer = np.concatenate([toks, _toks(4)])
    assert [n.page for n in pc.match(longer)] == pages    # all 3 now
    # divergence in block 2 forks: only block 1 matches
    fork = _toks(1, 7, 3)
    assert [n.page for n in pc.match(fork)] == pages[:1]
    # a second insert of the same blocks adds nothing (first wins)
    other = a.alloc(3)
    assert pc.insert(toks, other) == 0
    assert pc.nodes == 3


def test_radix_match_caps_below_last_token():
    a = PageAllocator(16)
    pc = PrefixCache(a, page_size=4)
    toks = _toks(1, 2)                     # exactly 2 pages
    pc.insert(toks, a.alloc(2))
    # a prompt that IS the cached blocks must still prefill its last
    # token: only (len-1)//ps = 1 page may match
    assert len(pc.match(toks)) == 1
    assert len(pc.match(np.asarray(toks[:4], np.int32))) == 0


def test_radix_evict_lru_leaf_first_and_pin():
    a = PageAllocator(16)
    pc = PrefixCache(a, page_size=4)
    p1 = a.alloc(2)
    pc.insert(_toks(1, 2), p1)          # chain 1 -> 2
    p2 = a.alloc(1)
    pc.insert(_toks(5), p2)             # sibling leaf, more recent
    a.free(p1 + p2)                        # producers release
    assert a.in_use == 3 and pc.evictable_pages() == 3
    # pin the older chain's leaf: its path becomes unevictable
    leaf = pc.match(_toks(1, 2, 9))[-1]
    pc.acquire([leaf])
    assert pc.evictable_pages() == 1       # only the sibling
    assert pc.evict(3) == 1                # pinned chain survives
    assert pc.nodes == 2
    a.free([leaf.page])                    # unpin
    assert pc.evict(5) == 2                # leaf first, then its parent
    assert pc.nodes == 0 and a.in_use == 0


# ------------------------------------------------- refcount properties ----

op_strategy = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=3),   # op kind
        st.integers(min_value=0, max_value=5),   # seed / slot
        st.integers(min_value=1, max_value=5),   # pages wanted
    ),
    min_size=1, max_size=60)


@settings(max_examples=40, deadline=None)
@given(op_strategy)
def test_allocator_refcount_invariants_hold(ops):
    """Satellite acceptance: through random admit/fork/free/evict
    sequences, no page is ever both free and referenced, and the total
    refcount equals page-table occupancy (slot-held pages) plus the
    radix index's own pins."""
    import itertools
    ps = 4
    alloc = PageAllocator(12)              # deliberately tight pool
    pc = PrefixCache(alloc, page_size=ps)
    slots: dict[int, list] = {}
    next_id = itertools.count()

    def check():
        free = set(alloc._free)
        held = {p for p in range(alloc.num_pages) if alloc.refcount(p) > 0}
        assert not free & held, "page both free and referenced"
        assert alloc.in_use == len(held)
        occupancy = sum(len(pages) for pages in slots.values())
        assert alloc.total_refs == occupancy + pc.nodes, \
            (alloc.total_refs, occupancy, pc.nodes)

    for kind, seed, want in ops:
        if kind == 0:                      # admit: match, acquire, alloc
            # prompts from 2 families with a shared head block => forks
            blocks = [seed % 2, seed % 3 + 2, seed + 4][:max(want % 3, 1) + 1]
            toks = np.concatenate([_toks(*blocks), _toks(9)[:1]])
            shared = pc.acquire(pc.match(toks))
            need = pages_for(len(toks), ps) - len(shared)
            priv = alloc.alloc(need)
            if priv is None and pc.evict(need - alloc.available()):
                priv = alloc.alloc(need)
            if priv is None:
                if shared:
                    alloc.free(shared)
            else:
                pages = shared + priv
                pc.insert(toks, pages)
                slots[next(next_id)] = pages
        elif kind == 1 and slots:          # finish/evict a slot
            key = sorted(slots)[seed % len(slots)]
            alloc.free(slots.pop(key))
        elif kind == 2:                    # capacity-pressure LRU evict
            pc.evict(want)
        check()
    for pages in slots.values():           # drain
        alloc.free(pages)
    slots.clear()
    pc.evict(alloc.num_pages)
    check()
    assert alloc.in_use == 0 and alloc.total_refs == 0


# ------------------------------------------------------ engine reuse ----

def _shared_reqs(cfg, n=4, sys_len=40, tail=6, max_new=6, **kw):
    rng = np.random.default_rng(11)
    system = rng.integers(2, cfg.vocab_size, sys_len).astype(np.int32)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [system,
                         rng.integers(2, cfg.vocab_size, tail).astype(
                             np.int32)]),
                    max_new_tokens=max_new, **kw)
            for i in range(n)]


def _run(cfg, params, reqs, **engine_kw):
    engine_kw.setdefault("prefill_buckets", (16, 32, 64))
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       decode_chunk=4, kv_page_size=8, **engine_kw)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    return eng


def test_prefix_reuse_bit_identical_and_counted(tiny_model):
    """Acceptance: greedy outputs with the prefix cache on are
    bit-identical to the no-reuse paged path, prefix pages are shared
    (hit/miss/reused-token counters), and finished requests leave their
    prompt pages cached (held only by the index)."""
    cfg, params = tiny_model
    ref = _shared_reqs(cfg)
    _run(cfg, params, ref)
    got = _shared_reqs(cfg)
    eng = _run(cfg, params, got, prefix_cache=True)
    assert [r.output for r in got] == [r.output for r in ref]
    m = eng.metrics
    assert m.counter(METRIC_SERVE_PREFIX_HITS).value() == 3
    assert m.counter(METRIC_SERVE_PREFIX_MISSES).value() == 1
    # 40-token system prompt = 5 shared 8-line pages per hit
    assert m.counter(METRIC_SERVE_PREFIX_REUSED_TOKENS).value() == 3 * 40
    assert eng.prefix.nodes == 5
    assert eng.allocator.in_use == 5       # cached pages outlive requests
    assert eng.prefix.evictable_pages() == 5
    assert eng._page_holders == {}         # no active holders remain


def test_prefix_reuse_exact_length_prefill_matches(tiny_model):
    """Reuse also works without buckets (exact-length suffix prefill)."""
    cfg, params = tiny_model
    ref = _shared_reqs(cfg, n=2)
    _run(cfg, params, ref, prefill_buckets=None)
    got = _shared_reqs(cfg, n=2)
    eng = _run(cfg, params, got, prefill_buckets=None, prefix_cache=True)
    assert [r.output for r in got] == [r.output for r in ref]
    assert eng.metrics.counter(METRIC_SERVE_PREFIX_HITS).value() == 1


def test_shared_pages_are_never_written(tiny_model):
    """COW safety: decode and suffix prefill must never write through a
    read-only shared mapping — the cached pages' pool lines are
    byte-identical before and after sharing requests run."""
    import jax
    cfg, params = tiny_model
    reqs = _shared_reqs(cfg, n=1)
    eng = _run(cfg, params, reqs, prefix_cache=True)
    cached = np.asarray([n.page for n in eng.prefix.match(
        np.concatenate([reqs[0].prompt, np.zeros(9, np.int32)]))])
    assert len(cached) == 5
    before = [np.asarray(leaf[:, cached])
              for leaf in jax.tree.leaves(eng.cache)]
    more = _shared_reqs(cfg, n=3, max_new=10)
    for r in more:
        eng.submit(r)
    eng.run_to_completion()
    assert eng.metrics.counter(METRIC_SERVE_PREFIX_HITS).value() >= 3
    after = [np.asarray(leaf[:, cached])
             for leaf in jax.tree.leaves(eng.cache)]
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)


def test_prefix_lru_eviction_under_pressure(tiny_model):
    """A full pool whose pages are only index-held must yield to a new
    request: unpinned cached prefixes LRU-evict back to the free pool
    (counted), and admission's page gate sees them as available."""
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    # pool: 8 usable pages; each 24-token prompt needs 3 + growth
    eng = DecodeEngine(cfg, params, num_slots=1, cache_len=64,
                       decode_chunk=4, kv_page_size=8, kv_pages=9,
                       prefill_buckets=(32, 64), prefix_cache=True)
    a = Request(rid=0, prompt=rng.integers(
        2, cfg.vocab_size, 24).astype(np.int32), max_new_tokens=4)
    eng.submit(a)
    eng.run_to_completion()
    assert a.done and eng.prefix.nodes == 3
    b = Request(rid=1, prompt=rng.integers(
        2, cfg.vocab_size, 50).astype(np.int32), max_new_tokens=4)
    eng.submit(b)                          # needs 7 pages; only 5 free
    eng.run_to_completion()
    assert b.done
    assert eng.metrics.counter(METRIC_SERVE_PREFIX_EVICTIONS).value() >= 1
    assert eng.prefix.nodes < 3 + 6


def test_shared_residency_bills_once_across_holders(tiny_model):
    """Billing satellite: with two live holders every shared page bills
    1/2 to each, so the tenant ledger's raw gres/kv_page consumption is
    strictly lower than the no-reuse run of the same workload."""
    cfg, params = tiny_model

    def ledger(prefix_cache):
        ctrl = AdmissionController()
        reqs = _shared_reqs(cfg, n=2, max_new=8, tenant="acct")
        eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                           admission=ctrl, decode_chunk=4, kv_page_size=8,
                           prefill_buckets=(16, 32, 64),
                           prefix_cache=prefix_cache)
        for r in reqs:
            eng.submit(r)
        eng._admit()                       # both prefilled, none decoded
        if prefix_cache:
            shared = [n.page for n in eng.prefix.match(
                np.concatenate([reqs[0].prompt, np.zeros(9, np.int32)]))]
            assert all(eng._page_holders[p] == 2 for p in shared)
        eng.run_to_completion()
        return ctrl.tree.tres_usage_of("acct")["gres/kv_page"]

    dup = ledger(False)
    amortized = ledger(True)
    assert amortized < 0.75 * dup, (amortized, dup)


def test_preempted_victim_resumes_through_prefix_cache(tiny_model):
    """A scavenger victim's resume prefill re-matches the cached prompt
    prefix and still finishes with the undisturbed solo output."""
    cfg, params = tiny_model
    rng = np.random.default_rng(9)
    prompt = rng.integers(2, cfg.vocab_size, 24).astype(np.int32)
    scav = Request(rid=0, prompt=prompt, max_new_tokens=24,
                   tenant="a", qos="scavenger")
    hi = Request(rid=1, prompt=prompt.copy(), max_new_tokens=24,
                 tenant="b", qos="high")
    eng = DecodeEngine(cfg, params, num_slots=1, cache_len=64,
                       decode_chunk=4, kv_page_size=8,
                       prefill_buckets=(32, 64), prefix_cache=True)
    eng.submit(scav)
    eng.step()
    eng.submit(hi)                         # evicts scav from the only slot
    eng.run_to_completion()
    assert scav.done and hi.done and scav.preemptions >= 1
    solo = Request(rid=2, prompt=prompt.copy(), max_new_tokens=24)
    solo_eng = DecodeEngine(cfg, params, num_slots=1, cache_len=64,
                            decode_chunk=4, kv_page_size=8,
                            prefill_buckets=(32, 64))
    solo_eng.submit(solo)
    solo_eng.run_to_completion()
    assert scav.output == solo.output == hi.output


def test_no_livelock_when_match_is_the_only_eviction_fodder(tiny_model):
    """Regression: when the private-page shortfall can only be covered
    by the matched prefix pages themselves (everything else pinned by a
    running request), placement must abandon the match and fall back to
    a plain prefill instead of bouncing admit->pin->evict-nothing->
    requeue forever."""
    cfg, params = tiny_model
    rng = np.random.default_rng(21)
    base = rng.integers(2, cfg.vocab_size, 24).astype(np.int32)
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       decode_chunk=2, kv_page_size=8, kv_pages=9,
                       prefill_buckets=(32, 64), prefix_cache=True)
    seed = Request(rid=0, prompt=base, max_new_tokens=2)
    eng.submit(seed)
    eng.run_to_completion()                # index now holds base's 3 pages
    assert seed.done and eng.prefix.nodes == 3
    hog = Request(rid=1, prompt=rng.integers(
        2, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=40)
    eng.submit(hog)
    eng.step()                             # hog runs, pinning free pages
    big = Request(rid=2, prompt=np.concatenate(
        [base, rng.integers(2, cfg.vocab_size, 24).astype(np.int32)]),
        max_new_tokens=2)
    eng.submit(big)                        # needs 6 pages; matches 3
    for _ in range(300):
        if eng.step() == 0:
            break
    assert hog.done and big.done
    assert eng.allocator.in_use == eng.prefix.nodes


# -------------------------------------------------------- config errors ----

def test_paged_config_errors_name_the_offending_field(tiny_model):
    """Satellite: the paged-mode refusal names the config field instead
    of the old generic 'non-sliding-window configs only'."""
    from repro.configs import get_reduced_config
    from repro.models import init_params
    cfg, params = tiny_model
    with pytest.raises(ValueError, match="cfg.sliding_window=8"):
        DecodeEngine(dataclasses.replace(cfg, sliding_window=8), params,
                     num_slots=1, cache_len=32, kv_page_size=8)
    ssm_cfg = get_reduced_config("mamba2-780m")
    with pytest.raises(ValueError, match="cfg.ssm="):
        DecodeEngine(ssm_cfg, init_params(ssm_cfg, 0), num_slots=1,
                     cache_len=32, kv_page_size=8)
    with pytest.raises(ValueError, match="cfg.attn_every=2"):
        DecodeEngine(dataclasses.replace(cfg, attn_every=2), params,
                     num_slots=1, cache_len=32, kv_page_size=8)


def test_prefix_cache_requires_paging(tiny_model):
    cfg, params = tiny_model
    with pytest.raises(ValueError, match="kv_page_size"):
        DecodeEngine(cfg, params, num_slots=1, cache_len=32,
                     prefix_cache=True)


def test_cli_prefix_cache_implies_kv_paging(capsys):
    from repro.launch.serve import (
        DEFAULT_PREFIX_PAGE_SIZE, resolve_prefix_paging,
    )
    assert resolve_prefix_paging(False, 0) == 0
    assert resolve_prefix_paging(False, 8) == 8
    assert resolve_prefix_paging(True, 8) == 8
    assert resolve_prefix_paging(True, 0) == DEFAULT_PREFIX_PAGE_SIZE
    assert "implies --kv-paging" in capsys.readouterr().out


# ------------------------------------------------------ bench baseline ----

def test_update_baseline_round_trips_with_compare(tmp_path, monkeypatch):
    """Satellite: --update-baseline writes the same schema --compare
    reads, so refreshing the CI baseline is one flag, not a hand edit."""
    from benchmarks.run import compare_against, write_results
    path = tmp_path / "baseline.json"
    write_results([("b1", 100.0, "x"), ("b2", 50.0, "y")], str(path))
    rows = json.loads(path.read_text())
    assert rows[0] == {"name": "b1", "us_per_call": 100.0, "derived": "x"}
    # same speed: gate passes against the freshly-updated baseline
    assert compare_against([("b1", 100.0, "x"), ("b2", 55.0, "y")],
                           str(path)) == 0
    assert compare_against([("b1", 130.0, "x")], str(path)) == 1
