"""SLURM command surface (§5.2.1 tables), provisioning/validation (§4),
monitoring (§6), and the allocation->Mesh bridge."""
import jax
import pytest

from repro.cluster import (
    Cluster, JobState, Node, NodeState, Partition, ResourceRequest,
    commands, provision, tpu_pod_spec, validate,
)
from repro.cluster.meshbridge import factor_mesh, mesh_for_job
from repro.monitoring import MetricsRegistry


@pytest.fixture()
def pod():
    spec = tpu_pod_spec(hosts_x=4, hosts_y=4)      # 16 hosts x 4 chips
    return provision(spec), spec


# ------------------------------------------------------------- commands ----

def test_sinfo_lists_partitions_and_states(pod):
    c, _ = pod
    out = commands.sinfo(c)
    assert "PARTITION" in out and "idle" in out
    c.submit("a", ResourceRequest(nodes=16, gres_per_node={"tpu": 4}),
             run_time_s=10)
    out = commands.sinfo(c)
    assert "alloc" in out


def test_squeue_shows_running_and_pending(pod):
    c, _ = pod
    c.submit("big", ResourceRequest(nodes=16, gres_per_node={"tpu": 4}),
             run_time_s=10)
    c.submit("queued", ResourceRequest(nodes=2, gres_per_node={"tpu": 4}),
             run_time_s=10)
    out = commands.squeue(c)
    assert " R " in out and " PD " in out
    assert "big" in out and "queued" in out


def test_sbatch_parses_slurm_script_options(pod):
    c, _ = pod
    msg = commands.sbatch(c, name="deep_learning_job", nodes=1,
                          gres="tpu:4", mem="32G", time="24:00:00",
                          cpus_per_task=8)
    jid = int(msg.split()[-1])
    job = c.jobs[jid]
    assert job.req.gres_per_node == {"tpu": 4}
    assert job.req.mem_mb_per_node == 32 * 1024
    assert job.req.time_limit_s == 24 * 3600
    assert job.req.cpus_per_node == 8


def test_srun_runs_script_and_returns_result(pod):
    c, _ = pod
    c.real_mode = True
    out = commands.srun(c, lambda job, alloc: f"hello from {len(alloc)}",
                        nodes=2)
    assert "hello from 2" in str(out)


def test_scancel_and_scontrol(pod):
    c, _ = pod
    (jid,) = c.submit("x", ResourceRequest(nodes=1,
                                           gres_per_node={"tpu": 4}),
                      run_time_s=100)
    show = commands.scontrol_show_job(c, jid)
    assert f"JobId={jid}" in show and "RUNNING" in show
    commands.scancel(c, jid)
    assert c.jobs[jid].state == JobState.CANCELLED
    nodes_out = commands.scontrol_show_nodes(c)
    assert "NodeName=" in nodes_out


def test_scontrol_update_node_drain(pod):
    c, _ = pod
    name = next(iter(c.nodes))
    commands.scontrol_update_node(c, name, "drain", reason="maintenance")
    assert c.nodes[name].state == NodeState.DRAIN


def test_sacct_reports_history(pod):
    c, _ = pod
    c.submit("done", ResourceRequest(nodes=1, gres_per_node={"tpu": 4}),
             run_time_s=5)
    c.run()
    out = commands.sacct(c)
    assert "done" in out and "COMPLETED" in out


# ---------------------------------------------------------- provisioning ----

def test_tpu_pod_spec_topology():
    spec = tpu_pod_spec(hosts_x=8, hosts_y=8)
    assert len(spec.hosts) == 64
    coords = {h.coord for h in spec.hosts}
    assert coords == {(x, y) for x in range(8) for y in range(8)}


def test_validation_passes_on_healthy_cluster(pod):
    c, spec = pod
    report = validate(c, spec)
    assert report.ok, str(report)


def test_validation_catches_down_node(pod):
    c, spec = pod
    c.set_node_state(next(iter(c.nodes)), NodeState.DOWN, "dead")
    report = validate(c, spec)
    assert not report.ok


# ------------------------------------------------------------ meshbridge ----

def test_factor_mesh():
    assert factor_mesh(16, 4) == (4, 4)
    assert factor_mesh(16, 1) == (16, 1)
    assert factor_mesh(12, 8) == (3, 4)     # gcd fallback


def test_mesh_for_job_builds_jax_mesh(pod):
    c, _ = pod
    c.real_mode = False
    (jid,) = c.submit("m", ResourceRequest(nodes=4,
                                           gres_per_node={"tpu": 4}),
                      run_time_s=100)
    mesh = mesh_for_job(c, c.jobs[jid], model_parallel=1)
    assert set(mesh.axis_names) == {"data", "model"}
    assert mesh.devices.size >= 1              # folded onto available devices


# ------------------------------------------------------------ monitoring ----

def test_metrics_counter_gauge_histogram():
    m = MetricsRegistry()
    m.counter("jobs_total", "jobs").inc()
    m.counter("jobs_total").inc(2, partition="gpu")
    m.gauge("util").set(0.5)
    m.histogram("lat").observe(0.1)
    m.histogram("lat").observe(0.9)
    assert m.counter("jobs_total").value() == 1
    assert m.counter("jobs_total").value(partition="gpu") == 2
    assert m.gauge("util").value() == 0.5
    assert m.histogram("lat").count() == 2
    text = m.expose()
    # prometheus exposition format
    assert "# TYPE jobs_total counter" in text
    assert 'jobs_total{partition="gpu"} 2' in text
    assert "lat_bucket" in text and 'le="+Inf"' in text


def test_metrics_quantile_and_dashboard():
    m = MetricsRegistry()
    for i in range(100):
        m.histogram("s").observe(i / 100.0)
    q = m.histogram("s").quantile(0.5)
    assert 0.3 <= q <= 0.8
    m.gauge("cluster_util").set(0.75)
    dash = m.dashboard()
    assert "cluster_util" in dash and "#" in dash


def test_cluster_metrics_hook(pod):
    c, _ = pod
    c.metrics = MetricsRegistry()
    c.submit("a", ResourceRequest(nodes=1, gres_per_node={"tpu": 4}),
             run_time_s=5)
    assert c.metrics.gauge("slurm_jobs_running").value() == 1
    c.run()
    assert c.metrics.gauge("slurm_jobs_running").value() == 0
