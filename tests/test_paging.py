"""Paged KV cache: allocator semantics, paged-vs-dense bit-identity
through the engine, the paged Pallas kernel vs its oracle, bucketed-prefill
x paging interaction (pad tails allocate and charge nothing), page-pool
exhaustion -> one-victim scavenger reclaim, kv_pages GrpTRES caps and
ledger residency, plus the sacctmgr modify satellite and the serve CLI's
--use-pallas fallback."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_reduced_config
from repro.kernels import ops
from repro.kernels.ref import paged_decode_attention_ref
from repro.models.paging import (
    NULL_PAGE, PageAllocator, PagedKVConfig, pages_for,
)
from repro.monitoring.metrics import METRIC_SERVE_PREEMPTIONS
from repro.policy import FairShareTree, QOS
from repro.serving import AdmissionController, DecodeEngine, Request

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def tiny_model():
    from repro.models import init_params
    cfg = get_reduced_config("stablelm-3b")
    return cfg, init_params(cfg, 0)


def _reqs(cfg, n=4, max_new=6, seed=3, plen=None, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        plen or (4 + 3 * i)).astype(np.int32),
                    max_new_tokens=max_new + (0 if plen else i), **kw)
            for i in range(n)]


def _run(cfg, params, reqs, num_slots=2, cache_len=64, **engine_kw):
    eng = DecodeEngine(cfg, params, num_slots=num_slots,
                       cache_len=cache_len, **engine_kw)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    return eng


# -------------------------------------------------------------- allocator ----

def test_allocator_all_or_nothing_and_null_reserved():
    a = PageAllocator(6)                  # null + 5 usable
    assert a.available() == 5
    got = a.alloc(3)
    assert len(got) == 3 and NULL_PAGE not in got
    assert a.alloc(3) is None             # only 2 left: all-or-nothing
    assert a.available() == 2             # the failed alloc took nothing
    more = a.alloc(2)
    assert a.available() == 0 and a.in_use == 5 and a.high_water == 5
    a.free(got)
    assert a.available() == 3 and a.in_use == 2
    again = a.alloc(3)                    # freed pages are reusable
    assert sorted(again) == sorted(got)
    assert a.alloc(0) == []
    a.free(more + again)
    assert a.in_use == 0 and a.high_water == 5


def test_paged_config_budget_math():
    pc = PagedKVConfig.for_budget(4 * 128, 16, 128)
    assert pc.usable_pages == 32 and pc.num_pages == 33
    assert pc.pages_per_seq == 8 and pc.capacity_tokens == 512
    assert pages_for(0, 16) == 0
    assert pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2


# ---------------------------------------------------------- paged kernel ----

PAGED_CASES = [
    # (B, H, K, Dh, page_size, pool_pages, table_pages)
    (2, 4, 2, 64, 16, 12, 4),
    (1, 8, 8, 64, 32, 6, 2),      # MHA
    (3, 4, 1, 32, 8, 20, 8),      # MQA, many small pages
]


@pytest.mark.parametrize("B,H,K,Dh,ps,pool,npages", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode_matches_oracle(B, H, K, Dh, ps, pool, npages,
                                           dtype):
    q = jnp.asarray(RNG.standard_normal((B, 1, H, Dh)), dtype)
    k = jnp.asarray(RNG.standard_normal((pool, ps, K, Dh)), dtype)
    v = jnp.asarray(RNG.standard_normal((pool, ps, K, Dh)), dtype)
    table = jnp.asarray(RNG.integers(1, pool, (B, npages)), jnp.int32)
    pos = jnp.asarray(RNG.integers(0, npages * ps, B), jnp.int32)
    out = ops.flash_decode_paged(q, k, v, table, pos, interpret=True)
    ref = paged_decode_attention_ref(q, k, v, table, pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


# ------------------------------------------------------ engine identity ----

def test_paged_greedy_bit_identical_to_dense(tiny_model):
    """Acceptance: greedy fused decode is bit-identical between the dense
    cache and the paged cache (both page sizes, chunk sizes that do and
    don't divide the generation lengths), and every page returns to the
    pool."""
    cfg, params = tiny_model
    ref = _reqs(cfg)
    _run(cfg, params, ref, decode_chunk=4)
    for page_size, chunk in ((8, 4), (16, 3)):
        got = _reqs(cfg)
        eng = _run(cfg, params, got, decode_chunk=chunk,
                   kv_page_size=page_size)
        assert [r.output for r in got] == [r.output for r in ref], page_size
        assert eng.allocator.in_use == 0
        assert (eng.page_tables == NULL_PAGE).all()


def test_paged_host_loop_matches_dense(tiny_model):
    cfg, params = tiny_model
    ref = _reqs(cfg, n=2)
    _run(cfg, params, ref, fused=False)
    got = _reqs(cfg, n=2)
    _run(cfg, params, got, fused=False, kv_page_size=8)
    assert [r.output for r in got] == [r.output for r in ref]


def test_paged_pallas_decode_matches_reference(tiny_model):
    """use_pallas routes paged decode through the paged split-KV kernel;
    greedy tokens must match the gathered-reference path."""
    cfg, params = tiny_model
    ref = _reqs(cfg, n=2, max_new=4)
    _run(cfg, params, ref, decode_chunk=4, kv_page_size=16)
    got = _reqs(cfg, n=2, max_new=4)
    _run(cfg, params, got, decode_chunk=4, kv_page_size=16,
         run=RunConfig(remat="none", use_pallas=True))
    assert [r.output for r in got] == [r.output for r in ref]


def test_paged_refused_for_ssm_and_ring_configs(tiny_model):
    from repro.models import init_params
    ssm_cfg = get_reduced_config("mamba2-780m")
    with pytest.raises(ValueError):
        DecodeEngine(ssm_cfg, init_params(ssm_cfg, 0), num_slots=1,
                     cache_len=32, kv_page_size=8)
    cfg, params = tiny_model
    win_cfg = dataclasses.replace(cfg, sliding_window=8)
    with pytest.raises(ValueError):
        DecodeEngine(win_cfg, params, num_slots=1, cache_len=32,
                     kv_page_size=8)


# ------------------------------------------------- bucketed x paged tails ----

def test_bucketed_pad_tail_allocates_and_charges_nothing(tiny_model):
    """Satellite acceptance: a 5-token prompt in a 32-bucket allocates
    ceil(5/16)=1 page — the 27 pad lines ride the null page and the
    ledger bills exactly one page."""
    cfg, params = tiny_model
    ctrl = AdmissionController()
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       admission=ctrl, decode_chunk=4, kv_page_size=16,
                       prefill_buckets=(32, 64))
    eng.submit(Request(rid=0, prompt=np.arange(2, 7).astype(np.int32),
                       max_new_tokens=4, tenant="acct"))
    eng._admit()                           # prefill only, no decode growth
    slot = next(i for i, r in enumerate(eng.slots) if r is not None)
    assert len(eng._slot_pages[slot]) == 1
    assert eng.allocator.in_use == 1
    row = eng.page_tables[slot]
    assert row[0] != NULL_PAGE and (row[1:] == NULL_PAGE).all()
    assert ctrl.tree.tres_usage_of("acct")["gres/kv_page"] == 1.0
    eng.run_to_completion()
    assert eng.allocator.in_use == 0


def test_bucketed_paged_outputs_match_dense_bucketed(tiny_model):
    cfg, params = tiny_model
    ref = _reqs(cfg, n=3)
    _run(cfg, params, ref, decode_chunk=4, prefill_buckets=(16, 32, 64))
    got = _reqs(cfg, n=3)
    _run(cfg, params, got, decode_chunk=4, prefill_buckets=(16, 32, 64),
         kv_page_size=8)
    assert [r.output for r in got] == [r.output for r in ref]


# ------------------------------------------------- exhaustion / reclaim ----

def test_pool_exhaustion_evicts_one_scavenger_and_reclaims(tiny_model):
    """Satellite acceptance: when decode-time growth exhausts the pool, a
    normal-QOS slot reclaims by evicting exactly one scavenger victim;
    the victim requeues with output retained, resumes later, and both
    finish with every page back in the pool."""
    cfg, params = tiny_model
    ctrl = AdmissionController()
    # usable pages: 6 x 8 lines = 48 < 2 slots x 40-line demand
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       admission=ctrl, decode_chunk=4, kv_page_size=8,
                       kv_pages=7)
    scav = Request(rid=0, prompt=np.arange(2, 10).astype(np.int32),
                   max_new_tokens=30, tenant="a", qos="scavenger")
    norm = Request(rid=1, prompt=np.arange(2, 10).astype(np.int32),
                   max_new_tokens=30, tenant="b", qos="normal")
    eng.submit(scav)
    eng.submit(norm)
    eng.run_to_completion()
    assert scav.done and norm.done
    assert scav.preemptions >= 1          # the reclaim victim
    assert eng.metrics.counter(METRIC_SERVE_PREEMPTIONS).value() >= 1
    assert len(scav.output) == 30 and len(norm.output) == 30
    assert eng.allocator.in_use == 0
    # resume correctness: the evicted run equals an undisturbed solo run
    solo = Request(rid=9, prompt=scav.prompt, max_new_tokens=30)
    _run(cfg, params, [solo], decode_chunk=4)
    assert scav.output == solo.output


def test_starved_slot_requeues_and_completes(tiny_model):
    """No evictable victim (all normal QOS) and a pool too small for both:
    the starved slot requeues (work retained, not truncated) and finishes
    once pages free up."""
    cfg, params = tiny_model
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       decode_chunk=4, kv_page_size=8, kv_pages=7)
    reqs = _reqs(cfg, n=2, max_new=30, plen=8)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    assert all(r.done and len(r.output) == 30 for r in reqs)
    assert eng.metrics.counter("serve_page_starvations").value() >= 1
    assert eng.allocator.in_use == 0


def test_reclaim_victim_below_requester_index_survives_dispatch(tiny_model):
    """Regression: a reclaim evicting a slot at a LOWER index than the
    growing slot must not leave a stale index in the step's active list
    (the readback loop would dereference the now-empty slot)."""
    cfg, params = tiny_model
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       decode_chunk=4, kv_page_size=8, kv_pages=5)
    scav = Request(rid=0, prompt=np.arange(2, 10).astype(np.int32),
                   max_new_tokens=20, qos="scavenger")
    eng.submit(scav)
    eng.step()                             # scav runs in slot 0, grows
    assert scav._slot == 0 and not scav.done
    hi = Request(rid=1, prompt=np.arange(2, 10).astype(np.int32),
                 max_new_tokens=20, qos="high")
    eng.submit(hi)
    eng.run_to_completion()                # pre-fix: AttributeError here
    assert hi.done and scav.done
    assert eng.metrics.counter(METRIC_SERVE_PREEMPTIONS).value() >= 1
    assert eng.allocator.in_use == 0


def test_submit_refuses_footprint_larger_than_pool(tiny_model):
    """A request whose worst-case pages exceed the pool would be vetoed
    by page-budget admission forever — submit refuses it loudly."""
    cfg, params = tiny_model
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       decode_chunk=4, kv_page_size=16, kv_pages=2)
    with pytest.raises(AssertionError):
        eng.submit(Request(rid=0, prompt=np.arange(2, 22).astype(np.int32),
                           max_new_tokens=8))


def test_kv_page_billing_scales_with_page_size(tiny_model):
    """One page bills like the lines it holds whatever the page size, so
    dense and paged tenants on one ledger stay fair-share comparable."""
    cfg, params = tiny_model
    for ps in (8, 32):
        ctrl = AdmissionController()
        DecodeEngine(cfg, params, num_slots=1, cache_len=64,
                     admission=ctrl, kv_page_size=ps)
        assert ctrl.tree.tres_weights["gres/kv_page"] == \
            pytest.approx(ps * ctrl.tree.tres_weights["gres/kv_token"])
    # an operator's explicit override survives
    tree = FairShareTree(tres_weights={"gres/kv_page": 1.0})
    ctrl = AdmissionController(tree=tree)
    DecodeEngine(cfg, params, num_slots=1, cache_len=64, admission=ctrl,
                 kv_page_size=8)
    assert tree.tres_weights["gres/kv_page"] == 1.0


def test_kv_pages_grp_tres_caps_tenant_residency(tiny_model):
    """GrpTRES {"kv_pages": N} bounds one tenant's concurrent HBM pages:
    with a 4-page cap and ~5-page requests (est: prompt+max_new), only
    one runs at a time even with slots to spare."""
    cfg, params = tiny_model
    qos_table = {"normal": QOS("normal", priority=500,
                               grp_tres={"kv_pages": 4})}
    ctrl = AdmissionController(qos_table=qos_table)
    eng = DecodeEngine(cfg, params, num_slots=4, cache_len=64,
                       admission=ctrl, decode_chunk=4, kv_page_size=8)
    reqs = _reqs(cfg, n=3, max_new=8, plen=16, tenant="capped")
    for r in reqs:
        eng.submit(r)
    assert all(r._est_pages == pages_for(16 + 8 + 1, 8) for r in reqs)
    peak = 0
    for _ in range(200):
        n = eng.step()
        peak = max(peak, eng.active())
        if n == 0:
            break
    assert all(r.done for r in reqs)
    assert peak == 1                       # cap serialized the tenant


# ------------------------------------------------------ sacctmgr modify ----

def _mini_cluster():
    from repro.cluster import Cluster, Node, Partition
    nodes = [Node(name=f"n{i:02d}", cpus=8, mem_mb=8192,
                  gres={"tpu": 4}, coord=(0, i)) for i in range(2)]
    parts = [Partition(name="gpu", nodes=tuple(n.name for n in nodes),
                       default=True)]
    c = Cluster(nodes, parts)
    c.fairshare.add_account("prod", shares=10)
    c.fairshare.add_account("research", shares=1)
    return c


def test_sacctmgr_modify_account_live_shares():
    from repro.cluster import commands
    c = _mini_cluster()
    before = c.fairshare.norm_shares("research")
    out = commands.sacctmgr_modify_account(c, "research", fairshare=30)
    assert "Fairshare=30" in out
    assert c.fairshare.norm_shares("research") > before
    # sshare reflects the edit on the next pass, no restart
    line = next(ln for ln in commands.sshare(c).splitlines()
                if "research" in ln)
    assert line.split()[1] == "30"


def test_sacctmgr_modify_account_validates():
    c = _mini_cluster()
    with pytest.raises(AssertionError):
        c.fairshare.modify_account("nope", shares=2)
    with pytest.raises(AssertionError):
        c.fairshare.modify_account("root", shares=2)
    c.fairshare.add_account("team", parent="prod")
    with pytest.raises(AssertionError):   # cycle: prod under its own child
        c.fairshare.modify_account("prod", parent="team")
    c.fairshare.modify_account("team", parent="research")
    assert c.fairshare.accounts["team"].parent == "research"


def test_sacctmgr_modify_qos_live():
    from repro.cluster import commands
    c = _mini_cluster()
    out = commands.sacctmgr_modify_qos(
        c, "scavenger", priority=42, grp_tres={"gres/tpu": 2})
    assert "priority=42" in out
    q = c.qos_table["scavenger"]
    assert q.priority == 42 and q.grp_tres == {"gres/tpu": 2}
    assert q.usage_factor == 0.25          # untouched fields survive
    assert "42" in commands.sacctmgr_show_qos(c)


def test_sshare_tres_column_reports_kv_pages():
    from repro.cluster import commands
    c = _mini_cluster()
    c.fairshare.charge_tres("research", {"gres/kv_page": 12.0})
    out = commands.sshare(c, tres=True)
    assert "TRESUsage" in out
    line = next(ln for ln in out.splitlines() if "research" in ln)
    assert "gres/kv_page=12" in line
    # default format unchanged (golden tests elsewhere)
    assert "TRESUsage" not in commands.sshare(c)


def test_tres_usage_decays_and_snapshots():
    t = FairShareTree(half_life_s=100.0)
    t.charge_tres("acct", {"gres/kv_page": 8.0, "tokens": 4.0})
    t.decay_to(100.0)                      # one half-life
    assert t.tres_usage_of("acct")["gres/kv_page"] == pytest.approx(4.0)
    restored = FairShareTree.restore(t.snapshot())
    assert restored.tres_usage_of("acct") == t.tres_usage_of("acct")


def test_tres_usage_reports_raw_consumption_not_billing_discount():
    """usage_factor is a billing break (scavenger pays 0.25x) but the
    per-key breakdown an auditor reads must show what was actually
    held."""
    t = FairShareTree(tres_weights={"gres/kv_page": 0.016})
    t.charge_tres("scav", {"gres/kv_page": 100.0}, usage_factor=0.25)
    assert t.usage["scav"] == pytest.approx(100.0 * 0.016 * 0.25)
    assert t.tres_usage_of("scav")["gres/kv_page"] == pytest.approx(100.0)


def test_kv_pages_cap_is_worst_case_reservation(tiny_model):
    """Decode-time growth cannot breach the GrpTRES cap: the hold
    reserves each request's worst-case footprint for its whole
    residency, so at cap 8 only two est-4 requests ever run at once —
    even while their actual allocations are still small."""
    cfg, params = tiny_model
    qos_table = {"normal": QOS("normal", priority=500,
                               grp_tres={"kv_pages": 8})}
    ctrl = AdmissionController(qos_table=qos_table)
    eng = DecodeEngine(cfg, params, num_slots=4, cache_len=64,
                       admission=ctrl, decode_chunk=2, kv_page_size=8)
    reqs = _reqs(cfg, n=3, max_new=14, plen=16, tenant="capped")  # est 4
    for r in reqs:
        eng.submit(r)
    peak_active = peak_hold = 0
    for _ in range(300):
        n = eng.step()
        peak_active = max(peak_active, eng.active())
        peak_hold = max(peak_hold, ctrl.tenants["capped"].pages_held)
        if n == 0:
            break
    assert all(r.done for r in reqs)
    assert peak_active == 2 and peak_hold <= 8


# ----------------------------------------------------------- serve CLI ----

def test_use_pallas_falls_back_on_cpu(capsys):
    from repro.launch.serve import resolve_use_pallas
    assert resolve_use_pallas(False, "cpu") is False
    assert resolve_use_pallas(False, "tpu") is False
    assert resolve_use_pallas(True, "tpu") is True
    assert resolve_use_pallas(True, "cpu") is False
    assert "falling back" in capsys.readouterr().out
