"""Substrate tests: data pipeline, checkpoint store, optimizer, serving
engine — the paper's §5.2.4 workload pieces."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_reduced_config
from repro.configs.base import InputShape
from repro.data import DataConfig, PackedStream, PrefetchLoader
from repro import checkpoint as ckpt
from repro.models import init_params, make_batch
from repro.optim import OptimizerConfig, adamw_update, init_opt_state, \
    lr_schedule


# ----------------------------------------------------------------- data ----

def test_stream_deterministic_across_instances():
    cfg = DataConfig(vocab_size=512, seq_len=128, global_batch=4, seed=7)
    a = [PackedStream(cfg).next_batch() for _ in range(1)][0]
    b = [PackedStream(cfg).next_batch() for _ in range(1)][0]
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_stream_seeds_differ():
    c1 = DataConfig(vocab_size=512, seq_len=128, global_batch=4, seed=1)
    c2 = DataConfig(vocab_size=512, seq_len=128, global_batch=4, seed=2)
    assert not np.array_equal(PackedStream(c1).next_batch()["tokens"],
                              PackedStream(c2).next_batch()["tokens"])


def test_prefetch_loader_matches_stream():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=2, seed=3)
    direct = PackedStream(cfg)
    want = [direct.next_batch()["tokens"] for _ in range(4)]
    loader = PrefetchLoader(PackedStream(cfg), depth=2)
    got = [next(loader)["tokens"] for _ in range(4)]
    loader.close()
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_loss_mask_is_all_ones_for_lm():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=2, seed=0)
    b = PackedStream(cfg).next_batch()
    assert b["loss_mask"].shape == (2, 64)
    assert set(np.unique(b["loss_mask"])) <= {0.0, 1.0}


# ------------------------------------------------------------ checkpoint ----

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 10, tree)
    assert ckpt.latest_step(str(tmp_path)) == 10
    got, ds = ckpt.restore(str(tmp_path), tree)
    assert ds is None
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_keeps_latest_and_gc(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    steps = sorted(int(d.split("_")[-1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_restores_specific_step(tmp_path):
    for step in (1, 2):
        ckpt.save(str(tmp_path), step,
                  {"w": jnp.full((2,), float(step))})
    got, _ = ckpt.restore(str(tmp_path), {"w": jnp.zeros((2,))}, step=1)
    np.testing.assert_array_equal(np.asarray(got["w"]), [1.0, 1.0])


def test_checkpoint_data_state_roundtrip(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    ds = {"doc": np.int64(42), "buf": np.arange(5)}
    ckpt.save(str(tmp_path), 1, tree, data_state=ds)
    _, got = ckpt.restore(str(tmp_path), tree)
    assert int(got["doc"]) == 42
    np.testing.assert_array_equal(got["buf"], np.arange(5))


# -------------------------------------------------------------- optimizer ----

def test_lr_schedule_warmup_and_decay():
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    assert float(lr_schedule(jnp.asarray(0), opt)) < 1e-4
    np.testing.assert_allclose(float(lr_schedule(jnp.asarray(10), opt)),
                               1e-3, rtol=1e-6)
    np.testing.assert_allclose(float(lr_schedule(jnp.asarray(100), opt)),
                               1e-4, rtol=1e-5)      # cosine floor


def test_adamw_matches_manual_reference():
    opt = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10_000,
                          b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.1,
                          clip_norm=1e9)
    p = {"w": jnp.asarray([[1.0, -2.0]])}            # 2-D => weight decay
    g = {"w": jnp.asarray([[0.5, 0.5]])}
    state = init_opt_state(p, opt)
    new_p, new_state, metrics = adamw_update(p, g, state, opt)

    # manual AdamW, step 1 (bias-corrected)
    lr = float(lr_schedule(jnp.asarray(1), opt))
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.001 * 0.25 / (1 - 0.999)
    want = np.asarray([[1.0, -2.0]]) - lr * (
        m / (np.sqrt(v) + 1e-8) + 0.1 * np.asarray([[1.0, -2.0]]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_state["step"]) == 1


def test_grad_clip_bounds_update():
    opt = OptimizerConfig(peak_lr=1.0, warmup_steps=0, decay_steps=100,
                          clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.asarray([30.0, 40.0, 0.0])}     # norm 50 -> scaled by 1/50
    state = init_opt_state(p, opt)
    _, _, metrics = adamw_update(p, g, state, opt)
    np.testing.assert_allclose(float(metrics["grad_norm"]), 50.0, rtol=1e-5)


def test_loss_decreases_over_short_run(cpu_mesh):
    """§5.2.4 acceptance: the training job actually learns."""
    from repro.training import make_train_step
    cfg = get_reduced_config("stablelm-3b")
    opt = OptimizerConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=200)
    run = RunConfig(strategy="dp", microbatches=1, remat="none")
    step = make_train_step(cfg, run, cpu_mesh, opt)
    params = init_params(cfg, 0)
    state = init_opt_state(params, opt)
    shape = InputShape("t", 64, 4, "train")
    from repro.data import DataConfig, PackedStream
    stream = PackedStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                     global_batch=4, seed=0))
    losses = []
    for _ in range(24):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    # synthetic-LM signal is mostly unigram stats: expect a steady, modest
    # drop (measured ~0.23 over 24 steps at this lr on jax 0.4 CPU; the
    # first dozen steps are still inside warmup noise)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.08, losses
