"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracles in kernels/ref.py (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import attention_ref, ssd_ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


ATTN_CASES = [
    # (B, S, H, K, Dh, window, block)
    (2, 128, 4, 2, 64, None, 64),
    (1, 256, 8, 8, 64, None, 128),
    (2, 128, 4, 1, 32, 64, 64),
    (1, 512, 4, 2, 128, 128, 128),
    (1, 64, 2, 2, 16, None, 64),      # single-block path
    (2, 96, 3, 1, 32, None, 32),      # non-pow2 heads
]


@pytest.mark.parametrize("B,S,H,K,Dh,window,block", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(B, S, H, K, Dh, window, block, dtype):
    q = _rand((B, S, H, Dh), dtype)
    k = _rand((B, S, K, Dh), dtype)
    v = _rand((B, S, K, Dh), dtype)
    out = ops.flash_attention(q, k, v, window=window, block_q=block,
                              block_k=block, interpret=True)
    ref = attention_ref(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


SSD_CASES = [
    # (B, S, H, P, N, chunk)
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
    (2, 64, 1, 16, 8, 64),            # single chunk
    (1, 96, 3, 32, 128, 32),          # big state
]


@pytest.mark.parametrize("B,S,H,P,N,chunk", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_oracle(B, S, H, P, N, chunk, dtype):
    x = _rand((B, S, H, P), dtype)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, (H,)), jnp.float32)
    Bm = _rand((B, S, N), dtype)
    Cm = _rand((B, S, N), dtype)
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


def test_model_chunked_ssd_matches_oracle():
    """The model's own chunked SSD (models.ssm.ssd_chunked) vs naive scan."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 130, 4, 32, 16     # deliberately not chunk-aligned
    x = _rand((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, (H,)), jnp.float32)
    Bm = _rand((B, S, N), jnp.float32)
    Cm = _rand((B, S, N), jnp.float32)
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_blockwise_attention_matches_oracle():
    """The model's q-block-chunked attention vs the naive oracle."""
    from repro.configs import get_reduced_config
    from repro.models.attention import causal_attention
    import dataclasses
    cfg = dataclasses.replace(get_reduced_config("qwen2-7b"),
                              sliding_window=48)
    B, S, H, K, Dh = 2, 128, 4, 2, 64
    q = _rand((B, S, H, Dh), jnp.float32)
    k = _rand((B, S, K, Dh), jnp.float32)
    v = _rand((B, S, K, Dh), jnp.float32)
    out = causal_attention(q, k, v, cfg, q_block=32)
    out_unrolled = causal_attention(q, k, v, cfg, q_block=32, unroll=True)
    ref = attention_ref(q, k, v, window=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out_unrolled), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_pallas_path_through_model_matches_pure():
    import dataclasses
    from repro.configs import get_reduced_config, RunConfig
    from repro.configs.base import InputShape
    from repro.models import init_params, loss_fn, make_batch
    for arch in ("qwen2-7b", "mamba2-780m"):
        cfg = dataclasses.replace(get_reduced_config(arch), dtype="float32")
        params = init_params(cfg, 0)
        batch = make_batch(cfg, InputShape("s", 64, 2, "train"), 0)
        l0, _ = loss_fn(params, batch, cfg,
                        RunConfig(remat="none", use_pallas=False))
        l1, _ = loss_fn(params, batch, cfg,
                        RunConfig(remat="none", use_pallas=True))
        assert abs(float(l0) - float(l1)) < 1e-4, arch
