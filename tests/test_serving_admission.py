"""Multi-tenant serving: the shared tenancy core driving admission.

The acceptance properties of the shared policy layer:

* `repro.policy` is engine-agnostic — no imports from `repro.cluster` or
  `repro.serving`, and the `cluster.fairshare`/`cluster.qos` shims
  re-export the same objects;
* two tenants with 10:1 shares under sustained load converge to a
  10:1 ± 15% generated-token ratio;
* QOS preemption evicts exactly one scavenger slot per blocked high
  request, and the victim resumes with its partial output retained;
* batch and serving usage land in the one shared ledger (`sshare`
  reflects both).
"""
import itertools
import pathlib

import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.monitoring import MetricsRegistry
from repro.monitoring.metrics import (
    METRIC_SERVE_PREEMPTIONS, METRIC_SERVE_TENANT_TOKENS,
)
from repro.policy import FairShareTree, QOS, default_qos_table
from repro.serving import AdmissionController, DecodeEngine, Request


def _req(rid, tenant="default", qos="normal", plen=8, max_new=4, vocab=32,
         seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return Request(rid=rid, prompt=rng.integers(0, vocab, plen).astype(
        np.int32), max_new_tokens=max_new, tenant=tenant, qos=qos)


# -------------------------------------------------------- package layering ----

def test_policy_package_is_engine_agnostic():
    """Dependency arrow points inward only: repro.policy must not import
    the execution engines it serves."""
    import re

    import repro.policy
    pkg = pathlib.Path(repro.policy.__file__).parent
    forbidden = re.compile(
        r"^\s*(?:from\s+repro\.(?:cluster|serving)\b"
        r"|import\s+repro\.(?:cluster|serving)\b)", re.M)
    for src_file in sorted(pkg.glob("*.py")):
        hit = forbidden.search(src_file.read_text())
        assert hit is None, (src_file, hit and hit.group(0))


def test_cluster_shims_reexport_policy():
    """PR-1 import paths keep working and alias the policy objects."""
    import repro.policy as P
    from repro.cluster import fairshare as shim_fs
    from repro.cluster import qos as shim_qos
    assert shim_fs.FairShareTree is P.FairShareTree
    assert shim_fs.MultifactorPriority is P.MultifactorPriority
    assert shim_fs.PriorityWeights is P.PriorityWeights
    assert shim_fs.DEFAULT_TRES_WEIGHTS is P.DEFAULT_TRES_WEIGHTS
    assert shim_qos.QOS is P.QOS
    assert shim_qos.default_qos_table is P.default_qos_table
    assert shim_qos.job_tres is P.job_tres


# ---------------------------------------------------- admission controller ----

def test_admission_fifo_within_tenant_and_auto_register():
    ctrl = AdmissionController()
    a1, a2 = _req(1, tenant="newbie"), _req(2, tenant="newbie")
    ctrl.submit(a1)
    ctrl.submit(a2)
    assert "newbie" in ctrl.tenants            # lenient auto-association
    assert "newbie" in ctrl.tree.accounts      # and in the shared ledger
    assert ctrl.next_request() is a1
    assert ctrl.next_request() is a2
    assert ctrl.next_request() is None


def test_grp_tres_slot_cap_holds_tenant():
    """A QOS GrpTRES cap of 1 slot keeps a tenant to one concurrent
    decode slot no matter how deep its queue is."""
    table = default_qos_table()
    table["normal"] = QOS("normal", priority=500, grp_tres={"slots": 1})
    ctrl = AdmissionController(qos_table=table)
    reqs = [_req(i, tenant="capped") for i in range(3)]
    for r in reqs:
        ctrl.submit(r)
    assert ctrl.next_request() is reqs[0]
    assert ctrl.next_request() is None         # at the cap, queue non-empty
    assert ctrl.pending() == 2
    ctrl.release(reqs[0])
    assert ctrl.next_request() is reqs[1]


def test_slot_cap_is_per_qos_like_batch_grp_tres():
    """GrpTRES caps are per-(account, QOS): slots held through `high` must
    not count against the same tenant's `scavenger` cap."""
    table = default_qos_table()
    table["scavenger"] = QOS("scavenger", priority=0,
                             grp_tres={"slots": 1})
    ctrl = AdmissionController(qos_table=table)
    highs = [_req(i, tenant="t", qos="high") for i in range(2)]
    scav = _req(2, tenant="t", qos="scavenger")
    for r in highs:
        ctrl.submit(r)
    ctrl.submit(scav)
    assert ctrl.next_request() is highs[0]     # high is uncapped
    assert ctrl.next_request() is highs[1]
    assert ctrl.next_request() is scav         # 2 high slots held, 0 scav


def test_blocked_high_preempts_even_from_low_fairshare_tenant():
    """A hog tenant's high request must still preempt scavenger slots even
    when a fresher tenant (whose head cannot preempt) outranks it for the
    next free slot."""
    ctrl = AdmissionController()
    ctrl.add_tenant("hog", shares=1)
    ctrl.add_tenant("fresh", shares=1)
    ctrl.tree.charge_tres("hog", {"tokens": 1000.0})   # hog's standing sinks
    running = [_req(0, tenant="third", qos="scavenger"),
               _req(1, tenant="third", qos="scavenger")]
    hi = _req(2, tenant="hog", qos="high")
    ctrl.submit(hi)
    ctrl.submit(_req(3, tenant="fresh", qos="scavenger"))
    # fresh outranks hog for a free slot, but its head can't preempt
    pick = ctrl.next_preempting(running)
    assert pick is not None
    req, victim = pick
    assert req is hi and victim in running


def test_admission_fairshare_converges_10_to_1():
    """The acceptance criterion: 10:1 shares under sustained saturating
    load from both tenants -> generated tokens converge to 10:1 ± 15%."""
    ctrl = AdmissionController()
    ctrl.add_tenant("big", shares=10)
    ctrl.add_tenant("small", shares=1)
    num_slots, max_new = 4, 4
    slots = [None] * num_slots
    tokens = {"big": 0, "small": 0}
    rid = itertools.count()

    def refill():
        for tenant in ("big", "small"):
            while ctrl.queued(tenant) < 4:
                ctrl.submit(_req(next(rid), tenant=tenant, max_new=max_new))

    refill()
    for _ in range(2000):
        for i in range(num_slots):
            if slots[i] is None:
                req = ctrl.next_request()
                if req is None:
                    break
                slots[i] = req
                ctrl.charge(req, kv_tokens=len(req.prompt))   # prefill rent
        for i in range(num_slots):
            req = slots[i]
            if req is None:
                continue
            req.output.append(0)
            tokens[req.tenant] += 1
            ctrl.charge(req, tokens=1,
                        kv_tokens=len(req.prompt) + len(req.output))
            if len(req.output) >= req.max_new_tokens:
                slots[i] = None
                ctrl.release(req)
        refill()
    ratio = tokens["big"] / tokens["small"]
    assert 10 / 1.15 <= ratio <= 10 * 1.15, (ratio, tokens)


# ------------------------------------------------------- engine integration ----

@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_reduced_config("stablelm-3b")
    return cfg, init_params(cfg, 0)


def test_qos_preemption_evicts_exactly_one_scavenger(tiny_model):
    """One blocked high request -> exactly one scavenger slot evicted; the
    victim requeues with its partial output retained and finishes with the
    same tokens an uninterrupted run produces."""
    cfg, params = tiny_model
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(3)]

    ctrl = AdmissionController()
    ctrl.add_tenant("research", shares=1)
    ctrl.add_tenant("prod", shares=10)
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       admission=ctrl)
    scavs = [Request(rid=i, prompt=prompts[i], max_new_tokens=16,
                     tenant="research", qos="scavenger") for i in range(2)]
    for r in scavs:
        eng.submit(r)
    for _ in range(4):                         # both running, some progress
        eng.step()
    assert all(len(r.output) >= 4 and not r.done for r in scavs)
    partial = {r.rid: list(r.output) for r in scavs}

    hi = Request(rid=2, prompt=prompts[2], max_new_tokens=4,
                 tenant="prod", qos="high")
    eng.submit(hi)
    eng.step()
    assert eng.metrics.counter(METRIC_SERVE_PREEMPTIONS).value() == 1
    evicted = [r for r in scavs if r.preemptions == 1]
    assert len(evicted) == 1                   # exactly one slot, not both
    victim = evicted[0]
    assert not victim.done
    assert victim.output[:len(partial[victim.rid])] == partial[victim.rid]

    eng.run_to_completion()
    assert hi.done and all(r.done for r in scavs)
    assert len(victim.output) == 16

    # resume correctness: the interrupted run must equal a solo greedy run
    solo = Request(rid=9, prompt=victim.prompt, max_new_tokens=16)
    ref = DecodeEngine(cfg, params, num_slots=1, cache_len=64)
    ref.submit(solo)
    ref.run_to_completion()
    assert victim.output == solo.output


def test_two_blocked_high_requests_evict_two_scavengers(tiny_model):
    cfg, params = tiny_model
    rng = np.random.default_rng(8)
    ctrl = AdmissionController()
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       admission=ctrl)
    scavs = [Request(rid=i,
                     prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                         np.int32),
                     max_new_tokens=12, tenant="research", qos="scavenger")
             for i in range(2)]
    for r in scavs:
        eng.submit(r)
    eng.step()
    highs = [Request(rid=10 + i,
                     prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                         np.int32),
                     max_new_tokens=3, tenant="prod", qos="high")
             for i in range(2)]
    for r in highs:
        eng.submit(r)
    eng.step()
    assert eng.metrics.counter(METRIC_SERVE_PREEMPTIONS).value() == 2
    assert sorted(r.preemptions for r in scavs) == [1, 1]
    eng.run_to_completion()
    assert all(r.done for r in scavs + highs)


def test_batch_and_serving_share_one_ledger(tiny_model):
    """A tenant's batch jobs and served tokens charge the same account in
    the same tree — one sshare call reports both."""
    from repro.cluster import (
        Cluster, Node, Partition, ResourceRequest, commands,
    )
    cfg, params = tiny_model
    tree = FairShareTree()
    nodes = [Node(name="n00", cpus=16, mem_mb=65536, gres={"tpu": 4},
                  coord=(0, 0))]
    cluster = Cluster(nodes, [Partition(name="p", nodes=("n00",),
                                        default=True)], fairshare=tree)
    tree.add_account("team", shares=4)
    cluster.submit("batch", ResourceRequest(nodes=1, gres_per_node={"tpu": 4},
                                            time_limit_s=3600),
                   account="team", run_time_s=100.0)
    cluster.run()
    batch_usage = tree.usage["team"]
    assert batch_usage > 0

    ctrl = AdmissionController(tree=tree)      # same ledger, same account
    ctrl.add_tenant("team", shares=4)
    eng = DecodeEngine(cfg, params, num_slots=1, cache_len=64,
                       admission=ctrl)
    eng.submit(_req(0, tenant="team", plen=8, max_new=6,
                    vocab=cfg.vocab_size))
    eng.run_to_completion()
    combined = tree.usage["team"]
    assert combined > batch_usage              # serving charged on top

    out = commands.sshare(cluster)
    team_row = next(ln for ln in out.splitlines() if "team" in ln)
    assert f"{combined:.0f}" in team_row       # sshare reflects both


def test_per_tenant_serve_metrics_exported(tiny_model):
    cfg, params = tiny_model
    metrics = MetricsRegistry()
    ctrl = AdmissionController()
    ctrl.add_tenant("alice", shares=8)
    ctrl.add_tenant("bob", shares=1)
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       metrics=metrics, admission=ctrl)
    for i, tenant in enumerate(["alice", "bob"]):
        eng.submit(_req(i, tenant=tenant, plen=6, max_new=3,
                        vocab=cfg.vocab_size))
    eng.run_to_completion()
    # decode-step tokens (the prefill-produced first token is not counted,
    # matching the unlabeled serve_tokens_generated series)
    tok = metrics.counter(METRIC_SERVE_TENANT_TOKENS)
    assert tok.value(tenant="alice") == 2
    assert tok.value(tenant="bob") == 2
    text = metrics.expose()
    assert 'serve_tenant_tokens_generated{tenant="alice"}' in text
    assert 'serve_tenant_requests_admitted{tenant="bob"}' in text


# ------------------------------------------------- radix-aware tie-break ----

def test_radix_tie_break_prefers_cached_prompts():
    """When two tenants' multifactor priorities tie *exactly*, the head
    whose prompt would hit the radix prefix index is admitted first (its
    prefill is mostly cached pages, so admitting it is nearly free and
    keeps those pages hot).  Probe unset degrades to pure FIFO; a real
    priority gap still dominates the tie-break bit."""
    # no probe (no prefix cache): FIFO within the tie
    ctrl = AdmissionController()
    cold, hot = _req(1, tenant="a"), _req(2, tenant="b")
    ctrl.submit(cold)
    ctrl.submit(hot)
    assert ctrl.next_request() is cold

    # probe wired: the later-arriving cached prompt jumps the tie
    ctrl = AdmissionController()
    cold, hot = _req(1, tenant="a"), _req(2, tenant="b")
    ctrl.radix_probe = lambda r: r is hot
    ctrl.submit(cold)
    ctrl.submit(hot)
    assert ctrl.next_request() is hot
    assert ctrl.next_request() is cold

    # fair-share still dominates: burned usage loses despite the hit
    ctrl = AdmissionController()
    ctrl.add_tenant("a", shares=1)
    ctrl.add_tenant("b", shares=1)
    cold, hot = _req(1, tenant="a"), _req(2, tenant="b")
    ctrl.radix_probe = lambda r: r is hot
    ctrl.tree.charge_tres("b", {"tokens": 10_000.0})
    ctrl.submit(cold)
    ctrl.submit(hot)
    assert ctrl.next_request() is cold


def test_engine_wires_radix_probe_into_admission(tiny_model):
    """The prefix-cache engine installs the probe on its controller; a
    dense engine leaves the controller in FIFO-tie-break mode."""
    cfg, params = tiny_model
    dense = DecodeEngine(cfg, params, num_slots=2, cache_len=64)
    assert dense.admission.radix_probe is None
    paged = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                         kv_page_size=8, prefix_cache=True)
    probe = paged.admission.radix_probe
    assert probe is not None
    rq = _req(0, plen=16, vocab=cfg.vocab_size)
    assert probe(rq) is False          # empty index: nothing to hit
    paged.submit(rq)
    paged.run_to_completion()          # prompt pages now in the index
    again = _req(1, plen=16, vocab=cfg.vocab_size, seed=0)
    assert probe(again) is True        # same seed -> same prompt
