"""Parallelism catalog tests (paper §2.4/§7): strategy -> sharding rules,
ZeRO stages, pipeline parallelism, and training-equivalence across
strategies on a tiny model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import RunConfig, get_reduced_config
from repro.configs.base import InputShape
from repro.core import sharding as shd
from repro.core.parallelism import STRATEGIES, get_strategy
from repro.core.zero import grad_shardings
from repro.models import init_params, make_batch
from repro.models.spec import ParamSpec
from repro.optim import OptimizerConfig, init_opt_state
from repro.training import make_train_step

SHAPE = InputShape("smoke", 64, 4, "train")


class FakeMesh:
    def __init__(self, d, m):
        self.shape = {"data": d, "model": m}
        self.axis_names = ("data", "model")


# ---------------------------------------------------------- spec mapping ----

def test_tp_shards_ffn_on_model():
    ps = ParamSpec((512, 2048), ("embed", "ffn"))
    spec = shd.param_pspec(ps, FakeMesh(4, 8), get_strategy("tp"))
    assert spec == P(None, "model")


def test_tp_respects_divisibility():
    ps = ParamSpec((512, 100), ("embed", "ffn"))     # 100 % 8 != 0
    spec = shd.param_pspec(ps, FakeMesh(4, 8), get_strategy("tp"))
    assert spec == P(None, None)


def test_fsdp_shards_largest_free_dim_on_data():
    ps = ParamSpec((512, 2048), ("embed", "ffn"))
    spec = shd.param_pspec(ps, FakeMesh(4, 8), get_strategy("fsdp"))
    assert spec == P(None, "data")                   # 2048 > 512


def test_fsdp_tp_composes():
    ps = ParamSpec((512, 2048), ("embed", "ffn"))
    spec = shd.param_pspec(ps, FakeMesh(4, 8), get_strategy("fsdp_tp"))
    assert spec == P("data", "model")                # ffn->model, embed->data


def test_dp_replicates_params():
    ps = ParamSpec((512, 2048), ("embed", "ffn"))
    spec = shd.param_pspec(ps, FakeMesh(4, 8), get_strategy("dp"))
    assert spec == P(None, None)


def test_expert_axis_takes_priority_over_ffn():
    ps = ParamSpec((16, 512, 1408), ("experts", "embed", "ffn"))
    spec = shd.param_pspec(ps, FakeMesh(4, 8), get_strategy("tp"))
    assert spec == P("model", None, None)            # expert parallelism


def test_experts_not_divisible_falls_through_to_ffn():
    ps = ParamSpec((60, 512, 1408), ("experts", "embed", "ffn"))
    spec = shd.param_pspec(ps, FakeMesh(4, 8), get_strategy("tp"))
    assert spec == P(None, None, "model")            # TP inside the expert


# ---------------------------------------------------------- zero stages ----

def _tiny_cfg():
    return get_reduced_config("starcoder2-3b")


def test_zero_stage_pspec_policy():
    """ZeRO stage semantics at the PartitionSpec level (data axis = 4):
    stage<3 keeps params off `data` (fsdp_override=False), stage 3 shards
    them; optimizer state is always data-sharded for stage>=1."""
    ps = ParamSpec((49152, 256), ("vocab", "embed"))       # an lm head
    mesh = FakeMesh(4, 1)
    strat = get_strategy("fsdp")
    off = shd.param_pspec(ps, mesh, strat, fsdp_override=False)
    on = shd.param_pspec(ps, mesh, strat, fsdp_override=True)
    assert all(s is None for s in off)
    assert "data" in tuple(on)


def test_zero_stage_gate_multidevice():
    """Full param/opt/grad pytree layouts per ZeRO stage, on a real 4-device
    mesh (subprocess with forced host devices — NamedSharding needs a real
    Mesh, and the divisibility gate needs data>1)."""
    import subprocess, sys, os
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from repro.configs import RunConfig, get_reduced_config
from repro.core import sharding as shd
from repro.core.zero import grad_shardings
from repro.launch.mesh import make_mesh

cfg = get_reduced_config("starcoder2-3b")
mesh = make_mesh(4, 1)
def lm_spec(tree):
    return tuple(jax.tree.leaves(tree["lm_head"])[0].spec)

for stage, param_sharded in ((1, False), (2, False), (3, True)):
    run = RunConfig(strategy="fsdp", zero_stage=stage)
    assert ("data" in lm_spec(shd.param_shardings(cfg, mesh, run))) \
        == param_sharded, stage
    assert "data" in lm_spec(shd.opt_shardings(cfg, mesh, run)), stage
    g = lm_spec(grad_shardings(cfg, mesh, run))
    assert ("data" in g) == (stage >= 2), (stage, g)
print("OK")
'''
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]


# ------------------------------------------------- strategy equivalence ----

@pytest.mark.parametrize("strategy", ["dp", "tp", "fsdp", "fsdp_tp"])
def test_all_strategies_one_device_same_loss(strategy, cpu_mesh):
    """On a 1-device mesh every strategy must produce identical numerics —
    sharding annotations change layout, never semantics."""
    cfg = _tiny_cfg()
    opt = OptimizerConfig(warmup_steps=2, decay_steps=10)
    run = RunConfig(strategy=strategy, microbatches=1, remat="none")
    step = make_train_step(cfg, run, cpu_mesh, opt)
    params = init_params(cfg, 0)
    state = init_opt_state(params, opt)
    batch = make_batch(cfg, SHAPE, 0)
    _, _, metrics = step(params, state, batch)
    if not hasattr(test_all_strategies_one_device_same_loss, "_ref"):
        test_all_strategies_one_device_same_loss._ref = float(metrics["loss"])
    np.testing.assert_allclose(
        float(metrics["loss"]),
        test_all_strategies_one_device_same_loss._ref, rtol=1e-5)


def test_microbatching_matches_full_batch(cpu_mesh):
    """grad accumulation over n microbatches == one full-batch step."""
    cfg = _tiny_cfg()
    opt = OptimizerConfig(warmup_steps=2, decay_steps=10)
    params = init_params(cfg, 0)
    batch = make_batch(cfg, SHAPE, 0)

    results = {}
    for n in (1, 2, 4):
        run = RunConfig(strategy="dp", microbatches=n, remat="none")
        step = make_train_step(cfg, run, cpu_mesh, opt)
        p_n = init_params(cfg, 0)            # fresh: the step donates buffers
        state = init_opt_state(p_n, opt)
        new_p, _, m = step(p_n, state, batch)
        results[n] = (float(m["loss"]),
                      np.asarray(jax.tree.leaves(new_p)[0]).copy())

    np.testing.assert_allclose(results[1][0], results[2][0], rtol=1e-5)
    np.testing.assert_allclose(results[1][0], results[4][0], rtol=1e-5)
    np.testing.assert_allclose(results[1][1], results[4][1],
                               rtol=2e-4, atol=2e-6)


def test_remat_does_not_change_numerics(cpu_mesh):
    cfg = _tiny_cfg()
    opt = OptimizerConfig(warmup_steps=2, decay_steps=10)
    batch = make_batch(cfg, SHAPE, 0)
    losses = []
    for remat in ("none", "layer"):
        run = RunConfig(strategy="dp", microbatches=1, remat=remat)
        step = make_train_step(cfg, run, cpu_mesh, opt)
        params = init_params(cfg, 0)
        state = init_opt_state(params, opt)
        _, _, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    np.testing.assert_allclose(losses[0], losses[1], rtol=1e-6)


# ------------------------------------------------------------- pipeline ----

def test_pipeline_parallel_matches_sequential():
    """shard_map pipeline over a 'pipe' axis == running stages in sequence."""
    from repro.core.pipeline import (
        make_pipeline_mesh, pipeline_apply, split_stages,
    )
    n_stages, n_micro, d = 1, 4, 16     # 1 device => 1 stage (CPU container)
    rng = np.random.default_rng(0)
    L = 4
    w = jnp.asarray(rng.standard_normal((L, d, d)) * 0.3, jnp.float32)
    # x_micro: (n_micro, mb, d)
    x = jnp.asarray(rng.standard_normal((n_micro, 2, d)), jnp.float32)

    def stage_fn(params, h):
        for i in range(params.shape[0]):
            h = jnp.tanh(h @ params[i])
        return h

    mesh = make_pipeline_mesh(n_stages)
    y = pipeline_apply(stage_fn, w, x, mesh)
    ref = jnp.stack([stage_fn(w, x[i]) for i in range(n_micro)])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_pipeline_two_stages_multidevice():
    """2 pipeline stages x 4 microbatches over ppermute == sequential run
    (needs 2 devices -> subprocess with forced host devices)."""
    import subprocess, sys, os
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.core.pipeline import make_pipeline_mesh, pipeline_apply

rng = np.random.default_rng(0)
L, d, n_micro = 4, 16, 4
w = jnp.asarray(rng.standard_normal((L, d, d)) * 0.3, jnp.float32)
x = jnp.asarray(rng.standard_normal((n_micro, 2, d)), jnp.float32)

def stage_fn(params, h):
    for i in range(params.shape[0]):
        h = jnp.tanh(h @ params[i])
    return h

mesh = make_pipeline_mesh(2)
y = pipeline_apply(stage_fn, w, x, mesh)
ref = jnp.stack([stage_fn(w, x[i]) for i in range(n_micro)])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                           atol=1e-6)
# and it differentiates end-to-end (training through the pipeline)
def loss(w):
    return jnp.sum(pipeline_apply(stage_fn, w, x, mesh) ** 2)
g = jax.grad(loss)(w)
def loss_ref(w):
    return jnp.sum(jnp.stack([stage_fn(w, x[i]) for i in range(n_micro)])**2)
g_ref = jax.grad(loss_ref)(w)
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4,
                           atol=1e-5)
print("OK")
'''
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]


# ----------------------------------------------------- activation rules ----

def test_activation_rules_pin_batch_and_model():
    cfg = _tiny_cfg()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run = RunConfig()
    rules = shd.make_activation_rules(cfg, mesh, run)
    sh = rules("hidden", (4, 64, 256))
    assert sh is not None
    assert rules("unknown-name", (4,)) is None


def test_constrain_is_identity_outside_context():
    from repro.core.actshard import constrain
    x = jnp.ones((2, 2))
    assert constrain(x, "hidden") is x


def test_seq_parallel_same_numerics(cpu_mesh):
    """seq_parallel only changes layout — 1-device numerics identical."""
    cfg = _tiny_cfg()
    opt = OptimizerConfig(warmup_steps=2, decay_steps=10)
    batch = make_batch(cfg, SHAPE, 0)
    losses = []
    for sp in (False, True):
        run = RunConfig(strategy="fsdp_tp", microbatches=1, remat="none",
                        seq_parallel=sp)
        step = make_train_step(cfg, run, cpu_mesh, opt)
        params = init_params(cfg, 0)
        state = init_opt_state(params, opt)
        _, _, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    np.testing.assert_allclose(losses[0], losses[1], rtol=1e-6)


def test_gather_bf16_close_numerics(cpu_mesh):
    """bf16 gathers quantize the weights once per step — the loss must stay
    within bf16 tolerance of the f32 path (params are bf16-cast at use in
    the f32 path too, so this is exact unless XLA reorders)."""
    cfg = _tiny_cfg()
    opt = OptimizerConfig(warmup_steps=2, decay_steps=10)
    batch = make_batch(cfg, SHAPE, 0)
    losses = []
    for gb in (False, True):
        run = RunConfig(strategy="fsdp_tp", microbatches=1, remat="none",
                        gather_bf16=gb)
        step = make_train_step(cfg, run, cpu_mesh, opt)
        params = init_params(cfg, 0)
        state = init_opt_state(params, opt)
        _, _, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    np.testing.assert_allclose(losses[0], losses[1], rtol=2e-2)
