"""Tensor-parallel serving: sharding the model and the paged KV pool over
a mesh must be a pure *layout* change — greedy decode token-for-token
identical to TP=1 in every engine mode — with divisibility falling back
to replication (never crashing), the pool budget tracked per shard, the
ring flash-decode kernel matching the reference wrapped-slot mask, and
the sdiag TP section reporting the plan.

TP >= 2 needs real devices and this process pinned the platform to one
at import, so those tests subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (same recipe as
``test_parallelism.py``).  Host-side pieces — plan resolution, the
two-level page table, the sharded allocator view, the ring kernel and
the sdiag golden text — run in-process.
"""
import dataclasses
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.paging import (
    NULL_PAGE, PageAllocator, ShardedAllocatorView, TwoLevelPageTable,
)
from repro.serving.tp import TPPlan, cache_pspec, plan_tp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_tp2(code: str) -> str:
    """Run ``code`` in a subprocess with 2 forced host devices."""
    src = ("import os\n"
           "os.environ['XLA_FLAGS'] = "
           "'--xla_force_host_platform_device_count=2'\n" + code)
    r = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True, env=dict(os.environ, PYTHONPATH="src"),
                       cwd=REPO)
    assert r.returncode == 0 and "OK" in r.stdout, \
        (r.stdout[-2000:], r.stderr[-2000:])
    return r.stdout


_PREAMBLE = r'''
import dataclasses
import numpy as np, jax
assert len(jax.devices()) == 2, jax.devices()
from repro.configs.stablelm_3b import reduced
from repro.models import init_params
from repro.serving import DecodeEngine
from repro.serving.engine import Request
from repro.launch.mesh import make_mesh

# float32: the cross-TP bit-identity guarantee is for f32 models (TP
# reductions run in f32); bf16 logits quantize coarsely enough that a
# reassociated sum can flip an exact near-tie argmax
cfg = dataclasses.replace(reduced(), dtype="float32")
params = init_params(cfg, 0)
mesh = make_mesh(1, 2)

def serve(mesh, cfg=cfg, params=params, run=None, **kw):
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                       mesh=mesh, run=run, **kw)
    reqs = [Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=6),
            Request(rid=1, prompt=np.arange(3, 17, dtype=np.int32),
                    max_new_tokens=5)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    return [r.output for r in reqs], eng
'''


# --------------------------------------------- TP=2 bit-identity (2 devs) ----

def test_tp2_bit_identity_dense_paged_budgeted():
    """Classic dense, paged, and token-budgeted engines produce the SAME
    greedy tokens on a (1,2) mesh as on one device — and the sharded pool
    view drains to zero on every shard when the requests finish."""
    _run_tp2(_PREAMBLE + r'''
from repro.models.paging import NULL_PAGE

for kw in [dict(), dict(kv_page_size=8),
           dict(kv_page_size=8, max_batch_tokens=16)]:
    base, _ = serve(None, **kw)
    tpd, eng = serve(mesh, **kw)
    assert base == tpd, (kw, base, tpd)
    assert eng.tp.active and eng.tp.tp == 2, eng.tp
    assert eng.tp.shard_attn and eng.tp.shard_mlp, eng.tp
    if eng.paging is not None:
        vec = eng.pool_view.in_use_vector()
        assert vec.shape == (2,) and (vec == 0).all(), vec
        assert (eng.page_tables == NULL_PAGE).all()
        st = eng.tp_stats()
        assert st["kv_pages_in_use"] == [0, 0], st
        assert st["kv_pages_total"] == eng.paging.usable_pages
print("OK")
''')


def test_tp2_bit_identity_prefix_and_speculative():
    """Prefix-cache (COW page sharing) and speculative (draft-and-verify
    scatter) engines stay bit-identical under TP=2, and a starved pool
    requeues without leaking pages on either shard."""
    _run_tp2(_PREAMBLE + r'''
for kw in [dict(kv_page_size=8, prefix_cache=True),
           dict(kv_page_size=8, speculate=2)]:
    base, _ = serve(None, **kw)
    tpd, eng = serve(mesh, **kw)
    assert base == tpd, (kw, base, tpd)

# tiny pool: 3 usable pages, requests need 2+3 -> the second starves
# until the first finishes; the requeue must free pages on EVERY shard
kw = dict(kv_page_size=8, kv_pages=4)
base, _ = serve(None, **kw)
tpd, eng = serve(mesh, **kw)
assert base == tpd, (base, tpd)
assert (eng.pool_view.in_use_vector() == 0).all()
print("OK")
''')


def test_tp2_pallas_and_nondivisible_fallback():
    """The Pallas flash-decode kernel runs per-shard inside shard_map
    (each shard sees K/tp KV heads, grid unchanged); head counts that do
    not divide the mesh axis replicate attention with a notice while the
    MLP still shards — output unchanged either way."""
    _run_tp2(_PREAMBLE + r'''
import dataclasses
from repro.configs.base import RunConfig

rc = RunConfig(remat="none", use_pallas=True)
base, _ = serve(None, run=rc, kv_page_size=8)
tpd, eng = serve(mesh, run=rc, kv_page_size=8)
assert base == tpd, (base, tpd)

cfg3 = dataclasses.replace(cfg, num_kv_heads=3, num_heads=3)
params3 = init_params(cfg3, 0)
base, _ = serve(None, cfg=cfg3, params=params3, kv_page_size=8)
tpd, eng = serve(mesh, cfg=cfg3, params=params3, kv_page_size=8)
assert base == tpd, (base, tpd)
assert eng.tp.shard_attn is False and eng.tp.shard_mlp is True
assert any("not divisible" in n for n in eng.tp.notices), eng.tp.notices
print("OK")
''')


# --------------------------------------------------- plan resolution ----

class _FakeMesh:
    """Just enough Mesh surface for plan_tp (no devices needed)."""

    def __init__(self, tp):
        self.shape = {"data": 1, "model": tp}
        self.axis_names = ("data", "model")
        self.devices = np.empty((1, tp), object)


def test_plan_tp_divisibility_policy():
    cfg = get_reduced_config("stablelm-3b")
    plan = plan_tp(cfg, _FakeMesh(2))
    assert plan.shard_attn and plan.shard_mlp and plan.active
    assert plan.notices == []
    # non-divisible heads: attention replicates, MLP still shards
    cfg3 = dataclasses.replace(cfg, num_kv_heads=3, num_heads=3)
    plan = plan_tp(cfg3, _FakeMesh(2))
    assert not plan.shard_attn and plan.shard_mlp and plan.active
    assert any("not divisible" in n for n in plan.notices)
    # nothing divides: fully replicated, inactive (engine skips shard_map)
    plan = plan_tp(cfg, _FakeMesh(5))
    assert not plan.shard_attn and not plan.shard_mlp and not plan.active
    assert any("nothing shardable" in n for n in plan.notices)
    # no mesh / tp=1: inert plan
    assert not plan_tp(cfg, None).active
    assert not plan_tp(cfg, _FakeMesh(1)).active


def test_plan_tp_psums_and_describe():
    cfg = get_reduced_config("stablelm-3b")          # 2 attn+mlp layers
    plan = plan_tp(cfg, _FakeMesh(2))
    assert plan.psums_per_token(cfg) == {"attn_out": 2, "mlp_out": 2}
    assert "attn(heads 4->2/shard" in plan.describe(cfg)
    plan3 = plan_tp(dataclasses.replace(cfg, num_kv_heads=3, num_heads=3),
                    _FakeMesh(2))
    assert plan3.psums_per_token(cfg) == {"attn_out": 0, "mlp_out": 2}


def test_cache_pspec_targets_kv_head_dim():
    cfg = get_reduced_config("stablelm-3b")
    plan = TPPlan(mesh=None, tp=2, shard_attn=True)
    spec = cache_pspec(plan, cfg)
    assert tuple(spec) == (None, None, None, "model", None)
    assert cache_pspec(TPPlan(mesh=None, tp=2, shard_attn=False), cfg) \
        == cache_pspec(TPPlan(mesh=None), None)


# ---------------------------------------------------- pool primitives ----

def test_sharded_allocator_view_vectors():
    alloc = PageAllocator(num_pages=5)               # 4 usable
    view = ShardedAllocatorView(alloc, shards=2)
    assert list(view.available_vector()) == [4, 4]
    pages = alloc.alloc(3)
    assert list(view.in_use_vector()) == [3, 3]
    assert view.min_available() == 1
    alloc.free(pages)
    assert list(view.in_use_vector()) == [0, 0]
    assert view.min_available() == 4


def test_two_level_page_table_round_trip():
    t = TwoLevelPageTable(num_slots=2, pages_per_seq=128, leaf_size=32)
    # a mapping crossing a leaf boundary lands intact
    t.set_range(0, 30, [7, 8, 9, 10])
    row = t.row(0)
    assert list(row[30:34]) == [7, 8, 9, 10]
    assert (np.delete(row, range(30, 34)) == NULL_PAGE).all()
    assert t.max_width() == 34
    # dense() at a narrow width truncates, at full width covers all slots
    t.set_range(1, 0, [3])
    d = t.dense(4)
    assert d.shape == (2, 4) and d[1, 0] == 3
    assert t.dense().shape == (2, 128)
    # host memory scales with leaves touched, not slots*pages_per_seq
    assert t.directory_leaves == 3                   # slot0: 2, slot1: 1
    t.clear(0)
    assert (t.row(0) == NULL_PAGE).all() and t.max_width() == 1
    assert t.directory_leaves == 1


def test_two_level_page_table_leaf_clamp():
    # leaf wider than the table clamps so one leaf covers the whole row
    t = TwoLevelPageTable(num_slots=1, pages_per_seq=4, leaf_size=32)
    assert t.leaf_size == 4
    t.set_range(0, 0, [1, 2, 3, 4])
    assert list(t.row(0)) == [1, 2, 3, 4]
    with pytest.raises(AssertionError):
        t.set_range(0, 3, [5, 6])                    # past pages_per_seq


# ------------------------------------------------- ring flash-decode ----

def _ring_decode_ref(q, k, v, pos, window):
    """Numpy oracle: wrapped-slot mask + softmax, one head at a time."""
    B, _, H, Dh = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    out = np.zeros((B, 1, H, Dh), np.float32)
    for b in range(B):
        slots = np.arange(S)
        slot_pos = pos[b] - ((pos[b] - slots) % S)
        mask = (slot_pos >= 0) & ((pos[b] - slot_pos) < window)
        for h in range(H):
            kh = h // G                              # grouped-query layout
            s = (q[b, 0, h] @ k[b, :, kh].T) * (Dh ** -0.5)
            s = np.where(mask, s, -1e30)
            p = np.exp(s - s.max())
            p = np.where(mask, p, 0.0)
            out[b, 0, h] = (p / p.sum()) @ v[b, :, kh]
    return out


def test_ring_flash_decode_matches_oracle():
    """``window`` turns the split-KV kernel's validity mask into the
    wrapped slot->position map; masking must match the reference ring
    math exactly (wrapped, partially-filled, and unwrapped positions)."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    B, H, K, Dh, window = 3, 4, 2, 16, 12
    S = window                                       # ring of min(len, win)
    q = rng.standard_normal((B, 1, H, Dh)).astype(np.float32)
    k = rng.standard_normal((B, S, K, Dh)).astype(np.float32)
    v = rng.standard_normal((B, S, K, Dh)).astype(np.float32)
    for pos in ([0, 5, 11], [13, 25, 31]):           # pre- and post-wrap
        pos = np.asarray(pos, np.int32)
        out = ops.flash_decode(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), jnp.asarray(pos),
                               block_k=4, interpret=True, window=window)
        ref = _ring_decode_ref(q, k, v, pos, window)
        np.testing.assert_allclose(np.asarray(out), ref,
                                   atol=2e-5, rtol=2e-5)


def test_windowed_engine_pallas_matches_reference():
    """End to end: a sliding-window config decodes through the ring
    kernel (cache wraps past ``window`` tokens) with the same greedy
    tokens as the jnp reference path."""
    from repro.configs.base import RunConfig
    from repro.models import init_params
    from repro.serving import DecodeEngine
    from repro.serving.engine import Request

    cfg = get_reduced_config("stablelm-3b").with_sliding_window(16)
    params = init_params(cfg, 0)

    def serve(use_pallas):
        eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64,
                           run=RunConfig(remat="none",
                                         use_pallas=use_pallas))
        reqs = [Request(rid=0, prompt=np.arange(1, 13, dtype=np.int32),
                        max_new_tokens=10),          # crosses the wrap
                Request(rid=1, prompt=np.arange(3, 9, dtype=np.int32),
                        max_new_tokens=6)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return [r.output for r in reqs]

    assert serve(False) == serve(True)


# ------------------------------------------------------ sdiag surface ----

def test_sdiag_tp_golden():
    from types import SimpleNamespace

    from repro.cluster import commands
    eng = SimpleNamespace(
        max_batch_tokens=None, speculate=0,
        tp=SimpleNamespace(tp=2),
        tp_stats=lambda: {
            "tp": 2, "active": True,
            "plan": "tp=2 attn(heads 4->2/shard, kv 4->2/shard), "
                    "mlp(ffn 512->256/shard)",
            "devices": ["TFRT_CPU_0", "TFRT_CPU_1"],
            "notices": ["d_ff=512 example notice"],
            "psums_per_token": {"attn_out": 2, "mlp_out": 2},
            "kv_pages_in_use": [4, 4], "kv_pages_total": 8})
    assert commands.sdiag(engine=eng) == "\n".join([
        "Tensor parallelism:",
        "\tPlan:             tp=2 attn(heads 4->2/shard, kv 4->2/shard), "
        "mlp(ffn 512->256/shard)",
        "\tDevices:          2 (TFRT_CPU_0, TFRT_CPU_1)",
        "\tPsums/token:      4 (attn_out 2, mlp_out 2)",
        "\tKV pool shard 0:  4/8 pages (50%)",
        "\tKV pool shard 1:  4/8 pages (50%)",
        "\tNotice:           d_ff=512 example notice",
    ])
    # tp=1 engines contribute no section
    off = SimpleNamespace(max_batch_tokens=None, speculate=0,
                          tp=SimpleNamespace(tp=1))
    assert commands.sdiag(engine=off) == "sdiag: nothing to report"
