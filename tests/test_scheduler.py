"""Scheduler behaviour tests — the paper's §3.2.3/§5 SLURM semantics:
priority order, FIFO, EASY/conservative backfill, dependencies, arrays,
time limits, node drain/requeue, HA failover, accounting."""
import pytest

from repro.cluster import (
    Cluster, Dependency, DependencyKind, Job, JobState, Node, NodeState,
    Partition, ResourceRequest,
)


def small_cluster(n_nodes=4, tpus=4, sched_mode="easy") -> Cluster:
    nodes = [Node(name=f"n{i:02d}", cpus=16, mem_mb=65536,
                  gres={"tpu": tpus}, coord=(0, i)) for i in range(n_nodes)]
    parts = [Partition(name="gpu", nodes=tuple(n.name for n in nodes),
                       default=True)]
    return Cluster(nodes, parts, sched_mode=sched_mode)


def req(nodes=1, tpu=4, time_s=3600, contiguous=True):
    return ResourceRequest(nodes=nodes, gres_per_node={"tpu": tpu},
                           cpus_per_node=1, mem_mb_per_node=1024,
                           time_limit_s=time_s, contiguous=contiguous)


# ------------------------------------------------------------ lifecycle ----

def test_submit_starts_immediately_when_free():
    c = small_cluster()
    (jid,) = c.submit("a", req(nodes=2), run_time_s=10)
    assert c.jobs[jid].state == JobState.RUNNING
    assert len(c.jobs[jid].nodes_alloc) == 2


def test_job_completes_and_releases_nodes():
    c = small_cluster()
    (jid,) = c.submit("a", req(nodes=4), run_time_s=10)
    assert c.tick()
    assert c.jobs[jid].state == JobState.COMPLETED
    assert c.clock == 10
    assert all(n.free_gres("tpu") == 4 for n in c.nodes.values())


def test_timeout_state():
    c = small_cluster()
    (jid,) = c.submit("a", req(time_s=5), run_time_s=50)
    c.run()
    assert c.jobs[jid].state == JobState.TIMEOUT
    assert c.clock == 5        # killed at the limit, not the natural end


def test_cancel_pending_and_running():
    c = small_cluster(n_nodes=1)
    (a,) = c.submit("a", req(), run_time_s=100)
    (b,) = c.submit("b", req(), run_time_s=100)      # queued behind a
    assert c.jobs[b].state == JobState.PENDING
    c.cancel(b)
    assert c.jobs[b].state == JobState.CANCELLED
    c.cancel(a)
    assert c.jobs[a].state == JobState.CANCELLED
    assert c.nodes["n00"].free_gres("tpu") == 4


def test_oversized_request_never_starts():
    c = small_cluster(n_nodes=2)
    (jid,) = c.submit("big", req(nodes=3), run_time_s=1)
    stuck = c.run()
    assert jid in stuck
    assert c.jobs[jid].state == JobState.PENDING


def test_time_limit_exceeds_partition_max():
    nodes = [Node(name="n0", cpus=16, mem_mb=65536, gres={"tpu": 4})]
    parts = [Partition(name="short", nodes=("n0",), default=True,
                       max_time_s=100)]
    c = Cluster(nodes, parts)
    with pytest.raises(ValueError):
        c.submit("a", req(time_s=1000))


# ------------------------------------------------------------- ordering ----

def test_priority_beats_fifo():
    c = small_cluster(n_nodes=1)
    (a,) = c.submit("a", req(), run_time_s=10)       # occupies the node
    (lo,) = c.submit("lo", req(), priority=1, run_time_s=10)
    (hi,) = c.submit("hi", req(), priority=9, run_time_s=10)
    c.run()
    assert c.jobs[hi].start_time < c.jobs[lo].start_time


def test_fifo_among_equal_priority():
    c = small_cluster(n_nodes=1)
    c.submit("a", req(), run_time_s=10)
    (b,) = c.submit("b", req(), run_time_s=10)
    (d,) = c.submit("d", req(), run_time_s=10)
    c.run()
    assert c.jobs[b].start_time < c.jobs[d].start_time


# ------------------------------------------------------------- backfill ----

def _backfill_scenario(mode):
    """head job needs 4 nodes (blocked); a short 1-node job can slip in."""
    c = small_cluster(n_nodes=4, sched_mode=mode)
    (long_,) = c.submit("long", req(nodes=2), run_time_s=100)
    (head,) = c.submit("head", req(nodes=4), priority=5, run_time_s=10)
    (short,) = c.submit("short", req(nodes=1, time_s=50), run_time_s=50)
    return c, long_, head, short


def test_easy_backfill_lets_short_job_through():
    c, long_, head, short = _backfill_scenario("easy")
    # short fits in the 2 free nodes and ends (t=50) before head's
    # reservation (t=100) => may start now
    assert c.jobs[short].state == JobState.RUNNING
    assert c.jobs[head].state == JobState.PENDING
    c.run()
    # head starts when long ends
    assert c.jobs[head].start_time == 100


def test_backfill_never_delays_reservation():
    c = small_cluster(n_nodes=4, sched_mode="easy")
    c.submit("long", req(nodes=2), run_time_s=100)
    (head,) = c.submit("head", req(nodes=4), priority=5, run_time_s=10)
    # would-be backfill running PAST the reservation on reserved nodes
    (bf,) = c.submit("bf", req(nodes=1, time_s=500), run_time_s=400)
    assert c.jobs[bf].state == JobState.PENDING   # blocked by the guard
    c.run()
    assert c.jobs[head].start_time == 100         # reservation honored


def test_fifo_mode_blocks_queue():
    c, long_, head, short = _backfill_scenario("fifo")
    assert c.jobs[short].state == JobState.PENDING
    c.run()
    # strict order: head at 100, then short
    assert c.jobs[head].start_time == 100
    assert c.jobs[short].start_time >= c.jobs[head].start_time


def test_conservative_reserves_for_all_blocked():
    c = small_cluster(n_nodes=4, sched_mode="conservative")
    c.submit("long", req(nodes=4), run_time_s=100)
    (b1,) = c.submit("b1", req(nodes=4), run_time_s=10)
    (b2,) = c.submit("b2", req(nodes=4), run_time_s=10)
    d = c.schedule()
    assert {r.job_id for r in d.reservations} == {b1, b2}


# ----------------------------------------------------------- contiguity ----

def test_tpu_contiguous_allocation_is_rectangle():
    """8 hosts in a 2x4 grid; a 4-host job must get a 1x4/4x1/2x2 tile."""
    nodes = [Node(name=f"n{r}{cl}", cpus=8, mem_mb=8192, gres={"tpu": 4},
                  coord=(r, cl)) for r in range(2) for cl in range(4)]
    parts = [Partition(name="p", nodes=tuple(n.name for n in nodes),
                       default=True)]
    c = Cluster(nodes, parts)
    (jid,) = c.submit("rect", req(nodes=4), run_time_s=1)
    alloc = c.jobs[jid].nodes_alloc
    coords = sorted(c.nodes[nm].coord for nm in alloc)
    rows = {r for r, _ in coords}
    cols = {cl for _, cl in coords}
    assert len(rows) * len(cols) == 4          # exact rectangle


def test_fragmented_grid_blocks_contiguous_job():
    nodes = [Node(name=f"n{i}", cpus=8, mem_mb=8192, gres={"tpu": 4},
                  coord=(0, i)) for i in range(4)]
    parts = [Partition(name="p", nodes=tuple(n.name for n in nodes),
                       default=True)]
    c = Cluster(nodes, parts)
    # occupy n1 => the free set {n0, n2, n3} has no 2-rectangle through n0
    c.submit("frag", ResourceRequest(nodes=1, gres_per_node={"tpu": 4}),
             run_time_s=100)  # takes n0 (first fit)
    c.submit("frag2", ResourceRequest(nodes=1, gres_per_node={"tpu": 4}),
             run_time_s=100)  # takes n1
    (jid,) = c.submit("rect3", req(nodes=3), run_time_s=1)
    # {n2,n3} free +nothing else: 3-node contiguous fails until release
    assert c.jobs[jid].state == JobState.PENDING
    c.run()
    assert c.jobs[jid].state == JobState.COMPLETED


# ---------------------------------------------------------- dependencies ----

def test_afterok_waits_then_runs():
    c = small_cluster(n_nodes=1)
    (a,) = c.submit("a", req(), run_time_s=10)
    (b,) = c.submit("b", req(), dependency=f"afterok:{a}", run_time_s=10)
    assert c.jobs[b].reason == "Dependency"
    c.run()
    assert c.jobs[b].state == JobState.COMPLETED
    assert c.jobs[b].start_time >= c.jobs[a].end_time


def test_afterok_on_failure_cancels():
    c = small_cluster(n_nodes=1)
    (a,) = c.submit("a", req(time_s=5), run_time_s=50)     # will TIMEOUT
    (b,) = c.submit("b", req(), dependency=f"afterok:{a}", run_time_s=10)
    c.run()
    assert c.jobs[a].state == JobState.TIMEOUT
    assert c.jobs[b].state == JobState.CANCELLED
    assert c.jobs[b].reason == "DependencyNeverSatisfied"


def test_afternotok_runs_only_on_failure():
    c = small_cluster(n_nodes=1)
    (a,) = c.submit("a", req(time_s=5), run_time_s=50)
    (fix,) = c.submit("fix", req(), dependency=f"afternotok:{a}",
                      run_time_s=10)
    c.run()
    assert c.jobs[fix].state == JobState.COMPLETED


def test_afterany_runs_either_way():
    c = small_cluster(n_nodes=1)
    (a,) = c.submit("a", req(), run_time_s=10)
    (b,) = c.submit("b", req(), dependency=f"afterany:{a}", run_time_s=10)
    c.run()
    assert c.jobs[b].state == JobState.COMPLETED


def test_dependency_parse_slurm_syntax():
    deps = Dependency.parse("afterok:12:13,afterany:14")
    assert deps == [
        Dependency(DependencyKind.AFTEROK, 12),
        Dependency(DependencyKind.AFTEROK, 13),
        Dependency(DependencyKind.AFTERANY, 14),
    ]


def test_unknown_dependency_rejected():
    c = small_cluster()
    with pytest.raises(ValueError):
        c.submit("x", req(), dependency="afterok:999")


# --------------------------------------------------------------- arrays ----

def test_job_array_members_run_serially_on_small_cluster():
    c = small_cluster(n_nodes=1)
    ids = c.submit("arr", req(), array=3, run_time_s=10)
    assert len(ids) == 3
    c.run()
    starts = sorted(c.jobs[j].start_time for j in ids)
    assert starts == [0, 10, 20]
    assert all(c.jobs[j].array_index == i for i, j in enumerate(ids))


# ------------------------------------------------------- drain / requeue ----

def test_node_down_requeues_job():
    c = small_cluster(n_nodes=2)
    (jid,) = c.submit("a", req(nodes=2), run_time_s=50)
    assert c.jobs[jid].state == JobState.RUNNING
    c.set_node_state("n00", NodeState.DOWN, "hw failure")
    assert c.jobs[jid].state == JobState.PENDING      # requeued
    c.set_node_state("n00", NodeState.IDLE)
    c.schedule()
    assert c.jobs[jid].state == JobState.RUNNING
    c.run()
    assert c.jobs[jid].state == JobState.COMPLETED


def test_drained_node_not_scheduled():
    c = small_cluster(n_nodes=2)
    c.set_node_state("n00", NodeState.DRAIN, "maintenance")
    (jid,) = c.submit("a", req(nodes=2), run_time_s=1)
    assert c.jobs[jid].state == JobState.PENDING
    c.set_node_state("n00", NodeState.IDLE)
    c.schedule()
    assert c.jobs[jid].state == JobState.RUNNING


# ------------------------------------------------------------------- HA ----

def test_ha_failover_preserves_all_state():
    c = small_cluster()
    (a,) = c.submit("a", req(nodes=2), run_time_s=30)
    (b,) = c.submit("b", req(nodes=4), run_time_s=10)    # queued
    c.tick()
    snap = c.snapshot()
    standby = Cluster.restore(snap)
    assert standby.clock == c.clock
    assert standby.jobs[a].state == c.jobs[a].state
    # the standby continues the workload to completion
    standby.run()
    assert standby.jobs[b].state == JobState.COMPLETED
    # and new submissions get fresh ids
    (nxt,) = standby.submit("c", req(), run_time_s=1)
    assert nxt > b


# ------------------------------------------------------------ accounting ----

def test_accounting_records_every_terminal_job():
    c = small_cluster()
    ids = []
    ids += c.submit("ok", req(), run_time_s=10)
    ids += c.submit("to", req(time_s=5), run_time_s=50)
    ids += c.submit("arr", req(), array=2, run_time_s=1)
    c.run()
    accounted = {r.job_id for r in c.accounting}
    assert accounted == set(ids)
    rec = {r.job_id: r for r in c.accounting}
    assert rec[ids[0]].state == "COMPLETED"
    assert rec[ids[0]].elapsed == 10
    assert rec[ids[1]].state == "TIMEOUT"
