"""Serving tests: prefill+decode consistency vs the training forward,
sliding-window ring cache, SSM recurrent decode, and the continuous-batching
engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_reduced_config
from repro.models import init_cache, init_params
from repro.models.model import decode_step, forward_train, prefill
from repro.serving import DecodeEngine, Request

RUN = RunConfig(strategy="dp", microbatches=1, remat="none")


def _greedy_reference(params, tokens, cfg, n_new):
    """Teacher-forced greedy continuation using only forward_train."""
    toks = list(np.asarray(tokens))
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        logits, _ = forward_train(params, batch, cfg, RUN)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


@pytest.mark.parametrize("arch_id", ["stablelm-3b", "mamba2-780m",
                                     "jamba-1.5-large-398b"])
def test_prefill_decode_matches_forward(arch_id):
    """KV/SSM-cache decode == teacher-forced forward, token for token."""
    cfg = get_reduced_config(arch_id)
    params = init_params(cfg, 0)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    n_new = 6

    ref = _greedy_reference(params, prompt, cfg, n_new)

    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                            cfg, RUN, cache_len=64)
    got = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    tok = jnp.asarray([[got[-1]]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = decode_step(params, cache, tok,
                                    jnp.asarray(pos, jnp.int32), cfg, RUN)
        nxt = int(jnp.argmax(logits[0, -1]))
        got.append(nxt)
        tok = jnp.asarray([[nxt]], jnp.int32)
        pos += 1
    assert got == ref, (arch_id, got, ref)


def test_sliding_window_ring_cache_decode():
    """With a window the ring cache must reproduce windowed attention
    exactly even after wrapping around."""
    cfg = dataclasses.replace(get_reduced_config("stablelm-3b"),
                              sliding_window=8)
    params = init_params(cfg, 0)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    n_new = 10                                # wraps the 8-slot ring

    ref = _greedy_reference(params, prompt, cfg, n_new)

    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                            cfg, RUN, cache_len=64)
    assert cache["layers"][0]["k"].shape[2] == 8   # ring has window slots
    got = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    tok = jnp.asarray([[got[-1]]], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = decode_step(params, cache, tok,
                                    jnp.asarray(pos, jnp.int32), cfg, RUN)
        nxt = int(jnp.argmax(logits[0, -1]))
        got.append(nxt)
        tok = jnp.asarray([[nxt]], jnp.int32)
        pos += 1
    assert got == ref, (got, ref)


def test_vector_positions_enable_mixed_depth_decode():
    """decode_step takes (B,) positions — slots at different depths."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(2)
    pa = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)

    # singleton decodes
    outs = {}
    for name, pr in (("a", pa), ("b", pb)):
        logits, cache = prefill(params, {"tokens": jnp.asarray(pr)[None]},
                                cfg, RUN, cache_len=32)
        tok = int(jnp.argmax(logits[0, -1]))
        logits, _ = decode_step(params, cache,
                                jnp.asarray([[tok]], jnp.int32),
                                jnp.asarray(len(pr), jnp.int32), cfg, RUN)
        outs[name] = int(jnp.argmax(logits[0, -1]))

    # batched mixed-depth decode
    cache = init_cache(cfg, 2, 32)
    for i, pr in enumerate((pa, pb)):
        _, c1 = prefill(params, {"tokens": jnp.asarray(pr)[None]}, cfg, RUN,
                        cache_len=32)
        cache = jax.tree.map(
            lambda b, o: jax.lax.dynamic_update_slice_in_dim(
                b, o.astype(b.dtype), i, axis=1), cache, c1)
    toks = []
    for pr in (pa, pb):
        logits, _ = prefill(params, {"tokens": jnp.asarray(pr)[None]}, cfg,
                            RUN, cache_len=32)
        toks.append(int(jnp.argmax(logits[0, -1])))
    logits, _ = decode_step(
        params, cache, jnp.asarray(toks, jnp.int32)[:, None],
        jnp.asarray([len(pa), len(pb)], jnp.int32), cfg, RUN)
    assert int(jnp.argmax(logits[0, -1])) == outs["a"]
    assert int(jnp.argmax(logits[1, -1])) == outs["b"]


# ---------------------------------------------------------------- engine ----

def test_engine_greedy_matches_reference():
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    ref = _greedy_reference(params, prompt, cfg, 5)

    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run_to_completion()
    assert req.done and req.output == ref, (req.output, ref)


def test_engine_continuous_batching_slot_reuse():
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(4)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4 + i).astype(
                        np.int32),
                    max_new_tokens=3 + i % 3)
            for i in range(5)]
    eng = DecodeEngine(cfg, params, num_slots=2, cache_len=64)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    assert all(r.done for r in reqs)
    for r in reqs:
        assert len(r.output) == r.max_new_tokens
    # 5 requests through 2 slots: admissions == completions == 5
    assert eng.metrics.counter("serve_requests_admitted").value() == 5
    assert eng.metrics.counter("serve_requests_completed").value() == 5


def test_engine_isolation_between_slots():
    """A request's output must not depend on what shares the batch."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    solo = Request(rid=0, prompt=prompt, max_new_tokens=4)
    eng1 = DecodeEngine(cfg, params, num_slots=2, cache_len=64)
    eng1.submit(solo)
    eng1.run_to_completion()

    other = Request(rid=1,
                    prompt=rng.integers(0, cfg.vocab_size, 11).astype(
                        np.int32), max_new_tokens=6)
    shared = Request(rid=2, prompt=prompt, max_new_tokens=4)
    eng2 = DecodeEngine(cfg, params, num_slots=2, cache_len=64)
    eng2.submit(other)
    eng2.submit(shared)
    eng2.run_to_completion()
    assert shared.output == solo.output


def test_engine_eos_frees_slot_early():
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    # pick the first greedy token as "EOS" so it stops after 1 token
    ref = _greedy_reference(params, prompt, cfg, 1)
    req = Request(rid=0, prompt=prompt, max_new_tokens=50, eos_id=ref[0])
    eng = DecodeEngine(cfg, params, num_slots=1, cache_len=64)
    eng.submit(req)
    eng.run_to_completion()
    assert req.done and len(req.output) == 1
