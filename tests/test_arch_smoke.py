"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant of the same family, runs one forward/train step on CPU with
correct output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, RunConfig, get_config, get_reduced_config
from repro.configs.base import InputShape
from repro.models import init_params, loss_fn, make_batch
from repro.models.model import forward_train
from repro.optim import OptimizerConfig, init_opt_state
from repro.training import make_train_step

SMOKE_SHAPE = InputShape("smoke", 64, 2, "train")
RUN = RunConfig(strategy="dp", microbatches=1, remat="none")


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_shapes_and_finite(arch_id):
    cfg = get_reduced_config(arch_id)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_params(cfg, 0)
    batch = make_batch(cfg, SMOKE_SHAPE, 0)
    logits, aux = forward_train(params, batch, cfg, RUN)
    B = SMOKE_SHAPE.global_batch
    S = SMOKE_SHAPE.seq_len
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    for v in aux.values():
        assert bool(jnp.isfinite(v))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_train_step(arch_id, cpu_mesh):
    cfg = get_reduced_config(arch_id)
    opt = OptimizerConfig(warmup_steps=2, decay_steps=10)
    step = make_train_step(cfg, RUN, cpu_mesh, opt)
    params = init_params(cfg, 0)
    state = init_opt_state(params, opt)
    batch = make_batch(cfg, SMOKE_SHAPE, 0)
    # snapshot before the step: train_step donates params/opt-state buffers
    old_leaves = [np.asarray(x).copy() for x in jax.tree.leaves(params)]
    new_params, state, metrics = step(params, state, batch)
    assert np.isfinite(metrics["loss"])
    assert np.isfinite(metrics["grad_norm"]) and metrics["grad_norm"] > 0
    # parameters actually moved
    moved = any(
        not np.array_equal(a, np.asarray(b))
        for a, b in zip(old_leaves, jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_exact_dims(arch_id):
    """The full configs carry the exact published dimensions."""
    cfg = get_config(arch_id)
    expected = {
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 0, 151936),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "dbrx-132b": (40, 6144, 48, 8, 0, 100352),  # FFN is MoE (d_ff_expert below)
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
    }[arch_id]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch_id, got, expected)
    # MoE expert hidden dims carry the published per-expert d_ff
    moe_dff = {"dbrx-132b": 10752, "qwen2-moe-a2.7b": 1408,
               "jamba-1.5-large-398b": 24576}
    if arch_id in moe_dff:
        assert cfg.moe is not None and cfg.moe.d_ff == moe_dff[arch_id]


def test_param_counts_match_family_scale():
    """Total params land near the advertised model size."""
    expect = {
        "jamba-1.5-large-398b": (300e9, 480e9),
        "starcoder2-3b": (2.5e9, 3.8e9),
        "pixtral-12b": (10e9, 14e9),
        "qwen2-moe-a2.7b": (12e9, 17e9),   # 14.3B total (2.7B active)
        "musicgen-large": (2.2e9, 4.2e9),  # ~2.4B decoder (3.3B incl. T5 text enc, stubbed)
        "qwen2-7b": (6.5e9, 8.5e9),
        "stablelm-3b": (2.3e9, 3.3e9),
        "mamba2-780m": (0.6e9, 0.95e9),
        "dbrx-132b": (110e9, 145e9),
        "minitron-4b": (3.5e9, 5.5e9),
    }
    for arch_id, (lo, hi) in expect.items():
        n = get_config(arch_id).param_count()
        assert lo <= n <= hi, f"{arch_id}: {n:,} outside [{lo:,}, {hi:,}]"


def test_moe_active_params_less_than_total():
    for arch_id in ("qwen2-moe-a2.7b", "dbrx-132b", "jamba-1.5-large-398b"):
        cfg = get_config(arch_id)
        assert cfg.active_param_count() < cfg.param_count()


@pytest.mark.parametrize("arch_id", ["pixtral-12b", "musicgen-large"])
def test_frontend_stub_batches(arch_id):
    """VLM/audio batches carry precomputed embeddings (assignment carve-out)."""
    cfg = get_reduced_config(arch_id)
    batch = make_batch(cfg, SMOKE_SHAPE, 0)
    if cfg.frontend == "vision":
        assert "prefix_embeddings" in batch and "tokens" in batch
        # no loss on the image prefix
        P = batch["prefix_embeddings"].shape[1]
        assert float(batch["loss_mask"][:, :P].sum()) == 0.0
    else:
        assert "frame_embeddings" in batch and "tokens" not in batch
    params = init_params(cfg, 0)
    loss, _ = loss_fn(params, batch, cfg, RUN)
    assert np.isfinite(loss)
