"""The paper's parallelism catalog (§2.4, §7) as a composable JAX engine:
strategies -> sharding rules -> GSPMD; ZeRO stages; shard_map pipeline."""
from repro.core.parallelism import STRATEGIES, Strategy, get_strategy
from repro.core import pipeline, sharding, zero

__all__ = ["STRATEGIES", "Strategy", "get_strategy", "sharding", "zero",
           "pipeline"]
