"""ZeRO stage semantics (paper §7.2), realized in GSPMD.

In torch-land ZeRO stages are process-group protocols; under GSPMD the same
semantics fall out of *where tensors live*:

  stage 1 — optimizer state sharded on `data`; params + grads replicated
            across `data` (modulo TP).  The update step computes on the
            shard and the new params are all-gathered implicitly.
  stage 2 — + gradients reduce-scattered: we constrain the grad pytree to
            the data-sharded layout so XLA emits reduce-scatter instead of
            all-reduce for the DP gradient sum.
  stage 3 — + parameters sharded (FSDP): weights are all-gathered at use,
            per layer, inside the scan.

``param_shardings`` / ``opt_shardings`` in core.sharding implement the
placement; this module provides the gradient constraint hook used by the
train-step builder.
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig, RunConfig
from repro.core import sharding as shd


def grad_shardings(cfg: ModelConfig, mesh, run: RunConfig):
    """Sharding pytree to constrain gradients to (ZeRO stage >= 2)."""
    if run.zero_stage >= 2:
        return shd.opt_shardings(cfg, mesh, run)
    return shd.param_shardings(cfg, mesh, run)


def constrain_grads(grads, cfg: ModelConfig, mesh, run: RunConfig):
    if run.zero_stage < 2:
        return grads
    specs = grad_shardings(cfg, mesh, run)
    return jax.tree.map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, specs)
