"""Activation sharding constraints, logical-name based.

GSPMD propagates shardings from jit boundaries inward; for under-constrained
programs (notably GQA attention with head counts not divisible by the model
axis) the propagation can pick pathological layouts — measured on
starcoder2-3b/train_4k: batch *replicated* and the (g, s) score dims sharded,
costing ~20x the useful flops per device.  The fix is the standard MaxText
practice: pin every major activation with ``with_sharding_constraint``.

Model code stays mesh-agnostic: it calls ``constrain(x, "<logical name>")``
and this module resolves the name against the active rule set (a contextvar
installed by the step builders while tracing).  Outside any rule context
(unit tests, CPU smoke runs) ``constrain`` is the identity.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

import jax

Rules = Callable[[str, tuple], Optional[object]]   # (name, shape) -> sharding

_RULES: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "repro_act_sharding_rules", default=None)


@contextlib.contextmanager
def activation_sharding(rules: Optional[Rules]):
    """Install activation-sharding rules for code traced inside the block."""
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Constrain activation ``x`` per the active rules (identity if none)."""
    rules = _RULES.get()
    if rules is None:
        return x
    sh = rules(name, tuple(x.shape))
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)


# ---------------------------------------------------- tensor-parallel psum ----
#
# Serving TP runs the model inside ``shard_map`` (the Pallas decode kernels
# cannot be partitioned by GSPMD), where cross-shard reductions must be
# written explicitly.  The model code stays mesh-agnostic the same way
# ``constrain`` keeps it: attention's output projection and the MLP
# down-projection call ``maybe_psum(x, kind)``, and the engine's step
# builders install a reduction spec for the trace.  Outside any spec
# (training, single-device serving) it is the identity.

#: (axis name, psum "attn_out"?, psum "mlp_out"?, counter dict or None)
_TP_REDUCE: contextvars.ContextVar[Optional[tuple]] = contextvars.ContextVar(
    "repro_tp_reduce", default=None)


@contextlib.contextmanager
def tp_reduce_scope(axis: str, attn: bool, mlp: bool, counts=None):
    """Install cross-shard reductions for code traced inside the block.

    ``attn``/``mlp`` gate the two reduction points independently: under
    the divisibility-fallback policy a dimension that does not divide the
    mesh axis keeps its params replicated, and a psum there would
    multiply the (already-complete) partial by the shard count.
    ``counts`` (optional dict) accumulates ``{"attn_out": n, "mlp_out":
    m}`` psum insertions during tracing — the sdiag per-shard section
    reports psums per dispatch from it.
    """
    tok = _TP_REDUCE.set((axis, attn, mlp, counts))
    try:
        yield
    finally:
        _TP_REDUCE.reset(tok)


def tp_will_reduce(kind: str) -> bool:
    """True when :func:`maybe_psum` would reduce at this point.  Call
    sites use it to keep the partial contraction in float32 through the
    psum: reducing already-rounded bf16 partials double-rounds, and the
    extra half-ulp is enough to flip a near-tie greedy argmax vs the
    single-device contraction (which rounds its f32 accumulator once)."""
    spec = _TP_REDUCE.get()
    if spec is None:
        return False
    _, attn, mlp, _ = spec
    return attn if kind == "attn_out" else mlp


def maybe_psum(x: jax.Array, kind: str) -> jax.Array:
    """Cross-shard ``psum`` of a partial sum at a named reduction point
    (``"attn_out"`` | ``"mlp_out"``); identity outside ``tp_reduce_scope``
    or when the point's dimension was left replicated."""
    spec = _TP_REDUCE.get()
    if spec is None:
        return x
    axis, attn, mlp, counts = spec
    if (kind == "attn_out" and not attn) or (kind == "mlp_out" and not mlp):
        return x
    if counts is not None:
        counts[kind] = counts.get(kind, 0) + 1
    return jax.lax.psum(x, axis)
