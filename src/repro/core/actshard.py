"""Activation sharding constraints, logical-name based.

GSPMD propagates shardings from jit boundaries inward; for under-constrained
programs (notably GQA attention with head counts not divisible by the model
axis) the propagation can pick pathological layouts — measured on
starcoder2-3b/train_4k: batch *replicated* and the (g, s) score dims sharded,
costing ~20x the useful flops per device.  The fix is the standard MaxText
practice: pin every major activation with ``with_sharding_constraint``.

Model code stays mesh-agnostic: it calls ``constrain(x, "<logical name>")``
and this module resolves the name against the active rule set (a contextvar
installed by the step builders while tracing).  Outside any rule context
(unit tests, CPU smoke runs) ``constrain`` is the identity.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

import jax

Rules = Callable[[str, tuple], Optional[object]]   # (name, shape) -> sharding

_RULES: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "repro_act_sharding_rules", default=None)


@contextlib.contextmanager
def activation_sharding(rules: Optional[Rules]):
    """Install activation-sharding rules for code traced inside the block."""
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Constrain activation ``x`` per the active rules (identity if none)."""
    rules = _RULES.get()
    if rules is None:
        return x
    sh = rules(name, tuple(x.shape))
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)
