"""Logical-axis -> mesh-axis mapping: build NamedSharding pytrees for
params, optimizer state, batches and decode caches.

Divisibility policy (see DESIGN.md): a dim is sharded on a mesh axis only if
its size divides evenly; otherwise the next candidate (or replication) is
chosen — mirroring what real deployments do when e.g. GQA kv_heads < TP.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core.parallelism import (
    NEVER_SHARD, TP_AXIS_PRIORITY, Strategy, get_strategy,
)
from repro.models.spec import ParamSpec, model_spec


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_partition(mesh: Mesh, global_batch: int,
                    strategy: Strategy) -> Optional[tuple[str, ...]]:
    """Mesh axes carrying the batch dim (longest divisible prefix-product)."""
    axes = [a for a in strategy.batch_axes if a in mesh.axis_names]
    while axes:
        total = int(np.prod([_axis_size(mesh, a) for a in axes]))
        if global_batch % total == 0:
            return tuple(axes)
        axes.pop(0)          # drop the outermost ("pod") first
    return None


def param_pspec(ps: ParamSpec, mesh: Mesh, strategy: Strategy,
                fsdp_override: Optional[bool] = None) -> P:
    """PartitionSpec for one parameter from its logical axes."""
    spec: list = [None] * len(ps.shape)
    model_n = _axis_size(mesh, "model")
    fsdp = strategy.fsdp if fsdp_override is None else fsdp_override

    # ---- tensor/expert parallelism on `model`, by priority ----
    if strategy.tp and model_n > 1:
        for logical in TP_AXIS_PRIORITY:
            placed = False
            for i, (ax, n) in enumerate(zip(ps.axes, ps.shape)):
                if ax == logical and n % model_n == 0 and spec[i] is None:
                    spec[i] = "model"
                    placed = True
                    break
            if placed:
                break

    # ---- ZeRO-3 / FSDP storage sharding on the batch axes (largest free
    # dim).  Multi-pod meshes shard over ("pod", "data") — ZeRO across the
    # DCN as well as within the pod, which is what makes a 398B model's
    # f32 master + optimizer state fit 512 chips at all. ----
    data_n = _axis_size(mesh, "data")
    pod_n = _axis_size(mesh, "pod") if "pod" in mesh.axis_names else 1
    groups = []
    if pod_n > 1:
        groups.append((("pod", "data"), pod_n * data_n))
    groups.append((("data",), data_n))
    for axes, total in groups:
        if not fsdp or total <= 1:
            continue
        cands = [
            (n, i) for i, (ax, n) in enumerate(zip(ps.axes, ps.shape))
            if spec[i] is None and ax not in NEVER_SHARD
            and n % total == 0 and n >= total
        ]
        if cands:
            _, i = max(cands)
            spec[i] = axes if len(axes) > 1 else axes[0]
            break
    return P(*spec)


def serving_param_pspec(ps: ParamSpec, tp: int, shard_axes,
                        axis: str = "model") -> P:
    """PartitionSpec for one parameter under serving tensor parallelism.

    Unlike :func:`param_pspec` (training: TP_AXIS_PRIORITY would
    vocab-shard the embedding/LM head), serving shards ONLY the logical
    axes in ``shard_axes`` — heads/kv_heads/ffn per the engine's TP plan
    — and replicates everything else: the decode engine samples on
    device from full logits, so every shard must hold the whole
    vocabulary.  The mesh axis lands on the first matching axis whose
    size divides ``tp`` (at most one placement per parameter)."""
    spec: list = [None] * len(ps.shape)
    for i, (ax, n) in enumerate(zip(ps.axes, ps.shape)):
        if ax in shard_axes and n % tp == 0:
            spec[i] = axis
            break
    return P(*spec)


def param_shardings(cfg: ModelConfig, mesh: Mesh, run: RunConfig):
    """NamedSharding pytree matching the parameter pytree.

    ZeRO stage semantics (paper §7.2): stage 3 shards parameters themselves;
    stages 1/2 keep parameters data-replicated (TP still applies) and shard
    only optimizer state (and, for 2, gradients) — see ``opt_shardings`` /
    ``grad_shardings``.
    """
    strategy = get_strategy(run.strategy)
    fsdp = strategy.fsdp and run.zero_stage >= 3

    def build(tree):
        if isinstance(tree, ParamSpec):
            return NamedSharding(
                mesh, param_pspec(tree, mesh, strategy, fsdp_override=fsdp))
        if isinstance(tree, dict):
            return {k: build(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [build(v) for v in tree]
        raise TypeError(type(tree))

    return build(model_spec(cfg))


def opt_shardings(cfg: ModelConfig, mesh: Mesh, run: RunConfig):
    """Optimizer-state (m/v) shardings: ZeRO>=1 always data-shards them."""
    strategy = get_strategy(run.strategy)
    fsdp = (strategy.fsdp and run.zero_stage >= 1) or run.zero_stage >= 1

    def build(tree):
        if isinstance(tree, ParamSpec):
            return NamedSharding(
                mesh, param_pspec(tree, mesh, strategy, fsdp_override=fsdp))
        if isinstance(tree, dict):
            return {k: build(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [build(v) for v in tree]
        raise TypeError(type(tree))

    return build(model_spec(cfg))


def batch_shardings(cfg: ModelConfig, mesh: Mesh, run: RunConfig,
                    specs: dict) -> dict:
    """Shardings for a batch dict (train or decode inputs)."""
    strategy = get_strategy(run.strategy)
    out = {}
    for k, s in specs.items():
        if k == "pos" or np.ndim(s) == 0 or len(s.shape) == 0:
            out[k] = NamedSharding(mesh, P())
            continue
        baxes = batch_partition(mesh, s.shape[0], strategy)
        spec = [baxes] + [None] * (len(s.shape) - 1)
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, run: RunConfig,
                    cache_abstract, paging: bool = False) -> dict:
    """Shardings for the decode cache pytree.

    batch -> (pod, data) when divisible; kv_heads/ssm_head -> model when
    divisible, else the cache sequence dim -> model (sequence-sharded KV —
    GSPMD inserts the softmax-combine collectives).  ``paging=True``
    matches a paged-pool cache (no batch dim: pages replace (batch, seq),
    so the batch rule never lands on the page axis).
    """
    from repro.models.model import cache_logical_axes
    strategy = get_strategy(run.strategy)
    model_n = _axis_size(mesh, "model")
    axes_tree = cache_logical_axes(cfg, paging=paging)

    def leaf_spec(arr, axes):
        spec: list = [None] * len(arr.shape)
        used_model = False
        for i, ax in enumerate(axes):
            n = arr.shape[i]
            if ax == "batch":
                baxes = batch_partition(mesh, n, strategy)
                spec[i] = baxes
            elif ax in ("kv_heads", "ssm_head") and strategy.tp and \
                    model_n > 1 and n % model_n == 0:
                spec[i] = "model"
                used_model = True
        if strategy.tp and model_n > 1 and not used_model:
            for i, ax in enumerate(axes):
                if ax == "cache_seq" and arr.shape[i] % model_n == 0:
                    spec[i] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf_spec, cache_abstract, axes_tree)


def make_activation_rules(cfg: ModelConfig, mesh: Mesh, run: RunConfig):
    """Activation-sharding rules (see core/actshard.py) for one run.

    Logical names (shapes as produced by the model code):
      hidden       (B, S, D)        batch -> (pod, data)
      heads        (B, S, H, Dh)    + H -> model when divisible
      kv           (B, S, K, Dh)    + K -> model when divisible
      ffn_hidden   (B, S, F)        + F -> model when divisible
      logits       (B, S, V)        + V -> model when divisible
      moe_tokens   (G, gs, D)       G (token groups) -> (pod, data)
      moe_dispatch (G, gs, E, C)    + E -> model when divisible
      moe_expert   (G, E, C, D|F)   + E -> model; else last dim (TP-in-expert)
      ssm_heads    (B, S, H, P)     + H -> model when divisible
      ssm_inner    (B, S, C)        + C -> model when divisible (conv channels)

    Every rule pins dim0 to the batch axes — this is the constraint whose
    absence let GSPMD replicate the batch on `data` (see actshard docstring).
    """
    strategy = get_strategy(run.strategy)
    model_n = _axis_size(mesh, "model")
    tp = strategy.tp and model_n > 1

    def mdl(n: int):
        return "model" if (tp and n % model_n == 0 and n >= model_n) else None

    # sequence parallelism (run.seq_parallel, beyond-paper §Perf): when the
    # head count does NOT divide `model`, shard the sequence dim there
    # instead of replicating the whole attention block.
    def seq(n: int):
        return "model" if (run.seq_parallel and tp
                           and n % model_n == 0 and n >= model_n) else None

    def rules(name: str, shape: tuple):
        b = batch_partition(mesh, shape[0], strategy)

        def ns(*spec):
            return NamedSharding(mesh, P(*spec))

        if name == "hidden":
            if len(shape) == 3 and mdl(1) is None:
                pass
            return ns(b, seq(shape[1]) if len(shape) == 3 else None, None)
        if name == "heads":
            h = mdl(shape[2])
            s = None if h else seq(shape[1])
            return ns(b, s, h, None)
        if name == "kv":
            # under seq-parallel the KV tensors are all-gathered (small
            # with GQA); otherwise kv heads go to model when they divide
            h = mdl(shape[2])
            if run.seq_parallel and mdl(shape[2]) is None:
                h = None
            return ns(b, None, h, None)
        if name == "ssm_heads":
            return ns(b, None, mdl(shape[2]), None)
        if name in ("ffn_hidden", "logits", "ssm_inner"):
            m = mdl(shape[-1])
            s = None if m or len(shape) != 3 else seq(shape[1])
            return ns(b, *([None] * (len(shape) - 3)), s, m)
        if name == "q_blocks":        # (nb, B, qb, K, G, Dh) — scan xs
            bb = batch_partition(mesh, shape[1], strategy)
            return ns(None, bb, seq(shape[2]), None, None, None)
        if name == "hidden_full":     # (B, S, D) — the Megatron-SP gather
            # point at the FFN entry: pinning it on the bf16 tensor stops
            # XLA hoisting rmsnorm's f32 cast before the all-gather
            # (measured 2x gather bytes; see EXPERIMENTS.md §Perf-1 it.4)
            return ns(b, None, None)
        if name == "moe_tokens":
            return ns(b, None, None)
        if name == "moe_dispatch":
            return ns(b, None, mdl(shape[2]), None)
        if name == "moe_expert_d":     # (G, E, C, d_model): never shard d
            e = mdl(shape[1])
            if e is None and run.moe_defer_combine:
                return None            # leave partial sums free to defer
            return ns(b, e, None, None)
        if name == "moe_expert_f":     # (G, E, C, d_ff): TP-in-expert when
            e = mdl(shape[1])          # the expert count doesn't divide
            return ns(b, e, None, None if e else mdl(shape[-1]))
        return None

    return rules


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def scalar_tree_shardings(mesh: Mesh, tree):
    return jax.tree.map(lambda _: replicated(mesh), tree)


def describe(shardings, max_rows: int = 0) -> str:
    """Human-readable table of a sharding pytree (debug/tests)."""
    rows = []
    flat, _ = jax.tree_util.tree_flatten_with_path(shardings)
    for path, sh in flat:
        name = jax.tree_util.keystr(path)
        rows.append(f"{name}: {sh.spec}")
    if max_rows:
        rows = rows[:max_rows]
    return "\n".join(rows)
