"""Parallelism strategies — the paper's §2.4/§7 catalog as composable
sharding policies.

Each :class:`Strategy` says which *logical* tensor axes map onto the mesh's
``model`` axis (tensor/expert parallelism), whether parameters are sharded
along ``data`` (FSDP / ZeRO-3), and which mesh axes carry the batch (data
parallelism).  ``repro.core.sharding`` turns a strategy plus a spec tree
into concrete ``NamedSharding`` pytrees.

The paper presents DP, TP, PP (§7.1) and FSDP/ZeRO-1/2/3 (§7.2); all are
available here.  PP is realized separately (``repro.core.pipeline``) as a
shard_map microbatch schedule over a ``pipe`` axis.
"""
from __future__ import annotations

from dataclasses import dataclass, field

# Priority-ordered logical axes eligible for the `model` mesh axis.  Expert
# parallelism first (all-to-all-style dispatch beats intra-expert TP when the
# expert count divides), then attention heads, then SSD heads/inner, then FFN
# hidden, then vocab.
TP_AXIS_PRIORITY = (
    "experts", "heads", "kv_heads", "ssm_head", "ssm_inner", "ffn", "vocab",
)

# Logical axes that must never be sharded (small / semantically atomic).
NEVER_SHARD = ("head_dim", "conv", "ssm_state", "layers")


@dataclass(frozen=True)
class Strategy:
    """One point in the paper's parallelism catalog."""
    name: str
    tp: bool                          # tensor/expert parallelism on `model`
    fsdp: bool                        # ZeRO-3 parameter sharding on `data`
    batch_axes: tuple[str, ...] = ("pod", "data")
    description: str = ""


STRATEGIES: dict[str, Strategy] = {
    "dp": Strategy(
        "dp", tp=False, fsdp=False,
        description="pure data parallelism (paper §2.4.1): replicate the "
                    "model, shard the batch, all-reduce gradients"),
    "tp": Strategy(
        "tp", tp=True, fsdp=False,
        description="tensor parallelism (paper §7.1 TP): shard heads/ffn/"
                    "experts over `model`, replicate across `data`"),
    "fsdp": Strategy(
        "fsdp", tp=False, fsdp=True,
        description="FSDP/ZeRO-3 (paper §7.2): shard params, grads and "
                    "optimizer state over `data`; all-gather at use"),
    "fsdp_tp": Strategy(
        "fsdp_tp", tp=True, fsdp=True,
        description="composed FSDP x TP — the production default"),
}


def get_strategy(name: str) -> Strategy:
    try:
        return STRATEGIES[name]
    except KeyError:
        if name == "pp":
            raise ValueError(
                "pipeline parallelism is driven via repro.core.pipeline, "
                "not a sharding strategy name") from None
        raise ValueError(f"unknown strategy {name!r}; "
                         f"have {sorted(STRATEGIES)}") from None
