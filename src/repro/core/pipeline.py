"""Pipeline parallelism (paper §7.1 "PipelineParallel") — GPipe-style
microbatch schedule over a ``pipe`` mesh axis, written with ``shard_map`` +
``lax.ppermute``.

The model's stacked layer groups are split contiguously across stages
(vertical split, exactly the paper's description: "the model is split up
vertically (layer-level) across multiple GPUs").  Each tick every stage runs
its slice on one microbatch (masked out during fill/drain bubbles) and
passes activations to the next stage over ``ppermute`` — the TPU analogue of
NCCL P2P sends.  Differentiable end-to-end, so training works through it.

This is a selectable strategy demonstrated on small meshes in tests and
examples; the production dry-run default composes FSDP x TP instead (same
choice most TPU deployments make — PP earns its bubble cost only on very
deep models over slow inter-node links).
"""
from __future__ import annotations

import functools

import inspect

import jax
import jax.numpy as jnp
try:                                    # jax >= 0.5 exports it at top level
    from jax import shard_map
except ImportError:                     # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# the replication-check kwarg was renamed check_rep -> check_vma
_CHECK_KW = ("check_vma" if "check_vma" in
             inspect.signature(shard_map).parameters else "check_rep")


def split_stages(stacked, n_stages: int):
    """Check the stacked-layer-group pytree divides across stages."""
    def check(x):
        assert x.shape[0] % n_stages == 0, (
            f"layer groups {x.shape[0]} not divisible by {n_stages} stages")
        return x
    return jax.tree.map(check, stacked)


def pipeline_apply(stage_fn, stacked_params, x_micro, mesh: Mesh,
                   axis: str = "pipe"):
    """Run microbatches through the pipeline.

    stage_fn(stage_params, x) -> y          (one stage's layer slice)
    stacked_params: leaves (n_groups, ...) — sharded over `axis` on dim 0
    x_micro: (n_micro, mb, S, d)            — replicated across `axis`
    Returns (n_micro, mb, S, d) from the last stage (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    split_stages(stacked_params, n_stages)

    p_params = jax.tree.map(lambda _: P(axis), stacked_params)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(p_params, P()),
        out_specs=P(),
        **{_CHECK_KW: False})
    def run(params_local, x_local):
        my = jax.lax.axis_index(axis)
        is_first = my == 0
        is_last = my == n_stages - 1
        carry = jnp.zeros_like(x_local[0])
        outs = jnp.zeros_like(x_local)
        for t in range(n_micro + n_stages - 1):
            mb = t - my                                   # my microbatch idx
            active = jnp.logical_and(mb >= 0, mb < n_micro)
            mb_c = jnp.clip(mb, 0, n_micro - 1)
            inp = jnp.where(is_first,
                            jax.lax.dynamic_index_in_dim(
                                x_local, mb_c, keepdims=False),
                            carry)
            y = stage_fn(params_local, inp)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage records its finished microbatch
            write = jnp.logical_and(is_last, active)
            upd = jnp.where(write, y, jax.lax.dynamic_index_in_dim(
                outs, mb_c, keepdims=False))
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, mb_c, 0)
            # hand activations to the next stage
            carry = jax.lax.ppermute(y, axis, perm)
        # broadcast last stage's buffer to everyone
        outs = jax.lax.psum(
            jnp.where(is_last, outs, jnp.zeros_like(outs)), axis)
        return outs

    return run(stacked_params, x_micro)


def make_pipeline_mesh(n_stages: int, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()[:n_stages]
    return jax.make_mesh((n_stages,), ("pipe",), devices=devices)
