from repro.monitoring.metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, Timer,
)

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "Timer"]
