from repro.monitoring.metrics import (
    LATENCY_BUCKETS, Counter, Gauge, Histogram, MetricsRegistry, Timer,
)
from repro.monitoring.trace import (
    DEFAULT_SLO_TARGETS, SLORecorder, SLOTarget, Span, SpanEvent, Tracer,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "LATENCY_BUCKETS", "MetricsRegistry",
    "Timer",
    "DEFAULT_SLO_TARGETS", "SLORecorder", "SLOTarget", "Span", "SpanEvent",
    "Tracer",
]
