"""In-process metrics registry with Prometheus text exposition.

The paper's §6.1 deploys Prometheus + Grafana next to SLURM; daemons don't
fit a CI container, so the same observability surface is provided in-process:
counters / gauges / histograms, labeled series, `expose()` emitting the
Prometheus text format those servers would scrape, and an ASCII dashboard
(`dashboard()`) standing in for Grafana.
"""
from __future__ import annotations

import bisect
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

_DEFAULT_BUCKETS = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 50.0, 100.0,
    float("inf"))

#: Latency-tuned preset for request-level SLO series (queue wait, TTFT,
#: inter-token latency, end-to-end): sub-millisecond resolution at the
#: fast end, where the default preset's decade-wide buckets would smear
#: every interactive-tier percentile into one bin.
LATENCY_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, float("inf"))

# Canonical series names for the SLURM layer (what the paper's §6.1
# Prometheus would scrape from slurmctld exporters).  The cluster engine
# exports these; dashboards/tests key off the constants, not string
# literals.
METRIC_JOBS_PENDING = "slurm_jobs_pending"
METRIC_JOBS_RUNNING = "slurm_jobs_running"
#: total preempted segments since boot (gauge mirror of the counter below)
METRIC_PREEMPTIONS = "slurm_preemptions_total"
#: preempted segments labeled by victim {qos=,account=}
METRIC_PREEMPTIONS_BY = "slurm_preempted_segments"
#: decayed weighted TRES-seconds, labeled {account=}
METRIC_ACCOUNT_USAGE = "slurm_account_tres_usage"
#: the 2^(-usage/shares) fair-share factor, labeled {account=}
METRIC_ACCOUNT_FAIRSHARE = "slurm_account_fairshare_factor"

# Multi-tenant serving (the admission controller shares the fair-share
# ledger above; these series break the decode engine down per tenant).
#: generated tokens, labeled {tenant=}
METRIC_SERVE_TENANT_TOKENS = "serve_tenant_tokens_generated"
#: admitted requests (incl. resumed preemption victims), labeled {tenant=}
METRIC_SERVE_TENANT_ADMITTED = "serve_tenant_requests_admitted"
#: decode slots evicted for a higher-QOS request
METRIC_SERVE_PREEMPTIONS = "serve_preemptions_total"

# Prefix cache (radix-style shared-prefix reuse over the paged KV pool).
#: admissions that mapped >= 1 cached prefix page read-only
METRIC_SERVE_PREFIX_HITS = "serve_prefix_hits"
#: admissions that found no cached prefix
METRIC_SERVE_PREFIX_MISSES = "serve_prefix_misses"
#: prompt tokens whose prefill was skipped via shared pages
METRIC_SERVE_PREFIX_REUSED_TOKENS = "serve_prefix_reused_tokens"
#: cached prefix pages LRU-evicted back to the free pool under pressure
METRIC_SERVE_PREFIX_EVICTIONS = "serve_prefix_evicted_pages"

# Tensor-parallel serving (paged KV pool sharded across the mesh).
#: KV pages with >= 1 holder, a gauge labeled {device=} — one series per
#: shard, so asymmetric pool pressure is visible before it starves a shard
METRIC_SERVE_KV_PAGES_IN_USE = "serve_kv_pages_in_use"

# Speculative decoding (draft-and-verify inside the fused chunk).
#: draft tokens proposed to the verifier
METRIC_SPEC_PROPOSED = "serve_spec_proposed_total"
#: draft tokens the target model accepted
METRIC_SPEC_ACCEPTED = "serve_spec_accepted_total"
#: running acceptance rate (accepted / proposed), a gauge
METRIC_SPEC_ACCEPT_RATE = "serve_spec_acceptance_rate"

# Elastic multi-replica serving (prefix-affinity router + autoscaler).
#: per-replica queue depth (slot holders + queued), a gauge {replica=}
METRIC_SERVE_REPLICA_LOAD = "serve_replica_load"
#: per-replica KV pages with >= 1 holder, a gauge {replica=}
METRIC_SERVE_REPLICA_KV_PAGES = "serve_replica_kv_pages_in_use"
#: requests the router sent to their prefix-affine replica
METRIC_ROUTE_AFFINITY_HITS = "route_affinity_hits"
#: affinity routes shed to the least-loaded replica (load-shed bound)
METRIC_ROUTE_SPILLS = "route_spills_total"


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _escape_label_value(value) -> str:
    """Prometheus exposition-format label-value escaping: backslash,
    double-quote, and newline must be escaped or the scrape text is
    invalid (a tenant named ``acme "prod"`` would otherwise break every
    series it labels)."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labels_text(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label_value(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    def __init__(self, name: str, help_: str = ""):
        self.name, self.help = name, help_
        self._vals: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0, **labels):
        assert amount >= 0, "counters only go up"
        key = _labels_key(labels)
        with self._lock:
            self._vals[key] = self._vals.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._vals.get(_labels_key(labels), 0.0)

    def expose(self) -> list[str]:
        out = [f"# HELP {self.name} {self.help}",
               f"# TYPE {self.name} counter"]
        for key, v in sorted(self._vals.items()):
            out.append(f"{self.name}{_labels_text(dict(key))} {v}")
        return out


class Gauge:
    def __init__(self, name: str, help_: str = ""):
        self.name, self.help = name, help_
        self._vals: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def set(self, value: float, **labels):
        with self._lock:
            self._vals[_labels_key(labels)] = float(value)

    def add(self, amount: float, **labels):
        key = _labels_key(labels)
        with self._lock:
            self._vals[key] = self._vals.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._vals.get(_labels_key(labels), 0.0)

    def expose(self) -> list[str]:
        out = [f"# HELP {self.name} {self.help}",
               f"# TYPE {self.name} gauge"]
        for key, v in sorted(self._vals.items()):
            out.append(f"{self.name}{_labels_text(dict(key))} {v}")
        return out


class Histogram:
    def __init__(self, name: str, help_: str = "", buckets=_DEFAULT_BUCKETS):
        self.name, self.help = name, help_
        self.buckets = tuple(buckets)
        assert self.buckets[-1] == float("inf")
        self._counts: dict[tuple, list[int]] = {}
        self._sum: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def observe(self, value: float, **labels):
        key = _labels_key(labels)
        with self._lock:
            counts = self._counts.setdefault(key, [0] * len(self.buckets))
            counts[bisect.bisect_left(self.buckets, value)] += 1
            self._sum[key] = self._sum.get(key, 0.0) + value

    def count(self, **labels) -> int:
        return sum(self._counts.get(_labels_key(labels), []))

    def sum(self, **labels) -> float:
        """Total of all observed values (the Prometheus ``_sum`` series)
        — e.g. cumulative prefill seconds across admissions."""
        return self._sum.get(_labels_key(labels), 0.0)

    def quantile(self, q: float, **labels) -> float:
        """Approximate quantile from bucket boundaries, linearly
        interpolated within the terminal bucket (Prometheus
        ``histogram_quantile`` semantics) — 100 observations of 3ms in
        the (1ms, 5ms] bucket report ~3ms, not the 5ms upper bound.  The
        +Inf bucket has no upper bound to interpolate toward, so values
        landing there report the last finite boundary."""
        counts = self._counts.get(_labels_key(labels))
        if not counts:
            return math.nan
        total = sum(counts)
        target = q * total
        acc = 0
        for i, (b, c) in enumerate(zip(self.buckets, counts)):
            prev = acc
            acc += c
            if acc >= target:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                if math.isinf(b) or c == 0:
                    return lo
                return lo + (b - lo) * (target - prev) / c
        return self.buckets[-2]

    def label_sets(self) -> list[dict]:
        """Every label combination this histogram has observed — lets
        dashboards/reports enumerate series without poking ``_counts``."""
        return [dict(key) for key in sorted(self._counts)]

    def expose(self) -> list[str]:
        out = [f"# HELP {self.name} {self.help}",
               f"# TYPE {self.name} histogram"]
        for key, counts in sorted(self._counts.items()):
            labels = dict(key)
            acc = 0
            for b, c in zip(self.buckets, counts):
                acc += c
                lb = dict(labels, le=("+Inf" if b == float("inf") else b))
                out.append(f"{self.name}_bucket{_labels_text(lb)} {acc}")
            out.append(f"{self.name}_sum{_labels_text(labels)} "
                       f"{self._sum[key]}")
            out.append(f"{self.name}_count{_labels_text(labels)} {acc}")
        return out


class MetricsRegistry:
    """One per process (or per Cluster); hand it to anything that reports."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help_: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help_, **kw)
                self._metrics[name] = m
            assert isinstance(m, cls), f"{name} registered as {type(m)}"
            return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._get(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._get(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "",
                  buckets=_DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help_, buckets=buckets)

    def timer(self, name: str, help_: str = "", **labels) -> Timer:
        """The ``with registry.timer(...)`` factory Timer's docstring
        advertises: times the with-block into the named histogram."""
        return Timer(self.histogram(name, help_), dict(labels))

    def expose(self) -> str:
        """Prometheus text exposition format (what :9090 would scrape)."""
        lines = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].expose())
        return "\n".join(lines) + "\n"

    def dashboard(self, width: int = 60) -> str:
        """ASCII Grafana: one bar per gauge/counter series, plus one
        summary row per histogram series (count, sum, p50/p99)."""
        rows = []
        vals = []
        hists = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, (Counter, Gauge)):
                for key, v in sorted(m._vals.items()):
                    vals.append((f"{name}{_labels_text(dict(key))}", v))
            elif isinstance(m, Histogram):
                for labels in m.label_sets():
                    hists.append((f"{name}{_labels_text(labels)}", m,
                                  labels))
        peak = max((abs(v) for _, v in vals), default=1.0) or 1.0
        for label, v in vals:
            bar = "#" * int(width * abs(v) / peak)
            rows.append(f"{label:<44} {v:>12.3f} |{bar}")
        for label, m, labels in hists:
            rows.append(
                f"{label:<44} n={m.count(**labels):<8d} "
                f"sum={m.sum(**labels):<12.3f} "
                f"p50={m.quantile(0.5, **labels):.4f} "
                f"p99={m.quantile(0.99, **labels):.4f}")
        return "\n".join(rows)


@dataclass
class Timer:
    """``with registry.timer(...)``-style latency helper."""
    hist: Histogram
    labels: dict = field(default_factory=dict)
    _t0: Optional[float] = None

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.hist.observe(time.perf_counter() - self._t0, **self.labels)
        return False
