"""Span-based request/job lifecycle tracer + derived SLO series.

The paper's §6.1 stands Prometheus + Grafana next to SLURM because
aggregate counters alone cannot answer "why was THIS request slow".
:class:`MetricsRegistry` reproduces the scrape surface; this module adds
the per-request story:

* **Spans** — named intervals with explicit parents, attributes, and
  instant events, stamped by an injectable monotonic clock (tests and the
  cluster simulation pass their own).  Completed spans land in a ring
  buffer (bounded memory under sustained traffic); open spans live in a
  side table until ended.
* **One timeline, two workloads** — the serving engine emits
  SUBMIT/QUEUED/ADMIT/PREFILL/DECODE/PREEMPT/RESUME/FINISH request spans
  and the cluster engine emits job PENDING/RUNNING/PREEMPTED/COMPLETED
  spans into the *same* tracer, so a single trace shows batch jobs and
  serving requests contending for the shared ledger.
* **Chrome trace export** — :meth:`Tracer.export_chrome` writes the
  Chrome trace-event JSON format; load it in Perfetto (ui.perfetto.dev)
  or ``chrome://tracing`` — the CI-friendly stand-in for the paper's
  Grafana dashboards.  Each span's ``track`` tuple becomes a
  (process, thread) lane pair.
* **Derived SLO series** — :class:`SLORecorder` turns lifecycle
  timestamps into per-tenant/per-QOS histograms on the latency-tuned
  bucket preset (queue wait, TTFT, inter-token latency, end-to-end) plus
  per-tier SLO-attainment counters, the series the ROADMAP's
  SLO-aware-QOS admission will be judged against.

Tracing is strictly opt-in: every producer guards on ``tracer is None``,
so the untraced hot path pays nothing (``bench_latency_slo`` asserts the
traced path stays within 5% tok/s of tracing disabled).
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

from repro.monitoring.metrics import LATENCY_BUCKETS, MetricsRegistry

# Derived SLO series (latency-tuned buckets, labeled {tenant=, qos=}).
#: seconds between enqueue and admission pick
METRIC_SERVE_QUEUE_WAIT = "serve_queue_wait_seconds"
#: admit -> first decoded token
METRIC_SERVE_TTFT = "serve_ttft_seconds"
#: per-token inter-token latency (chunk-amortized on the fused path)
METRIC_SERVE_ITL = "serve_itl_seconds"
#: submit -> finish
METRIC_SERVE_E2E = "serve_e2e_seconds"
# Per-tier SLO attainment, labeled {tenant=, qos=} — the counters an
# SLO-aware admission policy will read to deadline-boost a tier.
METRIC_SLO_TTFT_MET = "serve_slo_ttft_met"
METRIC_SLO_TTFT_VIOLATIONS = "serve_slo_ttft_violations"
METRIC_SLO_ITL_MET = "serve_slo_itl_met"
METRIC_SLO_ITL_VIOLATIONS = "serve_slo_itl_violations"

#: default (process, thread) lane for spans that don't name one
DEFAULT_TRACK = ("trace", "main")


@dataclass
class SpanEvent:
    """Instant event inside a span (Chrome 'i' phase)."""
    name: str
    ts: float
    attrs: dict = field(default_factory=dict)


@dataclass
class Span:
    """One interval on the timeline.  ``track`` is a (process, thread)
    pair — requests get one thread lane each, so Perfetto stacks a
    request's QUEUED/PREFILL/DECODE children under its root span."""
    sid: int
    name: str
    cat: str
    track: tuple
    start: float
    parent: Optional[int] = None       # parent span id
    attrs: dict = field(default_factory=dict)
    events: list = field(default_factory=list)
    end: Optional[float] = None

    @property
    def duration(self) -> float:
        return (self.end - self.start) if self.end is not None else 0.0


@dataclass(frozen=True)
class SLOTarget:
    """Latency objectives for one QOS tier (None = best-effort: the
    series still records, but no attainment counter moves)."""
    ttft_s: Optional[float] = None
    itl_s: Optional[float] = None


#: Default per-tier objectives: interactive `high` traffic wants sub-
#: second first tokens and snappy streaming; `normal` tolerates seconds;
#: `scavenger` is explicitly best-effort (no SLO to violate).
DEFAULT_SLO_TARGETS = {
    "high": SLOTarget(ttft_s=1.0, itl_s=0.2),
    "normal": SLOTarget(ttft_s=5.0, itl_s=0.5),
    "scavenger": SLOTarget(),
}


class SLORecorder:
    """Derived latency series + per-tier attainment counters.

    Producers report raw seconds at lifecycle edges; everything lands in
    per-(tenant, QOS) histograms on :data:`LATENCY_BUCKETS` so p50/p99
    are meaningful at interactive latencies.  A tier with a target in
    ``targets`` additionally bumps met/violated counters per observation.
    """

    def __init__(self, metrics: MetricsRegistry,
                 targets: Optional[dict[str, SLOTarget]] = None):
        self.metrics = metrics
        self.targets = dict(DEFAULT_SLO_TARGETS if targets is None
                            else targets)

    def _hist(self, name: str, help_: str):
        return self.metrics.histogram(name, help_, buckets=LATENCY_BUCKETS)

    def queue_wait(self, seconds: float, tenant: str, qos: str):
        self._hist(METRIC_SERVE_QUEUE_WAIT,
                   "enqueue -> admission pick").observe(
            seconds, tenant=tenant, qos=qos)

    def ttft(self, seconds: float, tenant: str, qos: str):
        self._hist(METRIC_SERVE_TTFT,
                   "admit -> first decoded token").observe(
            seconds, tenant=tenant, qos=qos)
        self._attain(seconds, self.targets.get(qos, SLOTarget()).ttft_s,
                     METRIC_SLO_TTFT_MET, METRIC_SLO_TTFT_VIOLATIONS,
                     tenant, qos)

    def itl(self, seconds: float, tenant: str, qos: str, n: int = 1):
        """Per-token inter-token latency.  The fused decode path syncs
        once per chunk, so it reports the chunk-amortized per-token
        latency ``n`` times — the histogram stays token-weighted."""
        hist = self._hist(METRIC_SERVE_ITL, "per-token inter-token latency")
        for _ in range(n):
            hist.observe(seconds, tenant=tenant, qos=qos)
        target = self.targets.get(qos, SLOTarget()).itl_s
        for _ in range(n):
            self._attain(seconds, target, METRIC_SLO_ITL_MET,
                         METRIC_SLO_ITL_VIOLATIONS, tenant, qos)

    def e2e(self, seconds: float, tenant: str, qos: str):
        self._hist(METRIC_SERVE_E2E, "submit -> finish").observe(
            seconds, tenant=tenant, qos=qos)

    def _attain(self, seconds, target, met_name, viol_name, tenant, qos):
        if target is None:
            return
        name = met_name if seconds <= target else viol_name
        self.metrics.counter(name, "SLO attainment").inc(
            tenant=tenant, qos=qos)

    # ----------------------------------------------------------- reports ----
    def attainment(self) -> dict[tuple, dict]:
        """(tenant, qos) -> attainment summary for tiers with targets."""
        out: dict[tuple, dict] = {}
        met_t = self.metrics.counter(METRIC_SLO_TTFT_MET)
        viol_t = self.metrics.counter(METRIC_SLO_TTFT_VIOLATIONS)
        met_i = self.metrics.counter(METRIC_SLO_ITL_MET)
        viol_i = self.metrics.counter(METRIC_SLO_ITL_VIOLATIONS)
        keys = set()
        for c in (met_t, viol_t, met_i, viol_i):
            keys.update(tuple(sorted(dict(k).items())) for k in c._vals)
        for key in sorted(keys):
            labels = dict(key)
            out[(labels["tenant"], labels["qos"])] = {
                "ttft_met": met_t.value(**labels),
                "ttft_violations": viol_t.value(**labels),
                "itl_met": met_i.value(**labels),
                "itl_violations": viol_i.value(**labels),
            }
        return out

    def format_report(self) -> str:
        """Per-(tenant, QOS) p50/p95/p99 TTFT & ITL table — the serving
        section of ``sdiag`` and the ``--trace`` end-of-run summary."""
        ttft = self._hist(METRIC_SERVE_TTFT, "")
        itl = self._hist(METRIC_SERVE_ITL, "")
        e2e = self._hist(METRIC_SERVE_E2E, "")
        rows = [f"{'TENANT':<12}{'QOS':<11}{'N':>5} "
                f"{'TTFT p50/p95/p99 (ms)':>24} "
                f"{'ITL p50/p95/p99 (ms)':>23} {'SLO ok':>8}"]
        attain = self.attainment()
        for labels in ttft.label_sets():
            tenant, qos = labels["tenant"], labels["qos"]

            def pct(hist):
                return "/".join(
                    f"{hist.quantile(q, **labels) * 1e3:.1f}"
                    for q in (0.5, 0.95, 0.99))

            a = attain.get((tenant, qos))
            if a:
                total = sum(a.values())
                ok = (a["ttft_met"] + a["itl_met"]) / total if total else 1.0
                slo = f"{ok:.0%}"
            else:
                slo = "n/a"
            rows.append(f"{tenant:<12}{qos:<11}"
                        f"{ttft.count(**labels):>5d} {pct(ttft):>24} "
                        f"{pct(itl):>23} {slo:>8}")
            rows.append(f"{'':<12}{'':<11}{'':>5} "
                        f"e2e {e2e.quantile(0.5, **labels) * 1e3:.1f}/"
                        f"{e2e.quantile(0.99, **labels) * 1e3:.1f}ms "
                        f"(p50/p99)")
        return "\n".join(rows)


class Tracer:
    """Nestable spans over an injectable monotonic clock, with ring-buffer
    retention and Chrome trace-event export.

    One tracer per deployment: the serving engine, admission controller,
    and cluster simulation all write here.  ``clock`` defaults to wall
    ``time.monotonic``; the cluster passes explicit ``ts=`` stamps from
    its virtual clock so simulated jobs land on the same timeline.
    """

    def __init__(self, clock=time.monotonic, max_spans: int = 65536,
                 metrics: Optional[MetricsRegistry] = None,
                 slo_targets: Optional[dict[str, SLOTarget]] = None):
        self.clock = clock
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.slo = SLORecorder(self.metrics, slo_targets)
        self._done: deque[Span] = deque(maxlen=max_spans)
        self._open: dict[int, Span] = {}
        self._sid = itertools.count(1)
        self._lock = threading.Lock()

    # ------------------------------------------------------------- spans ----
    def begin(self, name: str, cat: str = "serving",
              track: tuple = DEFAULT_TRACK,
              parent: Optional[Span] = None,
              ts: Optional[float] = None, **attrs) -> Span:
        """Open a span.  ``parent`` nests it (child inherits the parent's
        track unless one is given explicitly via a non-default value)."""
        if parent is not None and track is DEFAULT_TRACK:
            track = parent.track
        span = Span(sid=next(self._sid), name=name, cat=cat,
                    track=tuple(track),
                    start=self.clock() if ts is None else ts,
                    parent=parent.sid if parent is not None else None,
                    attrs=dict(attrs))
        with self._lock:
            self._open[span.sid] = span
        return span

    def end(self, span: Span, ts: Optional[float] = None, **attrs) -> Span:
        """Close a span: stamp its end, merge attrs, move it from the
        open table into the ring buffer."""
        if span.end is not None:        # idempotent: double-end is a no-op
            return span
        span.end = self.clock() if ts is None else ts
        span.attrs.update(attrs)
        with self._lock:
            self._open.pop(span.sid, None)
            self._done.append(span)
        return span

    @contextmanager
    def span(self, name: str, cat: str = "serving",
             track: tuple = DEFAULT_TRACK, parent: Optional[Span] = None,
             **attrs):
        sp = self.begin(name, cat=cat, track=track, parent=parent, **attrs)
        try:
            yield sp
        finally:
            self.end(sp)

    def event(self, name: str, span: Span, ts: Optional[float] = None,
              **attrs):
        """Instant event attached to a span (rendered as an 'i' marker)."""
        span.events.append(SpanEvent(
            name, self.clock() if ts is None else ts, dict(attrs)))

    # ----------------------------------------------------------- queries ----
    def spans(self, name: Optional[str] = None, cat: Optional[str] = None,
              track: Optional[tuple] = None) -> list[Span]:
        """Completed spans (ring-buffer contents), optionally filtered."""
        with self._lock:
            out = list(self._done)
        if name is not None:
            out = [s for s in out if s.name == name]
        if cat is not None:
            out = [s for s in out if s.cat == cat]
        if track is not None:
            out = [s for s in out if s.track == tuple(track)]
        return out

    def open_spans(self) -> list[Span]:
        with self._lock:
            return list(self._open.values())

    # ------------------------------------------------------------ export ----
    def export_chrome(self, path: Optional[str] = None,
                      include_open: bool = True) -> dict:
        """Chrome trace-event JSON (the format Perfetto/chrome://tracing
        load).  Spans become complete ('X') events; span events become
        instants ('i'); track tuples map to (pid, tid) lanes with
        metadata naming events.  Events are sorted by timestamp, so
        consumers see a monotonically ordered stream.  Returns the dict;
        writes it to ``path`` when given."""
        with self._lock:
            spans = list(self._done)
            if include_open:
                now = self.clock()
                for s in self._open.values():
                    spans.append(Span(s.sid, s.name, s.cat, s.track,
                                      s.start, s.parent,
                                      dict(s.attrs, incomplete=True),
                                      list(s.events), end=now))
        pids: dict[str, int] = {}
        tids: dict[tuple, int] = {}
        meta, events = [], []
        for s in spans:
            proc, thread = s.track[0], s.track
            if proc not in pids:
                pids[proc] = len(pids) + 1
                meta.append({"ph": "M", "name": "process_name",
                             "pid": pids[proc], "tid": 0,
                             "args": {"name": proc}})
            if thread not in tids:
                tids[thread] = len(tids) + 1
                meta.append({"ph": "M", "name": "thread_name",
                             "pid": pids[proc], "tid": tids[thread],
                             "args": {"name": s.track[1]}})
            pid, tid = pids[proc], tids[thread]
            args = {k: v for k, v in s.attrs.items()}
            args["sid"] = s.sid
            if s.parent is not None:
                args["parent_sid"] = s.parent
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": round(s.start * 1e6, 3),
                "dur": round(max(s.duration, 0.0) * 1e6, 3),
                "pid": pid, "tid": tid, "args": args,
            })
            for ev in s.events:
                events.append({
                    "name": ev.name, "cat": s.cat, "ph": "i",
                    "ts": round(ev.ts * 1e6, 3), "pid": pid, "tid": tid,
                    "s": "t", "args": dict(ev.attrs, span_sid=s.sid),
                })
        events.sort(key=lambda e: (e["ts"], e.get("dur", 0.0) * -1))
        data = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(data, f)
        return data
