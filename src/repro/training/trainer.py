"""Trainer: the paper's §5.2.4 ``train.py`` workload, production-shaped —
data pipeline -> jitted train step -> metrics -> periodic checkpoints, with
resume-from-LATEST (what you want when the scheduler requeues your job after
a node drain).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro import checkpoint as ckpt
from repro.configs.base import InputShape, ModelConfig, RunConfig
from repro.data import DataConfig, PackedStream
from repro.models import init_params
from repro.monitoring import MetricsRegistry
from repro.optim import OptimizerConfig, init_opt_state
from repro.training.train_step import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0              # 0 = no checkpoints
    ckpt_dir: Optional[str] = None
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                 shape: InputShape, opt: OptimizerConfig,
                 tcfg: TrainerConfig,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg, self.run, self.mesh = cfg, run, mesh
        self.shape, self.opt, self.tcfg = shape, opt, tcfg
        self.metrics = metrics or MetricsRegistry()
        self.step_fn = make_train_step(cfg, run, mesh, opt)
        self.data = PackedStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
            global_batch=shape.global_batch, seed=tcfg.seed))
        self.step = 0
        self.params = None
        self.opt_state = None
        self.history: list[dict] = []

    # ------------------------------------------------------------ state ----
    def init_state(self):
        self.params = init_params(self.cfg, self.tcfg.seed)
        self.opt_state = init_opt_state(self.params, self.opt)

    def maybe_resume(self) -> bool:
        d = self.tcfg.ckpt_dir
        if not d:
            return False
        step = ckpt.latest_step(d)
        if step is None:
            return False
        state, ds = ckpt.restore(
            d, {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = state["params"], state["opt"]
        if ds is not None:
            self.data.restore({"doc": int(ds["doc"]), "buf": ds["buf"]})
        self.step = step
        return True

    def save(self):
        if not self.tcfg.ckpt_dir:
            return
        ds = self.data.state()
        ckpt.save(self.tcfg.ckpt_dir, self.step,
                  {"params": self.params, "opt": self.opt_state},
                  data_state={"doc": np.int64(ds["doc"]), "buf": ds["buf"]})

    # ------------------------------------------------------------- loop ----
    def train(self, log=print):
        if self.params is None:
            self.init_state()
            self.maybe_resume()
        tokens_per_step = self.shape.global_batch * self.shape.seq_len
        while self.step < self.tcfg.steps:
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.next_batch().items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, m = self.step_fn(
                self.params, self.opt_state, batch)
            m = {k: float(v) for k, v in m.items()}
            dt = time.perf_counter() - t0
            self.step += 1
            self.metrics.gauge("train_loss").set(m["loss"])
            self.metrics.gauge("train_grad_norm").set(m["grad_norm"])
            self.metrics.counter("train_tokens").inc(tokens_per_step)
            self.metrics.histogram("train_step_seconds").observe(dt)
            self.history.append({"step": self.step, **m, "sec": dt})
            if self.step % self.tcfg.log_every == 0 or \
                    self.step == self.tcfg.steps:
                log(f"step {self.step:5d}  loss {m['loss']:.4f}  "
                    f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
                    f"{tokens_per_step / dt:,.0f} tok/s")
            if self.tcfg.ckpt_every and \
                    self.step % self.tcfg.ckpt_every == 0:
                self.save()
        return self.history
