"""Train-step builder: loss + grad (+ microbatch accumulation, the paper's
gradient-accumulation knob), ZeRO grad constraints, AdamW update — jitted
with explicit in/out shardings for a given (config, run, mesh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, RunConfig
from repro.core import sharding as shd
from repro.core import zero
from repro.core.actshard import activation_sharding
from repro.models import abstract_params, input_specs
from repro.models.model import loss_fn
from repro.optim import (
    OptimizerConfig, abstract_opt_state, adamw_update,
)

METRIC_KEYS = ("loss", "xent", "moe_balance_loss", "moe_z_loss",
               "grad_norm", "lr")


def _split_micro(batch: dict, n: int, mesh: Mesh, baxes) -> dict:
    """(B, ...) -> (n, B//n, ...) on every batch leaf.

    The reshape must be re-constrained to keep the microbatch dim
    replicated and the batch dim sharded — unconstrained, GSPMD propagates
    a layout that makes every microbatch recompute at full-batch cost
    (measured 2x flops on a toy; see EXPERIMENTS.md §Perf notes).
    """
    def sp(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        xm = x.reshape((n, B // n) + x.shape[1:])
        spec = P(None, baxes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            xm, NamedSharding(mesh, spec))
    return {k: sp(v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                    opt: OptimizerConfig):
    """Returns f(params, opt_state, batch) -> (params, opt_state, metrics)."""

    act_rules = shd.make_activation_rules(cfg, mesh, run)
    p_sh_inner = shd.param_shardings(cfg, mesh, run)

    def step(params, opt_state, batch):
        with activation_sharding(act_rules):
            return _step(params, opt_state, batch)

    def _loss_params(params):
        """Beyond-paper (run.gather_bf16, §Perf): cast the f32 master
        shards to bf16 BEFORE the ZeRO-3 all-gather — the cast is local to
        the shard, so the gather moves half the bytes.  The constraint pins
        the bf16 copy to the sharded layout so XLA can't hoist the cast
        past the gather."""
        if not run.gather_bf16:
            return params
        return jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(
                p.astype(jnp.bfloat16), s) if p.dtype == jnp.float32 else p,
            params, p_sh_inner)

    def _step(params, opt_state, batch):
        n = run.microbatches

        def _loss(p, mb):
            return loss_fn(_loss_params(p), mb, cfg, run)

        if n == 1:
            (loss, metrics), grads = jax.value_and_grad(
                _loss, has_aux=True)(params, batch)
        else:
            from repro.core.parallelism import get_strategy
            baxes = shd.batch_partition(
                mesh, batch[next(iter(batch))].shape[0] // n,
                get_strategy(run.strategy))
            micro = _split_micro(batch, n, mesh, baxes)

            def body(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(_loss, has_aux=True)(
                    params, mb)
                if run.grad_reduce_bf16:
                    # cast BEFORE the layout constraint so the cross-data
                    # reduction moves bf16, not f32 (§Perf-2)
                    g = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
                g = jax.tree.map(
                    lambda x, s: jax.lax.with_sharding_constraint(x, s),
                    g, g_sh)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                m_acc = {k: m_acc[k] + m[k] for k in m_acc}
                return (g_acc, m_acc), None

            # accumulate in the ZeRO grad layout from step one — an
            # unconstrained f32 accumulator replicates (12.7 GB/device on
            # starcoder2-3b; see EXPERIMENTS.md §Perf).  grad_reduce_bf16
            # also accumulates in bf16 (~1 extra bit of rounding over 16
            # microbatches; halves the accumulator's 8.2 GB/device on
            # dbrx-132b — §Perf-3).
            acc_dt = (jnp.bfloat16 if run.grad_reduce_bf16
                      else jnp.float32)
            g_sh = zero.grad_shardings(cfg, mesh, run)
            g0 = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, acc_dt), s), params, g_sh)
            m0 = {k: jnp.zeros((), jnp.float32)
                  for k in ("loss", "xent", "moe_balance_loss", "moe_z_loss")}
            if run.unroll:
                carry = (g0, m0)
                for i in range(n):
                    carry, _ = body(carry,
                                    {k: v[i] for k, v in micro.items()})
                grads, metrics = carry
            else:
                (grads, metrics), _ = jax.lax.scan(
                    body, (g0, m0), jax.tree.map(lambda x: x, micro))
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = {k: v / n for k, v in metrics.items()}

        grads = zero.constrain_grads(grads, cfg, mesh, run)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    p_sh = shd.param_shardings(cfg, mesh, run)
    o_sh = {
        "m": shd.opt_shardings(cfg, mesh, run),
        "v": shd.opt_shardings(cfg, mesh, run),
        "step": shd.replicated(mesh),
    }
    metric_sh = {k: shd.replicated(mesh) for k in METRIC_KEYS}
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, metric_sh),
        donate_argnums=(0, 1),
    )


def train_step_lowering_args(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                             shape: InputShape, opt: OptimizerConfig):
    """Abstract (params, opt_state, batch) for ``.lower()`` — no allocation."""
    ap = abstract_params(cfg)
    ao = abstract_opt_state(ap, opt)
    specs = input_specs(cfg, shape)
    b_sh = shd.batch_shardings(cfg, mesh, run, specs)
    batch = {
        k: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=b_sh[k])
        for k, s in specs.items()
    }
    return ap, ao, batch
