from repro.training.train_step import (
    make_train_step,
    train_step_lowering_args,
)
from repro.training.trainer import Trainer, TrainerConfig

__all__ = ["make_train_step", "train_step_lowering_args", "Trainer",
           "TrainerConfig"]
