"""Pallas TPU flash attention (causal + sliding window, GQA-aware).

TPU adaptation of the paper's §3.1.2 insight (keep the hot working set in
on-chip memory): instead of CUDA shared-memory tiles, we block HBM→VMEM with
``BlockSpec`` and keep the online-softmax running statistics in VMEM scratch
across the innermost grid dimension.  The MXU sees (block_q × head_dim) @
(head_dim × block_k) matmuls with 128-aligned dims.

Grid: ``(batch, q_heads, num_q_blocks, num_kv_blocks)`` — the kv dimension is
innermost and sequential on TPU, so the scratch accumulator carries across kv
blocks of one q block.  GQA is handled in the k/v index_map (kv head =
q_head // group_size) — no materialized head broadcast, which is exactly the
HBM-traffic win GQA exists for.

Layout contract (from ops.py): q (B, H, S, Dh), k/v (B, K, S, Dh),
out (B, H, S, Dh).  Causal masking assumes q and kv positions both start
at 0 (self-attention over the same sequence).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 block_q: int, block_k: int, num_kv_blocks: int,
                 window, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kv_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kv_pos <= q_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window

    # skip fully-masked blocks (still executed on TPU grid, but cheap via when)
    block_needed = ki * block_k <= qi * block_q + block_q - 1
    if window is not None:
        # earliest kv this q block can see: q_start - window + 1
        block_needed = jnp.logical_and(
            block_needed,
            (ki + 1) * block_k - 1 >= qi * block_q - window + 1)

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, Dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, Dh)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, Dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                            # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        # rows with no visible kv (shouldn't happen causally) -> zeros
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention_bhsd(q, k, v, *, window=None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False):
    """q: (B,H,S,Dh); k/v: (B,K,S,Dh) with H % K == 0.  Causal."""
    B, H, S, Dh = q.shape
    K = k.shape[1]
    assert H % K == 0, (H, K)
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = Dh ** -0.5

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, Dh), q.dtype),
        scratch_shapes=[
            # fp32 online-softmax state in VMEM, persistent across the kv dim
            pltpu.VMEM((block_q, Dh), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
