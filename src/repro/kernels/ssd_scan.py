"""Pallas TPU kernel for the Mamba-2 SSD chunked scan. [arXiv:2405.21060]

TPU adaptation: the CUDA Mamba kernel leans on warp-level scans; the TPU
version instead exploits the *state-space duality* directly — within a chunk
the quadratic "attention-like" form runs on the MXU ((Q,N)@(N,Q) and
(Q,Q)@(Q,P) matmuls), while the inter-chunk recurrence is carried in a VMEM
scratch state of shape (P, N) across the sequential innermost grid dimension.
That is the natural systolic mapping of the SSD algorithm: big dense matmuls
per chunk, O(1)-size carry between chunks.

Grid: ``(batch, heads, num_chunks)`` — chunks innermost/sequential; the
scratch ``state`` persists across the chunk dimension for one (b, h).

Layout contract (from ops.py):
  x  (B, H, S, P)   head inputs
  dt (B, H, S)      post-softplus step sizes (fp32)
  a  (B, H, S)      dt * A  (fp32, precomputed — avoids scalar refs)
  Bm (B, S, N)      input projection (shared across heads)
  Cm (B, S, N)      output projection
  y  (B, H, S, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q,)
    dA = a_ref[0, 0].astype(jnp.float32)       # (Q,)  = dt * A  (<= 0)
    Bm = b_ref[0].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)          # (Q, N)

    dA_cs = jnp.cumsum(dA)                     # (Q,)
    xdt = x * dt[:, None]                      # (Q, P)

    # ---- intra-chunk quadratic (MXU) ----
    seg = dA_cs[:, None] - dA_cs[None, :]      # (Q, Q)
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(causal, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # (Q, Q)
    y_diag = jax.lax.dot_general(
        scores * L, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # (Q, P)

    # ---- contribution of the carried state ----
    state = state_ref[...]                     # (P, N)
    state_decay = jnp.exp(dA_cs)               # (Q,)
    y_off = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * state_decay[:, None]  # (Q, P)

    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # ---- state update for the next chunk ----
    chunk_decay = jnp.exp(dA_cs[-1])
    in_decay = jnp.exp(dA_cs[-1] - dA_cs)      # (Q,)
    new_state = jax.lax.dot_general(
        xdt * in_decay[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # (P, N)
    state_ref[...] = state * chunk_decay + new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bhsp(x, dt, a, Bm, Cm, *, chunk: int = 256,
                  interpret: bool = False):
    """x: (B,H,S,P); dt/a: (B,H,S); Bm/Cm: (B,S,N).  S % chunk == 0."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    grid = (B, H, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((P, N), jnp.float32),   # carried SSD state
        ],
        interpret=interpret,
    )(x, dt, a, Bm, Cm)
