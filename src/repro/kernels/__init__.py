"""Pallas TPU kernels for the compute hot-spots (flash attention, SSD scan),
with jit'd wrappers (``ops``) and pure-jnp oracles (``ref``).

Validated with ``interpret=True`` on CPU; compiled with VMEM BlockSpec
tiling on TPU.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssd_scan import ssd_scan_bhsp

__all__ = ["ops", "ref", "flash_attention_bhsd", "ssd_scan_bhsp"]
