"""Pallas TPU flash-decode: single-query attention against a long KV cache.

Decode is the inverse regime of prefill: ONE query per sequence, thousands
of KV lines.  The prefill kernel's q-block grid collapses to a single row,
so the parallelism has to come from the KV axis instead — the classic
*split-KV* flash-decode trick:

  * the KV sequence is split into ``block_k`` chunks across the grid; each
    grid cell computes an **online-softmax partial** over its chunk — the
    unnormalized accumulator ``acc = exp(s - m) @ v``, the chunk max ``m``
    and the chunk sum ``l`` — entirely in VMEM;
  * a cheap **cross-block combine** (O(num_chunks), pure jnp in the
    wrapper) rescales the partials to the global max and normalizes:
    ``out = Σ acc_c·exp(m_c - m*) / Σ l_c·exp(m_c - m*)``.

Chunks are independent, so nothing is carried across grid cells — on
hardware with a parallel KV grid dimension every chunk runs concurrently,
which is what keeps decode latency flat as the cache grows.

GQA-aware like ``flash_attention.py``: the grid iterates KV heads and each
cell processes all G query heads sharing that KV head as one (G, Dh)
block — the KV chunk is fetched from HBM once per group, not once per
query head.

Validity is a per-sequence position: the cache holds ``S`` slots of which
``[0, pos_b]`` are live (the serving engine's non-ring full cache — slot i
holds absolute position i).  ``pos`` rides in as a scalar-prefetch operand
so the mask is computed from SMEM, not HBM.

**Sliding-window ring caches** (``window`` set): slot ``i`` no longer
holds absolute position ``i`` but the *latest* written position congruent
to ``i`` — ``slot_pos = pos - ((pos - i) mod slots)``.  The mask becomes a
second masked range over that wrapped position map (written at all, and
within the window), computed from the same SMEM scalars; the split-KV
math is otherwise unchanged, so ring decode keeps the flat-latency
property of the full-cache kernel.

Layout contract (from ops.py): q (B, K, G, Dh) grouped queries;
k/v (B, K, S, Dh); pos (B,) int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
_NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                   block_k: int, scale: float, window=None, slots=None):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    pos = pos_ref[b]

    q = q_ref[0, 0].astype(jnp.float32)               # (G, Dh)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, Dh)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, Dh)
    G = q.shape[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (G, bk)
    kv_slot = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (G, block_k), 1)
    if window is None:
        # full cache: slot i holds absolute position i
        mask = kv_slot <= pos
    else:
        # ring: slot i holds the latest position congruent to i.  The
        # floor-mod keeps slot_pos in (pos - slots, pos], so only "ever
        # written" (>= 0) and "inside the window" need checking — the
        # second masked range of the wrapped slot -> position map
        slot_pos = pos - ((pos - kv_slot) % slots)
        mask = (slot_pos >= 0) & ((pos - slot_pos) < window)
    s = jnp.where(mask, s, _NEG_INF)

    m = jnp.max(s, axis=-1)                           # (G,)
    p = jnp.exp(s - m[:, None])
    p = jnp.where(mask, p, 0.0)                       # fully-masked chunk: 0
    l = jnp.sum(p, axis=-1)                           # (G,)
    acc = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (G, Dh)

    o_ref[0, 0, 0] = acc
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l


@functools.partial(jax.jit,
                   static_argnames=("block_k", "interpret", "window"))
def flash_decode_bkgd(q, k, v, pos, *, block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = False, window=None):
    """q: (B, K, G, Dh); k/v: (B, K, S, Dh); pos: (B,) int32 — each
    sequence attends kv slots [0, pos_b].  Returns (B, K, G, Dh).

    ``window`` (static) marks k/v as a sliding-window RING of S slots
    (slot = position mod S, S = min(cache_len, window)): sequence b then
    attends the wrapped slots holding positions (pos_b - window, pos_b]."""
    B, K, G, Dh = q.shape
    S = k.shape[2]
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    scale = Dh ** -0.5

    kernel = functools.partial(_decode_kernel, block_k=block_k, scale=scale,
                               window=window,
                               slots=(S if window is not None else None))
    o_part, m_part, l_part = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, K, nk),
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh),
                             lambda b, h, ki, pos: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, Dh),
                             lambda b, h, ki, pos: (b, h, ki, 0)),
                pl.BlockSpec((1, 1, block_k, Dh),
                             lambda b, h, ki, pos: (b, h, ki, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, G, Dh),
                             lambda b, h, ki, pos: (b, h, ki, 0, 0)),
                pl.BlockSpec((1, 1, 1, G),
                             lambda b, h, ki, pos: (b, h, ki, 0)),
                pl.BlockSpec((1, 1, 1, G),
                             lambda b, h, ki, pos: (b, h, ki, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, K, nk, G, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, K, nk, G), jnp.float32),
            jax.ShapeDtypeStruct((B, K, nk, G), jnp.float32),
        ],
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k, v)

    # cross-block combine: rescale every chunk's partial to the global max
    m_star = jnp.max(m_part, axis=2, keepdims=True)          # (B, K, 1, G)
    w = jnp.exp(m_part - m_star)                             # (B, K, nk, G)
    num = jnp.sum(o_part * w[..., None], axis=2)             # (B, K, G, Dh)
    den = jnp.sum(l_part * w, axis=2)                        # (B, K, G)
    den = jnp.where(den == 0.0, 1.0, den)
    return (num / den[..., None]).astype(q.dtype)


# ----------------------------------------------------------- paged cache ----
#
# Same split-KV scheme against a PAGED pool: the KV lines live in
# (num_pages, page_size) physical pages and each sequence's logical chunk
# ``pi`` resolves through a scalar-prefetched page table.  The page table
# rides in SMEM, so the *BlockSpec index_map itself* does the indirection —
# grid cell (b, h, pi) DMAs physical page ``pt[b, pi]`` from HBM.  The
# kernel body is the dense one: logical positions are ``pi*ps + iota``
# regardless of which physical page backs them, so masking (and therefore
# numerics) is identical to the dense split-KV kernel with block_k = ps.
# Unallocated tails resolve to the null page and are fully masked.


def _paged_decode_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, *, page_size: int, scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    pos = pos_ref[b]

    q = q_ref[0, 0].astype(jnp.float32)               # (G, Dh)
    k = k_ref[0, 0].astype(jnp.float32)               # (ps, Dh)
    v = v_ref[0, 0].astype(jnp.float32)               # (ps, Dh)
    G = q.shape[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (G, ps)
    kv_pos = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (G, page_size), 1)
    mask = kv_pos <= pos
    s = jnp.where(mask, s, _NEG_INF)

    m = jnp.max(s, axis=-1)                           # (G,)
    p = jnp.exp(s - m[:, None])
    p = jnp.where(mask, p, 0.0)                       # fully-masked page: 0
    l = jnp.sum(p, axis=-1)                           # (G,)
    acc = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (G, Dh)

    o_ref[0, 0, 0] = acc
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged_bkgd(q, k, v, page_table, pos, *,
                            interpret: bool = False):
    """Split-KV decode against a paged pool.

    q: (B, K, G, Dh) grouped queries; k/v: (K, num_pages, page_size, Dh)
    pools; page_table: (B, n_pages) int32 (logical page -> physical page,
    0 = null page); pos: (B,) int32 — sequence b attends logical KV
    positions [0, pos_b].  Returns (B, K, G, Dh).

    One grid cell per (sequence, kv head, logical page); each fetches its
    physical page via the prefetched table and emits an online-softmax
    partial, combined across pages in jnp exactly like the dense kernel.
    """
    B, K, G, Dh = q.shape
    page_size = k.shape[2]
    n_pages = page_table.shape[1]
    scale = Dh ** -0.5

    kernel = functools.partial(_paged_decode_kernel, page_size=page_size,
                               scale=scale)
    o_part, m_part, l_part = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, K, n_pages),
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh),
                             lambda b, h, pi, pt, pos: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, page_size, Dh),
                             lambda b, h, pi, pt, pos: (h, pt[b, pi], 0, 0)),
                pl.BlockSpec((1, 1, page_size, Dh),
                             lambda b, h, pi, pt, pos: (h, pt[b, pi], 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, G, Dh),
                             lambda b, h, pi, pt, pos: (b, h, pi, 0, 0)),
                pl.BlockSpec((1, 1, 1, G),
                             lambda b, h, pi, pt, pos: (b, h, pi, 0)),
                pl.BlockSpec((1, 1, 1, G),
                             lambda b, h, pi, pt, pos: (b, h, pi, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, K, n_pages, G, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, K, n_pages, G), jnp.float32),
            jax.ShapeDtypeStruct((B, K, n_pages, G), jnp.float32),
        ],
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32), q, k, v)

    m_star = jnp.max(m_part, axis=2, keepdims=True)          # (B, K, 1, G)
    w = jnp.exp(m_part - m_star)                             # (B, K, np, G)
    num = jnp.sum(o_part * w[..., None], axis=2)             # (B, K, G, Dh)
    den = jnp.sum(l_part * w, axis=2)                        # (B, K, G)
    den = jnp.where(den == 0.0, 1.0, den)
    return (num / den[..., None]).astype(q.dtype)
