"""Pure-jnp oracles for the Pallas kernels.

These intentionally re-derive the math independently of the model code's
blockwise implementations (``repro.models.attention.causal_attention`` is
itself chunked) so kernel tests compare against the most naive possible
formulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, window=None):
    """Naive causal (+ sliding window) attention.

    q: (B,S,H,Dh); k/v: (B,S,K,Dh), H % K == 0.  fp32 softmax.
    """
    B, S, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * (Dh ** -0.5)
    pos = jnp.arange(S)
    mask = pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= (pos[:, None] - pos[None, :]) < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k, v, pos):
    """Naive single-query decode attention (the flash-decode oracle).

    q: (B,1,H,Dh); k/v: (B,S,K,Dh) with H % K == 0 (slot i holds absolute
    position i); pos: (B,) int32 — sequence b attends slots [0, pos_b].
    fp32 softmax.
    """
    B, S, K, Dh = k.shape
    H = q.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * (Dh ** -0.5)
    valid = jnp.arange(S)[None, :] <= pos[:, None]           # (B, S)
    logits = jnp.where(valid[:, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, vv.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_decode_attention_ref(q, k, v, page_table, pos):
    """Naive paged decode attention (the paged flash-decode oracle).

    q: (B,1,H,Dh); k/v: (num_pages, page_size, K, Dh) shared pool;
    page_table: (B, n_pages) int32 (0 = null page); pos: (B,) int32.
    Gathers the logical (B, n_pages*page_size, K, Dh) view through the
    page table, then defers to :func:`decode_attention_ref`.
    """
    B = q.shape[0]
    n_pages = page_table.shape[1]
    ps = k.shape[1]
    K, Dh = k.shape[2], k.shape[3]
    kd = k[page_table].reshape(B, n_pages * ps, K, Dh)
    vd = v[page_table].reshape(B, n_pages * ps, K, Dh)
    return decode_attention_ref(q, kd, vd, pos)


def ssd_ref(x, dt, A, Bm, Cm):
    """Naive sequential SSD recurrence (token-by-token, exact).

    x: (B,S,H,P); dt: (B,S,H) post-softplus; A: (H,);
    Bm/Cm: (B,S,N).  Returns y: (B,S,H,P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(state, inp):
        xt, dtt, bt, ct = inp                    # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * A.astype(f32))     # (B,H)
        dBx = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        state = state * decay[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    s0 = jnp.zeros((Bsz, H, P, N), f32)
    xs = (x.astype(f32).transpose(1, 0, 2, 3), dt.astype(f32).transpose(1, 0, 2),
          Bm.astype(f32).transpose(1, 0, 2), Cm.astype(f32).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
