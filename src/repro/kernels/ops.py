"""jit'd public wrappers around the Pallas kernels.

Model code calls these with model-layout tensors; the wrappers transpose to
kernel layout, pick hardware-aligned block sizes, and run the kernel —
``interpret=True`` on CPU (this container), compiled on real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.flash_decode import flash_decode_bkgd, flash_decode_paged_bkgd
from repro.kernels.ssd_scan import ssd_scan_bhsp


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pick_block(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (prefers multiples of 128)."""
    b = min(target, S)
    while S % b:
        b -= 1
    return b


def flash_attention(q, k, v, window=None, block_q: int = 512,
                    block_k: int = 512, interpret=None):
    """Model-layout wrapper.  q: (B,S,H,Dh); k/v: (B,S,K,Dh) -> (B,S,H,Dh)."""
    if interpret is None:
        interpret = _on_cpu()
    S = q.shape[1]
    bq = _pick_block(S, block_q)
    bk = _pick_block(S, block_k)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(qt, kt, vt, window=window, block_q=bq,
                             block_k=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)


def flash_decode(q, k, v, pos, block_k: int = 512, interpret=None,
                 window=None):
    """Model-layout wrapper for single-query decode attention.

    q: (B, 1, H, Dh) roped query; k/v: (B, S, K, Dh) KV cache (slot i =
    absolute position i, H % K == 0); pos: (B,) int32 — attends slots
    [0, pos_b].  Returns (B, 1, H, Dh).

    ``window`` (static int) marks k/v as a sliding-window ring of S =
    min(cache_len, window) slots (slot = position mod S): sequence b
    attends only positions (pos_b - window, pos_b] through the wrapped
    slot map.
    """
    if interpret is None:
        interpret = _on_cpu()
    B, _, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    S = k.shape[1]
    bk = _pick_block(S, block_k)
    qg = q[:, 0].reshape(B, K, G, Dh)                # grouped like the model
    kt = k.transpose(0, 2, 1, 3)                     # (B, K, S, Dh)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_decode_bkgd(qg, kt, vt, pos, block_k=bk, interpret=interpret,
                          window=window)
    return o.reshape(B, H, Dh)[:, None]


def flash_decode_paged(q, k, v, page_table, pos, interpret=None):
    """Model-layout wrapper for paged single-query decode attention.

    q: (B, 1, H, Dh) roped query; k/v: (num_pages, page_size, K, Dh)
    shared page pool (H % K == 0); page_table: (B, n_pages) int32 mapping
    logical to physical pages (0 = null page); pos: (B,) int32 — attends
    logical positions [0, pos_b].  Returns (B, 1, H, Dh).
    """
    if interpret is None:
        interpret = _on_cpu()
    B, _, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q[:, 0].reshape(B, K, G, Dh)                # grouped like the model
    kt = k.transpose(2, 0, 1, 3)                     # (K, num_pages, ps, Dh)
    vt = v.transpose(2, 0, 1, 3)
    o = flash_decode_paged_bkgd(qg, kt, vt, page_table, pos,
                                interpret=interpret)
    return o.reshape(B, H, Dh)[:, None]


def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 256, interpret=None):
    """Model-layout wrapper.  x: (B,S,H,P); dt: (B,S,H); A: (H,);
    Bm/Cm: (B,S,N) -> y: (B,S,H,P)."""
    if interpret is None:
        interpret = _on_cpu()
    B, S, H, P = x.shape
    Q = _pick_block(S, chunk)
    xt = x.transpose(0, 2, 1, 3)                     # (B,H,S,P)
    dtt = dt.astype(jnp.float32).transpose(0, 2, 1)  # (B,H,S)
    a = dtt * A.astype(jnp.float32)[None, :, None]   # (B,H,S)
    y = ssd_scan_bhsp(xt, dtt, a, Bm, Cm, chunk=Q, interpret=interpret)
    return y.transpose(0, 2, 1, 3)
