"""The decayed TRES usage ledger: one shared accounting of who consumed
what, across every execution engine.

:class:`FairShareTree` extends the association tree with a usage ledger:
every finished (or preempted) batch-job segment charges its account
``elapsed × TRES-cost``, and every served token / KV-cache-second charges
the same ledger through :meth:`FairShareTree.charge_tres` — so a single
``sshare`` call reports batch *and* serving consumption against one set
of shares.  The cost weights accelerator-seconds far above CPU/mem
(``TRESBillingWeights``).  Usage decays with an exponential half-life
(``PriorityDecayHalfLife``), so yesterday's hog is not punished forever.
Charges propagate to all ancestors.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.policy.accounts import Account, AccountTree
from repro.policy.qos import job_tres

#: TRESBillingWeights — accelerator-seconds dominate the charge.
DEFAULT_TRES_WEIGHTS = {
    "gres/tpu": 1.0,
    "gres/gpu": 1.0,
    "cpu": 0.04,
    "mem": 1e-5,          # per MB-second
}


class FairShareTree(AccountTree):
    """Account hierarchy + decayed TRES usage ledger."""

    def __init__(self, half_life_s: float = 7 * 86_400.0,
                 tres_weights: Optional[dict] = None):
        assert half_life_s > 0
        super().__init__()
        self.half_life_s = half_life_s
        self.tres_weights = dict(tres_weights or DEFAULT_TRES_WEIGHTS)
        self.usage: dict[str, float] = {"root": 0.0}
        # per-TRES-key raw consumption (same decay as ``usage``): what a
        # tenant actually burned, before billing weights — e.g.
        # ``gres/kv_page`` here is true HBM page-steps held, which is how
        # ``sshare --tres`` reports paged-cache residency per tenant
        self.tres_usage: dict[str, dict[str, float]] = {}
        self._last_decay: float = 0.0
        self._clock: Optional[Callable[[], float]] = None

    # ------------------------------------------------------------- admin ----
    def add_account(self, name: str, parent: str = "root",
                    shares: int = 1, description: str = "") -> Account:
        acct = super().add_account(name, parent=parent, shares=shares,
                                   description=description)
        self.usage.setdefault(name, 0.0)
        return acct

    # ------------------------------------------------------------- usage ----
    def enable_wallclock_decay(self, clock: Callable[[], float]
                               = time.monotonic):
        """Drive decay from a wall clock instead of an engine event loop.

        For long-lived pure-serving deployments: nothing there calls
        ``decay_to``, so without this an old hog's usage never decays and
        it is punished forever.  The ledger's decay epoch is re-anchored
        to ``clock()`` now (usage accrued so far starts decaying from
        this instant); afterwards every :meth:`tick` advances decay to
        the current clock reading.  Do NOT enable on a ledger whose decay
        is already driven by a simulated cluster clock — the two
        timebases would mix.
        """
        self._clock = clock
        self._last_decay = float(clock())

    def tick(self):
        """Advance decay to the wall clock, if enabled (no-op otherwise)."""
        if self._clock is not None:
            self.decay_to(self._clock())

    def decay_to(self, now: float):
        """Apply exponential half-life decay up to ``now``."""
        dt = now - self._last_decay
        if dt <= 0:
            return
        factor = 2.0 ** (-dt / self.half_life_s)
        for name in self.usage:
            self.usage[name] *= factor
        for per_key in self.tres_usage.values():
            for key in per_key:
                per_key[key] *= factor
        self._last_decay = now

    def tres_cost_per_s(self, req) -> float:
        """Billing rate of one job-second for this resource request."""
        cost = 0.0
        for key, amount in job_tres(req).items():
            cost += self.tres_weights.get(key, 0.0) * amount
        return cost

    def charge_tres(self, account: str, tres: dict,
                    now: Optional[float] = None,
                    usage_factor: float = 1.0) -> float:
        """Charge a raw TRES vector to the account chain.

        The engine-agnostic charging entry: batch charges job-seconds
        through :meth:`charge`; serving charges generated tokens and
        KV-cache residency here directly.  ``now=None`` charges at the
        ledger's current decay epoch (no decay advance) — right for
        engines without their own clock sharing a ledger whose decay is
        driven elsewhere.  Returns the charged amount (weighted
        TRES units).
        """
        if account not in self.accounts:        # auto-associate unknowns
            self.add_account(account)
        if now is not None:
            self.decay_to(now)
        amount = sum(self.tres_weights.get(key, 0.0) * amt
                     for key, amt in tres.items()) * usage_factor
        for acct in self._ancestors(account):
            self.usage[acct.name] = self.usage.get(acct.name, 0.0) + amount
            per_key = self.tres_usage.setdefault(acct.name, {})
            for key, amt in tres.items():
                if amt:
                    # raw, UNdiscounted: usage_factor is a billing break,
                    # not a consumption reduction — an auditor reading
                    # sshare --tres must see what was actually held
                    per_key[key] = per_key.get(key, 0.0) + amt
        return amount

    def tres_usage_of(self, account: str) -> dict:
        """Decayed raw per-key TRES consumption of one account."""
        return dict(self.tres_usage.get(account, {}))

    def charge(self, account: str, req, elapsed_s: float, now: float,
               usage_factor: float = 1.0) -> float:
        """Charge ``elapsed_s`` of the request's TRES to the account chain.

        Returns the charged amount (weighted TRES-seconds).
        """
        elapsed = max(elapsed_s, 0.0)
        return self.charge_tres(
            account, {k: v * elapsed for k, v in job_tres(req).items()},
            now=now, usage_factor=usage_factor)

    # ----------------------------------------------------------- factors ----
    def norm_usage(self, name: str) -> float:
        total = self.usage.get("root", 0.0)
        if total <= 0:
            return 0.0
        return self.usage.get(name, 0.0) / total

    def fair_share_factor(self, account: str) -> float:
        """The classic SLURM ``2^(-usage/shares)`` in [0, 1]."""
        if account not in self.accounts:
            return 1.0                          # never-seen account: fresh
        shares = self.norm_shares(account)
        if shares <= 0:
            return 0.0
        return 2.0 ** (-self.norm_usage(account) / shares)

    # ---------------------------------------------------------- snapshot ----
    def snapshot(self) -> dict:
        return {
            "half_life_s": self.half_life_s,
            "tres_weights": dict(self.tres_weights),
            "accounts": [(a.name, a.parent, a.shares, a.description)
                         for a in self.accounts.values()],
            "user_account": dict(self.user_account),
            "usage": dict(self.usage),
            "tres_usage": {k: dict(v) for k, v in self.tres_usage.items()},
            "last_decay": self._last_decay,
        }

    @classmethod
    def restore(cls, snap: dict) -> "FairShareTree":
        t = cls(half_life_s=snap["half_life_s"],
                tres_weights=snap["tres_weights"])
        for name, parent, shares, desc in snap["accounts"]:
            if name == "root":
                continue
            t.accounts[name] = Account(name, parent=parent, shares=shares,
                                       description=desc)
        t.user_account = dict(snap["user_account"])
        t.usage = dict(snap["usage"])
        t.tres_usage = {k: dict(v)
                        for k, v in snap.get("tres_usage", {}).items()}
        t._last_decay = snap["last_decay"]
        return t
