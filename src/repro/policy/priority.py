"""Multifactor priority — the classic SLURM composition::

    prio = W_age  * age_factor
         + W_fs   * 2^(-usage/shares)        (the fair-share factor)
         + W_size * job_size_factor
         + W_part * partition_factor
         + W_qos  * qos_factor
         + nice   (the job's static priority)

Starved accounts rise (usage decays toward 0 → factor → 1); dominant
accounts sink (usage ≫ shares → factor → 0).  The convergence property
is proven in ``tests/test_multitenant.py``.

Duck-typed over any workload carrying ``job_id`` / ``account`` / ``qos`` /
``submit_time`` / ``priority`` / ``partition`` / ``req.nodes`` — the batch
scheduler feeds it Jobs; serving admission composes the same fair-share
and QOS terms for requests (see ``repro.serving.admission``).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.policy.qos import QOS
from repro.policy.usage import FairShareTree


@dataclass(frozen=True)
class PriorityWeights:
    """slurm.conf ``PriorityWeight*`` knobs."""
    age: float = 1_000.0
    fairshare: float = 10_000.0
    job_size: float = 500.0
    partition: float = 1_000.0
    qos: float = 2_000.0
    max_age_s: float = 7 * 86_400.0     # PriorityMaxAge


@dataclass(frozen=True)
class PriorityBreakdown:
    """One sprio row: the weighted components and their sum."""
    job_id: int
    age: float
    fairshare: float
    job_size: float
    partition: float
    qos: float
    nice: float

    @property
    def total(self) -> float:
        return (self.age + self.fairshare + self.job_size + self.partition
                + self.qos + self.nice)


class MultifactorPriority:
    """The priority/multifactor plugin: compose factors into one number."""

    def __init__(self, tree: FairShareTree,
                 qos_table: dict[str, QOS],
                 weights: PriorityWeights = PriorityWeights()):
        self.tree = tree
        self.qos_table = qos_table
        self.weights = weights

    def breakdown(self, job, now: float, partitions: dict,
                  cluster_nodes: int) -> PriorityBreakdown:
        w = self.weights
        age = min(max(now - job.submit_time, 0.0) / w.max_age_s, 1.0)
        fs = self.tree.fair_share_factor(job.account)
        size = job.req.nodes / max(cluster_nodes, 1)
        part = partitions[job.partition].priority_tier if job.partition in \
            partitions else 1
        max_tier = max((p.priority_tier for p in partitions.values()),
                       default=1)
        qos = self.qos_table.get(job.qos)
        max_qos = max((q.priority for q in self.qos_table.values()),
                      default=1) or 1
        return PriorityBreakdown(
            job_id=job.job_id,
            age=w.age * age,
            fairshare=w.fairshare * fs,
            job_size=w.job_size * size,
            partition=w.partition * part / max(max_tier, 1),
            qos=w.qos * (qos.priority / max_qos if qos else 0.0),
            nice=float(job.priority),
        )

    def priority(self, job, now: float, partitions: dict,
                 cluster_nodes: int) -> float:
        return self.breakdown(job, now, partitions, cluster_nodes).total

    def priority_fn(self, now: float, partitions: dict, cluster_nodes: int):
        """A ``job -> priority`` callable for one scheduling pass (the
        fair-share factor is frozen at pass start, like SLURM's decay tick).
        """
        cache: dict[int, float] = {}

        def fn(job) -> float:
            p = cache.get(job.job_id)
            if p is None:
                p = self.priority(job, now, partitions, cluster_nodes)
                cache[job.job_id] = p
            return p
        return fn
