"""Engine-agnostic multi-tenancy policy: the account tree, TRES usage
ledger, QOS tiers, and multifactor priority that both the batch scheduler
(`repro.cluster`) and the serving admission controller (`repro.serving`)
consult.

Dependency rule: this package imports nothing from ``repro.cluster`` or
``repro.serving`` — the dependency arrow points inward only.  Jobs,
requests, and partitions are duck-typed (``req.nodes``,
``partition.priority_tier``, ...), so any execution engine can bring its
own workload type and still share one ledger.

Layout (one concern per module):

* :mod:`repro.policy.accounts` — the sacctmgr association tree (accounts,
  shares, users, normalized shares);
* :mod:`repro.policy.usage` — the decayed TRES usage ledger
  (:class:`FairShareTree` = accounts + usage) with billing weights;
* :mod:`repro.policy.priority` — SLURM's priority/multifactor composition
  around the classic ``2^(-usage/shares)`` fair-share factor;
* :mod:`repro.policy.qos` — QOS tiers: priority boosts, GrpTRES caps,
  preemption rules, and the TRES vector helpers.
"""
from repro.policy.accounts import Account, AccountTree
from repro.policy.priority import (
    MultifactorPriority, PriorityBreakdown, PriorityWeights,
)
from repro.policy.qos import (
    GrpTresLedger, PREEMPT_CANCEL, PREEMPT_REQUEUE, QOS, add_tres,
    default_qos_table, format_tres, job_tres, tres_within,
)
from repro.policy.usage import DEFAULT_TRES_WEIGHTS, FairShareTree

__all__ = [
    "Account", "AccountTree", "DEFAULT_TRES_WEIGHTS", "FairShareTree",
    "GrpTresLedger", "MultifactorPriority", "PREEMPT_CANCEL",
    "PREEMPT_REQUEUE",
    "PriorityBreakdown", "PriorityWeights", "QOS", "add_tres",
    "default_qos_table", "format_tres", "job_tres", "tres_within",
]
