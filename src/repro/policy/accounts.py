"""The sacctmgr association tree: accounts, raw shares, user bindings.

A hierarchy of accounts (``root`` → org → team) with raw *shares*; users
associate to exactly one account.  Normalized shares are computed
sibling-relative and multiplied down the tree, exactly like ``sshare``'s
NormShares column.

Pure structure — no usage, no clocks.  The decayed TRES ledger that turns
this tree into a fair-share engine lives in :mod:`repro.policy.usage`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass
class Account:
    """One node of the sacctmgr association tree."""
    name: str
    parent: Optional[str] = "root"      # None only for root itself
    shares: int = 1
    description: str = ""


class AccountTree:
    """Account hierarchy + user associations (the ``sacctmgr`` surface)."""

    def __init__(self):
        self.accounts: dict[str, Account] = {
            "root": Account("root", parent=None, shares=1)}
        self.user_account: dict[str, str] = {}

    # ------------------------------------------------------------- admin ----
    def add_account(self, name: str, parent: str = "root",
                    shares: int = 1, description: str = "") -> Account:
        """``sacctmgr add account <name> parent=<p> fairshare=<shares>``."""
        assert name not in self.accounts, f"account {name!r} exists"
        assert parent in self.accounts, f"unknown parent {parent!r}"
        assert shares >= 1
        acct = Account(name, parent=parent, shares=shares,
                       description=description)
        self.accounts[name] = acct
        return acct

    def add_user(self, user: str, account: str):
        """``sacctmgr add user <u> account=<a>`` (one association/user)."""
        assert account in self.accounts, f"unknown account {account!r}"
        self.user_account[user] = account

    def add_user_association(self, user: str, account: str,
                             shares: int = 1) -> Account:
        """Two-level ``tenant/user`` association (idempotent): a leaf
        account named ``<account>/<user>`` parented under ``account``,
        with the user bound to it.  Charges landed on the leaf propagate
        to the tenant and root like any other subtree, so sibling users
        fair-share *within* their tenant's slice and ``sshare`` renders
        the nesting with no special casing."""
        assert account in self.accounts, f"unknown account {account!r}"
        leaf = f"{account}/{user}"
        acct = self.accounts.get(leaf)
        if acct is None:
            acct = self.add_account(leaf, parent=account, shares=shares)
        self.user_account.setdefault(user, leaf)
        return acct

    def modify_account(self, name: str, shares: Optional[int] = None,
                       parent: Optional[str] = None,
                       description: Optional[str] = None) -> Account:
        """``sacctmgr modify account <name> set fairshare=<n> [parent=<p>]``
        on a live tree.  Normalized shares are computed on read, so every
        priority/sshare pass after this sees the new values — no restart,
        exactly like SLURM's live association edits.  Reparenting refuses
        cycles (an account may not move under its own subtree)."""
        assert name in self.accounts, f"unknown account {name!r}"
        assert name != "root", "cannot modify the root association"
        acct = self.accounts[name]
        if shares is not None:
            assert shares >= 1, shares
            acct.shares = shares
        if parent is not None:
            assert parent in self.accounts, f"unknown parent {parent!r}"
            ancestor = parent
            while ancestor is not None:
                assert ancestor != name, \
                    f"reparenting {name!r} under its own subtree"
                ancestor = self.accounts[ancestor].parent
            acct.parent = parent
        if description is not None:
            acct.description = description
        return acct

    def account_of(self, user: str, default: str = "root") -> str:
        return self.user_account.get(user, default)

    def children(self, name: str) -> list[Account]:
        return [a for a in self.accounts.values() if a.parent == name]

    def _ancestors(self, name: str):
        """name, parent, ..., root."""
        while name is not None:
            acct = self.accounts[name]
            yield acct
            name = acct.parent

    # ----------------------------------------------------------- factors ----
    def norm_shares(self, name: str) -> float:
        """Sibling-relative shares multiplied down from root (sshare col)."""
        assert name in self.accounts, f"unknown account {name!r}"
        frac = 1.0
        for acct in self._ancestors(name):
            if acct.parent is None:
                break
            level = sum(a.shares for a in self.children(acct.parent))
            frac *= acct.shares / max(level, 1)
        return frac
