"""QOS tiers: priority boosts, TRES limits, and preemption policy.

SLURM's Quality-of-Service layer (``sacctmgr show qos``) is what turns one
physical cluster into several virtual service classes.  Each QOS carries:

* ``priority`` — a boost folded into the multifactor priority;
* ``preempt`` — the set of QOS names whose running work this QOS may evict
  when it cannot otherwise start (SLURM ``Preempt=``).  The batch
  scheduler evicts whole jobs; the serving admission controller evicts
  decode slots — same rule, either engine;
* ``preempt_mode`` — how work *of this QOS* is treated when evicted
  (``requeue``: back to PENDING keeping checkpointed progress;
  ``cancel``: killed outright);
* ``grp_tres`` — GrpTRES-style cap on the TRES an *account* may hold
  concurrently through this QOS (e.g. scavenger capped at 16 TPUs/account,
  or a serving tenant capped at 2 decode slots);
* ``usage_factor`` — fair-share charge multiplier (scavenger cycles are
  discounted, mirroring SLURM ``UsageFactor``).

The default catalogue models the three tiers most LLM clusters run:
``high`` (paid/production, may preempt), ``normal``, and ``scavenger``
(free-for-all on idle capacity, first to be evicted).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

PREEMPT_REQUEUE = "requeue"
PREEMPT_CANCEL = "cancel"


@dataclass(frozen=True)
class QOS:
    """One named service tier."""
    name: str
    priority: int = 0                    # folded into multifactor priority
    preempt: tuple[str, ...] = ()        # QOS names this tier may evict
    preempt_mode: str = PREEMPT_REQUEUE  # how *this* tier's jobs are evicted
    grp_tres: dict = field(default_factory=dict)   # {"gres/tpu": 16} per acct
    max_wall_s: Optional[int] = None     # per-job wall cap (tighter of QOS
    usage_factor: float = 1.0            # fair-share charge multiplier

    def __post_init__(self):
        assert self.preempt_mode in (PREEMPT_REQUEUE, PREEMPT_CANCEL)
        assert self.usage_factor >= 0.0

    def can_preempt(self, victim_qos: str) -> bool:
        return victim_qos in self.preempt


def default_qos_table() -> dict[str, QOS]:
    """The stock high/normal/scavenger catalogue."""
    return {
        "high": QOS("high", priority=1000, preempt=("normal", "scavenger")),
        "normal": QOS("normal", priority=500, preempt=("scavenger",)),
        "scavenger": QOS("scavenger", priority=0, usage_factor=0.25,
                         preempt_mode=PREEMPT_REQUEUE),
    }


def job_tres(req, tres_weights: Optional[dict] = None) -> dict[str, float]:
    """A job's total TRES vector (across all its nodes).

    Keys follow sacctmgr syntax: ``cpu``, ``mem`` (MB), ``gres/<name>``.
    Duck-typed over any request carrying ``nodes`` / ``cpus_per_node`` /
    ``mem_mb_per_node`` / ``gres_per_node``.
    """
    out = {"cpu": float(req.cpus_per_node * req.nodes),
           "mem": float(req.mem_mb_per_node * req.nodes)}
    for g, n in req.gres_per_node.items():
        out[f"gres/{g}"] = float(n * req.nodes)
    return out


def tres_within(usage: dict, extra: dict, limit: dict) -> bool:
    """Would ``usage + extra`` stay under ``limit`` (only limited keys)?"""
    for key, cap in limit.items():
        if usage.get(key, 0.0) + extra.get(key, 0.0) > cap + 1e-9:
            return False
    return True


def add_tres(into: dict, tres: dict, scale: float = 1.0) -> dict:
    for key, amt in tres.items():
        into[key] = into.get(key, 0.0) + amt * scale
    return into


class GrpTresLedger:
    """Shared GrpTRES holdings across admission controllers.

    SLURM's GrpTRES caps bind at the *association*, not per slurmctld
    thread — a 2-slot scavenger cap means 2 slots on the whole cluster.
    With N serving replicas, each replica's admission controller tracks
    its own physical slots/pages; this ledger is the shared view they
    write through so `_over_cap` checks the account's total across every
    replica.  Holdings are keyed ``(account, qos)`` and clamped at zero
    (release after a drain must not go negative).

    Scope is the policy knob: the router wires ONE ledger into all
    replica controllers (``grp_scope="global"``); omit it and each
    controller falls back to its private per-replica counters —
    GrpTRES × N, the pre-elastic behaviour.
    """

    def __init__(self):
        self._held: dict[tuple[str, str], dict[str, float]] = {}

    def adjust(self, account: str, qos: str, tres: dict):
        held = self._held.setdefault((account, qos), {})
        for key, amt in tres.items():
            held[key] = max(held.get(key, 0.0) + amt, 0.0)

    def held(self, account: str, qos: str) -> dict[str, float]:
        return dict(self._held.get((account, qos), {}))


def format_tres(tres: dict) -> str:
    """``cpu=8,mem=8192M,gres/tpu=16`` (sacctmgr-style)."""
    parts = []
    for key in sorted(tres):
        v = tres[key]
        v = int(v) if float(v).is_integer() else round(v, 2)
        parts.append(f"{key}={v}M" if key == "mem" else f"{key}={v}")
    return ",".join(parts)
