"""HLO post-mortem: collective census + roofline terms from a compiled
dry-run artifact.

``cost_analysis()`` has no collective accounting, so we parse the optimized
(post-SPMD) HLO text and sum the tensor sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, converting
each to *per-device link bytes* with the standard ring-algorithm factors.
Shapes in the partitioned module are already per-device.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (values from the assignment).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shapes live between '=' and the op name: `%x = bf16[8,128]{1,0} op(`
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str, op_pos: int) -> int:
    eq = line.find("=")
    if eq < 0 or eq > op_pos:
        return 0
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(line[eq:op_pos])
               if m.group(1) in _DTYPE_BYTES)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:                      # iota form [num_groups,group_size]
        return int(m.group(2))
    return 2


def _link_factor(op: str, n: int) -> float:
    """Per-device link bytes as a multiple of the parsed result bytes
    (ring algorithms).  reduce-scatter's result is the shard, hence (n-1)."""
    if op == "all-gather":
        return (n - 1) / n
    if op == "all-reduce":
        return 2 * (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0                 # collective-permute


@dataclass
class CollectiveCensus:
    ops: list = field(default_factory=list)   # (op, result_bytes, group, link)
    by_op: dict = field(default_factory=dict)
    total_result_bytes: int = 0
    total_link_bytes: float = 0.0

    def add(self, op: str, rbytes: int, group: int):
        link = rbytes * _link_factor(op, group)
        self.ops.append((op, rbytes, group, link))
        agg = self.by_op.setdefault(op, {"count": 0, "bytes": 0,
                                         "link_bytes": 0.0})
        agg["count"] += 1
        agg["bytes"] += rbytes
        agg["link_bytes"] += link
        self.total_result_bytes += rbytes
        self.total_link_bytes += link

    def summary(self) -> dict:
        return {
            "by_op": self.by_op,
            "total_result_bytes": self.total_result_bytes,
            "total_link_bytes": self.total_link_bytes,
            "num_ops": len(self.ops),
        }


def collective_census(hlo_text: str) -> CollectiveCensus:
    census = CollectiveCensus()
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            # match ` op(` or ` op-start(` — skip `-done` (already counted)
            pos = line.find(f" {op}(")
            if pos < 0:
                pos = line.find(f" {op}-start(")
            if pos < 0:
                continue
            census.add(op, _result_bytes(line, pos), _group_size(line))
            break
    return census


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   link_bytes_per_device: float) -> dict:
    """The three §Roofline terms, in seconds, from per-device quantities."""
    compute = flops_per_device / PEAK_FLOPS
    memory = hbm_bytes_per_device / HBM_BW
    collective = link_bytes_per_device / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).split("_")[0]
    return terms
