import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): prove the production sharding config
lowers + compiles for every (architecture x input shape x mesh) — with 512
placeholder devices standing in for 2 TPU v5e pods.

For each combination this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the real step function — ``train_step`` (train shapes),
     ``prefill`` (prefill shapes) or ``serve_step`` (decode shapes) — from
     ShapeDtypeStruct inputs (no allocation),
  3. compiles, records ``memory_analysis()`` / ``cost_analysis()``,
  4. parses the optimized HLO for the collective census (launch.hlo),
  5. writes one JSON per combo under results/dryrun/ and prints a summary.

Failures here are sharding bugs in the system, not environment problems.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch ID ...] \
      [--shape NAME ...] [--mesh single|multi|both] [--outdir DIR]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import (
    ARCH_IDS, INPUT_SHAPES, default_run_config, get_config, shape_for,
)
from repro.launch import hlo as H
from repro.launch.mesh import make_production_mesh
from repro.optim import OptimizerConfig


def _batch_divisor(mesh) -> int:
    d = mesh.shape.get("data", 1)
    return d * mesh.shape.get("pod", 1)


def lower_combo(arch_id: str, shape_name: str, mesh, overrides=None):
    """Lower the right step function for one (arch, shape, mesh)."""
    import dataclasses
    shape = INPUT_SHAPES[shape_name]
    cfg = shape_for(get_config(arch_id), shape)
    run = default_run_config(cfg, shape, batch_divisor=_batch_divisor(mesh))
    if overrides:
        run = dataclasses.replace(run, **overrides)
    lowered = lower_step(cfg, run, shape, mesh)
    return cfg, run, shape, lowered


def lower_step(cfg, run, shape, mesh):
    with mesh:
        if shape.kind == "train":
            from repro.training import (
                make_train_step, train_step_lowering_args,
            )
            opt = OptimizerConfig(state_dtype=run.opt_state_dtype)
            step = make_train_step(cfg, run, mesh, opt)
            args = train_step_lowering_args(cfg, run, mesh, shape, opt)
            lowered = step.lower(*args)
        elif shape.kind == "prefill":
            import jax.numpy as jnp
            from repro.core import sharding as shd
            from repro.models import abstract_params, input_specs
            from repro.models.model import prefill

            ap = abstract_params(cfg)
            specs = input_specs(cfg, shape)
            b_sh = shd.batch_shardings(cfg, mesh, run, specs)
            batch = {k: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                             sharding=b_sh[k])
                     for k, s in specs.items()
                     if k not in ("labels", "loss_mask")}
            p_sh = shd.param_shardings(cfg, mesh, run)

            from repro.core.actshard import activation_sharding
            act_rules = shd.make_activation_rules(cfg, mesh, run)

            def prefill_step(params, batch):
                with activation_sharding(act_rules):
                    return prefill(params, batch, cfg, run)

            lowered = jax.jit(
                prefill_step, in_shardings=(p_sh, None)).lower(ap, batch)
        else:   # decode
            from repro.serving import (
                make_serve_step, serve_step_lowering_args,
            )
            step = make_serve_step(cfg, run, mesh, shape.global_batch,
                                   shape.seq_len)
            args = serve_step_lowering_args(cfg, run, mesh, shape)
            lowered = step.lower(*args)
    return lowered


# --------------------------------------------------------- cost probes ------
# XLA's cost_analysis counts a `while` body ONCE, so the production program
# (scan over layer groups, scan over microbatches) under-reports flops/bytes
# and the HLO text shows loop-body collectives once.  The probes recover the
# exact per-step cost structurally: unroll everything at tiny depth and fit
#   X(m, G) = alpha + beta*G + m*(gamma + delta*G)
# (m = microbatches, G = layer groups), which is exact for group-homogeneous
# models, then evaluate at the production (m, G).

_PROBE_KEYS = ("flops", "hbm_bytes", "link_bytes")


def _probe_metrics(cfg, run, shape, mesh) -> dict:
    lowered = lower_step(cfg, run, shape, mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    census = H.collective_census(compiled.as_text())
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
        "link_bytes": census.total_link_bytes,
    }
    for op, agg in census.by_op.items():
        out[f"op:{op}"] = agg["link_bytes"]
    return out


def _fit_eval(c11, c12, c21, c22, m_lo, m_hi, m_full, g_full) -> dict:
    """Solve X(m, G) = a + b*G + c*m + d*m*G from probes at
    (m_lo, 1), (m_lo, 2), (m_hi, 1), (m_hi, 2) and evaluate at
    (m_full, g_full).  Exact for group/microbatch-homogeneous programs."""
    keys = set(c11) | set(c12) | set(c21) | set(c22)
    out = {}
    for k in keys:
        x11, x12 = c11.get(k, 0.0), c12.get(k, 0.0)
        x21, x22 = c21.get(k, 0.0), c22.get(k, 0.0)
        if m_hi == m_lo:                       # no-microbatch axis (serve)
            beta = x12 - x11
            alpha = x11 - beta
            val = alpha + beta * g_full
        else:
            dG_lo = x12 - x11                  # beta + delta*m_lo
            dG_hi = x22 - x21                  # beta + delta*m_hi
            delta = (dG_hi - dG_lo) / (m_hi - m_lo)
            beta = dG_lo - delta * m_lo
            gamma = (x21 - x11) / (m_hi - m_lo) - delta
            alpha = x11 - beta - (gamma + delta) * m_lo
            val = (alpha + beta * g_full
                   + m_full * (gamma + delta * g_full))
        out[k] = max(val, 0.0)
    return out


def probe_costs(cfg, run, shape, mesh) -> dict:
    """Exact per-step flops/bytes/collective-bytes via unrolled probes.

    Train probes run at m in {2, 4} (the m=1 code path skips the
    grad-accumulation machinery entirely and would pollute the fit).
    """
    import dataclasses

    from repro.models.spec import group_period

    P = group_period(cfg)
    g_full = cfg.num_layers // P
    m_full = run.microbatches

    def mk(groups, micro):
        pc = dataclasses.replace(cfg, num_layers=P * groups)
        pr = dataclasses.replace(run, unroll=True, microbatches=micro)
        return _probe_metrics(pc, pr, shape, mesh)

    if shape.kind == "train" and m_full > 1:
        # m in {1, 2}: the unrolled probe graph scales with P*G*m, and CPU
        # compile time with it (jamba at m in {2,4} never finished).  The
        # m=1 step skips the grad-accumulation scan; the machinery it skips
        # is O(params) adds — noise against O(params*tokens) matmuls, and
        # the (1,2) fit matched the (2,4) fit within ~3% when validated.
        m_lo, m_hi = 1, 2
        if shape.global_batch % (m_hi * 32):
            m_lo, m_hi = 1, 2
        c11, c12 = mk(1, m_lo), mk(2, m_lo)
        c21, c22 = mk(1, m_hi), mk(2, m_hi)
    else:
        m_lo = m_hi = m_full = run.microbatches if shape.kind == "train" else 1
        c11, c12 = mk(1, m_full), mk(2, m_full)
        c21, c22 = c11, c12
    return _fit_eval(c11, c12, c21, c22, m_lo, m_hi, m_full, g_full)


def analyze(lowered, mesh, cfg, run, shape, probe: bool = True) -> dict:
    compiled = lowered.compile()
    n_chips = mesh.devices.size
    out: dict = {"devices": n_chips}

    # production-program numbers (loop bodies counted once — lower bound)
    cost = compiled.cost_analysis() or {}
    census = H.collective_census(compiled.as_text())
    out["cost_raw"] = {
        "flops_per_device": float(cost.get("flops", 0.0)),
        "hbm_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "link_bytes_per_device": census.total_link_bytes,
    }
    out["collectives_raw"] = census.summary()

    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes),
        }
    except Exception as e:     # noqa: BLE001 — backend may not implement
        out["memory"] = {"error": str(e)}

    if probe:
        pc = probe_costs(cfg, run, shape, mesh)
        flops = pc["flops"]
        hbm_bytes = pc["hbm_bytes"]
        link_bytes = pc["link_bytes"]
        out["cost"] = {
            "flops_per_device": flops,
            "hbm_bytes_per_device": hbm_bytes,
            "link_bytes_per_device": link_bytes,
            "by_op_link_bytes": {k[3:]: v for k, v in pc.items()
                                 if k.startswith("op:")},
            "method": "unrolled-probe extrapolation",
        }
    else:
        flops = out["cost_raw"]["flops_per_device"]
        hbm_bytes = out["cost_raw"]["hbm_bytes_per_device"]
        link_bytes = census.total_link_bytes
        out["cost"] = dict(out["cost_raw"], method="raw (loops once)")

    out["roofline"] = H.roofline_terms(flops, hbm_bytes, link_bytes)

    # MODEL_FLOPS: 6*N_active*D for train, 2*N_active*D for inference steps
    n_active = cfg.active_param_count()
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens
    out["model_flops_global"] = model_flops
    hlo_flops_global = flops * n_chips
    out["useful_flops_ratio"] = (model_flops / hlo_flops_global
                                 if hlo_flops_global else 0.0)
    return out


def run_one(arch_id: str, shape_name: str, mesh_kind: str,
            outdir: str, overrides=None, tag: str = "") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg, run, shape, lowered = lower_combo(arch_id, shape_name, mesh,
                                           overrides)
    t_lower = time.time() - t0
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "tag": tag, "overrides": dict(overrides or {}),
        "mesh_shape": dict(mesh.shape),
        "strategy": run.strategy, "zero_stage": run.zero_stage,
        "microbatches": run.microbatches,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "sliding_window": cfg.sliding_window,
    }
    # probes (exact cost accounting) only on the single-pod mesh — the
    # roofline table is single-pod; the multi-pod pass proves sharding.
    rec.update(analyze(lowered, mesh, cfg, run, shape,
                       probe=(mesh_kind == "single")))
    rec["lower_s"] = round(t_lower, 1)
    rec["total_s"] = round(time.time() - t0, 1)
    os.makedirs(outdir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{arch_id}__{shape_name}__{mesh_kind}{suffix}.json"
    with open(os.path.join(outdir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.dryrun")
    ap.add_argument("--arch", nargs="*", default=ARCH_IDS)
    ap.add_argument("--shape", nargs="*", default=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--keep-going", action="store_true")
    # §Perf hillclimb knobs (beyond-paper variants; see EXPERIMENTS.md)
    ap.add_argument("--tag", default="", help="suffix for the output JSON")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--gather-bf16", action="store_true")
    ap.add_argument("--moe-defer-combine", action="store_true")
    ap.add_argument("--grad-reduce-bf16", action="store_true")
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--remat", default=None)
    args = ap.parse_args(argv)

    overrides = {}
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.gather_bf16:
        overrides["gather_bf16"] = True
    if args.moe_defer_combine:
        overrides["moe_defer_combine"] = True
    if args.grad_reduce_bf16:
        overrides["grad_reduce_bf16"] = True
    if args.micro is not None:
        overrides["microbatches"] = args.micro
    if args.strategy:
        overrides["strategy"] = args.strategy
    if args.remat:
        overrides["remat"] = args.remat

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    failures = []
    for arch in args.arch:
        for shape in args.shape:
            for mk in meshes:
                tag = f"{arch} x {shape} x {mk}"
                try:
                    rec = run_one(arch, shape, mk, args.outdir,
                                  overrides=overrides, tag=args.tag)
                    r = rec["roofline"]
                    print(f"[ok] {tag:55s} "
                          f"C={r['compute_s']:.3e}s "
                          f"M={r['memory_s']:.3e}s "
                          f"N={r['collective_s']:.3e}s "
                          f"-> {r['bottleneck']:10s} "
                          f"useful={rec['useful_flops_ratio']:.2f} "
                          f"({rec['total_s']}s)", flush=True)
                except Exception as e:   # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    if not args.keep_going:
                        traceback.print_exc()
                        return 1
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        return 1
    print("\nall dry-run combinations lowered + compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
