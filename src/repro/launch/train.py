"""`python -m repro.launch.train` — the paper's §5.2.4 job-script payload.

Trains a (reduced or full) architecture on the synthetic LM pipeline on
whatever devices exist.  On a real pod each host runs this under the
scheduler; in the container it runs single-process (use --reduced for CPU).
"""
from __future__ import annotations

import argparse

from repro.configs import (
    ARCH_IDS, INPUT_SHAPES, default_run_config, get_config,
    get_reduced_config, shape_for,
)
from repro.configs.base import InputShape, RunConfig
from repro.launch.mesh import make_mesh
from repro.monitoring import MetricsRegistry
from repro.optim import OptimizerConfig
from repro.training import Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.train")
    ap.add_argument("--arch", default="stablelm-3b", choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k",
                    choices=list(INPUT_SHAPES))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + small batch (CPU smoke)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=0,
                    help="override sequence length")
    ap.add_argument("--batch", type=int, default=0,
                    help="override global batch")
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--strategy", default="fsdp_tp",
                    choices=["dp", "tp", "fsdp", "fsdp_tp"])
    ap.add_argument("--zero", type=int, default=3, choices=[1, 2, 3])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    base = INPUT_SHAPES[args.shape]
    shape = InputShape(
        base.name,
        args.seq_len or (256 if args.reduced else base.seq_len),
        args.batch or (8 if args.reduced else base.global_batch),
        base.kind)
    assert shape.kind == "train", "use repro.launch.serve for decode shapes"
    cfg = shape_for(cfg, shape)
    mesh = make_mesh(args.data, args.model)
    run = RunConfig(strategy=args.strategy, zero_stage=args.zero,
                    microbatches=args.microbatches, remat="layer")
    opt = OptimizerConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          decay_steps=args.steps)
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir or None,
                         ckpt_every=args.ckpt_every)
    metrics = MetricsRegistry()
    print(f"training {cfg.name} ({cfg.param_count():,} params) on "
          f"mesh {dict(mesh.shape)} strategy={args.strategy} "
          f"zero={args.zero}")
    trainer = Trainer(cfg, run, mesh, shape, opt, tcfg, metrics)
    with mesh:
        trainer.train()
    print("\n== metrics ==")
    print(metrics.dashboard())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
