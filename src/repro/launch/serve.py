"""`python -m repro.launch.serve` — batched serving entry point: spin up the
DecodeEngine on a (reduced) architecture and push a synthetic request load
through it, reporting throughput/latency metrics — per tenant when
``--tenants`` carves the engine into fair-share slices.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import init_params
from repro.monitoring import MetricsRegistry
from repro.monitoring.metrics import (
    METRIC_SERVE_PREFIX_EVICTIONS, METRIC_SERVE_PREFIX_HITS,
    METRIC_SERVE_PREFIX_MISSES, METRIC_SERVE_PREFIX_REUSED_TOKENS,
    METRIC_SERVE_TENANT_TOKENS,
)
from repro.serving import AdmissionController, DecodeEngine, Request

#: page size --prefix-cache falls back to when --kv-paging is absent
DEFAULT_PREFIX_PAGE_SIZE = 16


def resolve_prefix_paging(prefix_cache: bool, kv_paging: int) -> int:
    """--prefix-cache implies --kv-paging: with no explicit page size the
    default kicks in (and says so), since the radix index shares physical
    pages and cannot exist over the dense per-slot cache."""
    if prefix_cache and not kv_paging:
        print(f"[serve] --prefix-cache implies --kv-paging: using "
              f"{DEFAULT_PREFIX_PAGE_SIZE}-line pages")
        return DEFAULT_PREFIX_PAGE_SIZE
    return kv_paging


def resolve_chunked_paging(max_batch_tokens, kv_paging: int) -> int:
    """--max-batch-tokens implies --kv-paging: a partial prefill holds
    exactly ceil(pos_filled/page) pages, which the dense per-slot cache
    cannot express — so budgeted mode defaults the page size in (and
    says so) when paging wasn't requested explicitly."""
    if max_batch_tokens is not None and not kv_paging:
        print(f"[serve] --max-batch-tokens implies --kv-paging: using "
              f"{DEFAULT_PREFIX_PAGE_SIZE}-line pages")
        return DEFAULT_PREFIX_PAGE_SIZE
    return kv_paging


def resolve_spec_paging(speculate: int, kv_paging: int) -> int:
    """--speculate implies --kv-paging: the verify scatter routes draft
    KV lines through per-line page targets (rejected lines die on the
    null page), which the dense cache cannot express."""
    if speculate and not kv_paging:
        print(f"[serve] --speculate implies --kv-paging: using "
              f"{DEFAULT_PREFIX_PAGE_SIZE}-line pages")
        return DEFAULT_PREFIX_PAGE_SIZE
    return kv_paging


def parse_tenants(spec: str, shares: str = "") -> dict[str, int]:
    """``alice:8,bob:1`` (or ``--tenants alice,bob --shares 8,1``) ->
    {"alice": 8, "bob": 1}."""
    out: dict[str, int] = {}
    names = [p.strip() for p in spec.split(",") if p.strip()]
    extra = [s.strip() for s in shares.split(",") if s.strip()] if shares \
        else []
    for i, part in enumerate(names):
        name, _, inline = part.partition(":")
        if inline:
            share = int(inline)
        elif i < len(extra):
            share = int(extra[i])
        else:
            share = 1
        assert share >= 1, f"tenant {name!r}: shares must be >= 1"
        out[name] = share
    return out


def parse_buckets(spec: str):
    """``auto`` -> power-of-two buckets, ``off`` -> exact-length prefill,
    ``32,64,128`` -> explicit bucket lengths."""
    spec = spec.strip().lower()
    if spec in ("", "off", "none"):
        return None
    if spec == "auto":
        return "auto"
    return tuple(int(p) for p in spec.split(",") if p.strip())


def resolve_tp(requested: int, n_devices: int) -> int:
    """``--tp`` with a graceful fallback: fewer visible devices than the
    requested shard count downgrades to tp=1 with a warning (and a hint
    at the forced-host-device recipe) instead of crashing the deploy."""
    if requested <= 1:
        return 1
    if n_devices < requested:
        print(f"[serve] --tp {requested}: only {n_devices} device(s) "
              f"visible — running tp=1 (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={requested} before "
              f"import to fake devices on CPU)")
        return 1
    return requested


def resolve_use_pallas(requested: bool, backend: str) -> bool:
    """``--use-pallas`` with a graceful fallback: the split-KV decode
    kernels are TPU-Pallas, so anywhere else (CPU would run them
    interpreted — orders of magnitude slower than the jnp reference
    path; other backends cannot lower them at all) the flag downgrades
    with a warning instead of tanking the deployment."""
    if not requested:
        return False
    if backend != "tpu":
        print(f"[serve] --use-pallas: backend is {backend!r}, not TPU — "
              "falling back to the reference decode path")
        return False
    return True


def make_requests(args, cfg, names, qos_cycle) -> list:
    """The synthetic workload both serving paths (single-engine and
    routed) push: per-request prompt lengths/tenants/QOS are a pure
    function of ``--seed``, so replica counts never change the
    workload."""
    rng = np.random.default_rng(args.seed)
    assert args.shared_prefix < args.cache_len, "--shared-prefix too long"
    system = rng.integers(2, cfg.vocab_size,
                          args.shared_prefix).astype(np.int32)
    if args.speculate and args.shared_prefix >= 8:
        # tile a short phrase so prompt-lookup drafts have material
        phrase = system[:8]
        system = np.tile(phrase, -(-args.shared_prefix // 8))[
            :args.shared_prefix]
    requests = []
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.cache_len // 4))
        prompt = rng.integers(2, cfg.vocab_size, plen).astype(np.int32)
        if args.shared_prefix:
            prompt = np.concatenate([system, prompt])[:args.cache_len - 1]
            if args.speculate and args.shared_prefix >= 8:
                # land the prompt tail back inside the tiled phrase so
                # n-gram lookups fire from the first decode step
                prompt = np.concatenate([prompt, system[:8]])[
                    :args.cache_len - 1]
        requests.append(Request(
            rid=rid,
            prompt=prompt,
            max_new_tokens=args.max_new,
            temperature=float(rid % 2) * 0.8,
            tenant=names[rid % len(names)],
            qos=qos_cycle[rid % len(qos_cycle)]))
    return requests


def _serve_elastic(args, cfg, params, metrics, tenants, use_pallas,
                   kv_paging) -> int:
    """--replicas/--autoscale: N engines behind the prefix-affinity
    router, optionally as scavenger jobs in a small simulated cluster
    with the autoscaler driving replica count."""
    from repro.cluster.commands import sdiag
    from repro.configs.base import RunConfig
    from repro.serving import Autoscaler, Router

    if args.tp > 1 or args.speculate or args.trace:
        print("[serve] --replicas/--autoscale path ignores --tp, "
              "--speculate and --trace (single-engine features)")

    def make_engine(admission):
        return DecodeEngine(
            cfg, params, num_slots=args.slots, cache_len=args.cache_len,
            metrics=metrics, admission=admission,
            run=RunConfig(remat="none", use_pallas=use_pallas),
            decode_chunk=args.decode_chunk, fused=not args.no_fused,
            prefill_buckets=parse_buckets(args.prefill_buckets),
            kv_page_size=kv_paging, kv_pages=args.kv_pages,
            prefix_cache=args.prefix_cache,
            max_batch_tokens=args.max_batch_tokens)

    router = Router(make_engine,
                    replicas=0 if args.autoscale else args.replicas,
                    policy="affinity" if args.affinity else "rr",
                    spill_factor=args.spill_factor, metrics=metrics)
    for name, share in tenants.items():
        router.add_tenant(name, shares=share)
    autoscaler = cluster = None
    if args.autoscale:
        from repro.cluster import (
            Cluster, Node, Partition, ResourceRequest,
        )
        n_nodes = max(args.replicas, 2)
        nodes = [Node(name=f"n{i:02d}", cpus=16, mem_mb=65536,
                      gres={"tpu": 4}, coord=(0, i))
                 for i in range(n_nodes)]
        cluster = Cluster(nodes, [Partition(
            name="serve", nodes=tuple(nd.name for nd in nodes),
            default=True)])
        autoscaler = Autoscaler(
            router, cluster,
            req=ResourceRequest(nodes=1, gres_per_node={"tpu": 4},
                                time_limit_s=36_000),
            min_replicas=1, max_replicas=max(args.replicas, 1))
        autoscaler.tick()
        print(f"[serve] autoscaler: probe saw "
              f"{autoscaler.stats['last_probe']} idle node(s), started "
              f"{len(router.replicas)} replica(s) as scavenger jobs")
    names = list(tenants)
    qos_cycle = [q.strip() for q in args.qos.split(",") if q.strip()] \
        or ["normal"]
    requests = make_requests(args, cfg, names, qos_cycle)
    bursts = max(args.bursts, 1)
    per_wave = -(-len(requests) // bursts)       # ceil division
    t0 = time.perf_counter()
    for w in range(bursts):
        for req in requests[w * per_wave:(w + 1) * per_wave]:
            router.submit(req)
        if w < bursts - 1:
            for _ in range(3):                    # let the wave decode a bit
                router.step()
        if autoscaler is not None and w == 0 and len(router.replicas) > 1:
            # mid-run batch pressure: a high-QOS job preempts one
            # scavenger replica job; the tick drains that replica and its
            # in-flight requests resume elsewhere (partial output kept)
            from repro.cluster import ResourceRequest
            cluster.submit("batch-train", ResourceRequest(
                nodes=1, gres_per_node={"tpu": 4}), qos="high",
                run_time_s=600.0)
            autoscaler.tick()
            print(f"[serve] batch pressure: drained down to "
                  f"{len(router.replicas)} replica(s), "
                  f"{router.stats['resubmitted']} request(s) re-routed")
    router.run_to_completion()
    wall = time.perf_counter() - t0
    total = int(metrics.counter("serve_tokens_generated").value())
    busy = max(router.busy_seconds().values())
    st = router.stats
    print(f"served {len(requests)} requests on {len(router.replicas)} "
          f"replica(s), {total} tokens in {wall:.1f}s "
          f"({total / wall:,.1f} tok/s wall, busiest replica "
          f"{busy:.1f}s busy)")
    print(f"router: policy {router.policy}, {st['routed']} routed, "
          f"{st['affinity_hits']} affinity hits, {st['spills']} spills, "
          f"{st['drains']} drains ({st['resubmitted']} re-routed)")
    if args.prefix_cache:
        hits = int(metrics.counter(METRIC_SERVE_PREFIX_HITS).value())
        misses = int(metrics.counter(METRIC_SERVE_PREFIX_MISSES).value())
        print(f"prefix cache (all replicas): {hits} hits / {misses} "
              f"misses, "
              f"{int(metrics.counter(METRIC_SERVE_PREFIX_REUSED_TOKENS).value())} "
              f"prompt tokens reused")
    print(sdiag(cluster=cluster, router=router, autoscaler=autoscaler))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", default="stablelm-3b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens generated per device dispatch (the fused "
                         "decode_n scan length); 1 = per-token chunks")
    ap.add_argument("--no-fused", action="store_true",
                    help="use the legacy per-token host sampling loop")
    ap.add_argument("--prefill-buckets", default="auto",
                    help="'auto' (power-of-two), 'off', or comma lengths "
                         "e.g. 32,64,128 — prompts pad to the next bucket "
                         "so prefill compiles once per bucket")
    ap.add_argument("--tp", type=int, default=1, metavar="N",
                    help="tensor-parallel serving over N devices: params, "
                         "decode dispatches and the paged KV pool shard "
                         "over a (1, N) mesh's 'model' axis; greedy output "
                         "stays bit-identical to --tp 1 (falls back to 1 "
                         "when fewer devices are visible)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route decode attention through the Pallas "
                         "split-KV flash-decode kernel (falls back to the "
                         "reference path on CPU-only backends)")
    ap.add_argument("--kv-paging", type=int, default=0, metavar="PAGE_SIZE",
                    help="paged KV cache with PAGE_SIZE-line pages "
                         "(0 = dense per-slot cache); short requests then "
                         "share HBM instead of pinning cache-len lines")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="page-pool size override (default: dense-budget "
                         "equivalent, slots*cache_len/page_size + null)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache: requests sharing a prompt "
                         "prefix map the same KV pages copy-on-write and "
                         "prefill only their suffix (implies --kv-paging "
                         f"{DEFAULT_PREFIX_PAGE_SIZE})")
    ap.add_argument("--max-batch-tokens", type=int, nargs="?", const=512,
                    default=None, metavar="T",
                    help="continuous batching: each iteration runs ONE "
                         "fused step over a T-token budget mixing decode "
                         "lanes and prefill chunks, so long prompts stop "
                         "head-of-line blocking short ones (bare flag: "
                         "T=512; implies --kv-paging "
                         f"{DEFAULT_PREFIX_PAGE_SIZE})")
    ap.add_argument("--speculate", type=int, nargs="?", const=4,
                    default=0, metavar="K",
                    help="speculative decoding: draft up to K tokens per "
                         "lane and verify them in ONE batched target "
                         "dispatch — greedy output stays bit-identical, "
                         "temperature uses rejection sampling (bare "
                         "flag: K=4; implies --kv-paging "
                         f"{DEFAULT_PREFIX_PAGE_SIZE})")
    ap.add_argument("--spec-source", default="ngram",
                    choices=("ngram", "model"),
                    help="draft source: 'ngram' prompt-lookup (free, fed "
                         "by finished requests) or 'model' (a tiny draft "
                         "model with its own dense KV cache)")
    ap.add_argument("--draft-model", default=None, choices=ARCH_IDS,
                    metavar="ARCH",
                    help="with --spec-source model: architecture to draft "
                         "with (always reduced; must share the target's "
                         "vocabulary). Default: a 1-layer shrink of the "
                         "target config")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token system prompt to every "
                         "synthetic request (exercises --prefix-cache)")
    ap.add_argument("--tenants", default="",
                    help="tenant:shares list, e.g. alice:8,bob:1 "
                         "(empty: single default tenant)")
    ap.add_argument("--shares", default="",
                    help="shares for --tenants given as bare names, "
                         "e.g. --tenants alice,bob --shares 8,1")
    ap.add_argument("--qos", default="",
                    help="comma list of QOS tiers cycled across requests "
                         "(e.g. high,scavenger); empty = all 'normal'")
    ap.add_argument("--bursts", type=int, default=1,
                    help="submit the workload in N bursts with a few "
                         "decode steps between waves (exercises queueing "
                         "and the queue-wait/TTFT series)")
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="elastic serving: N decode-engine replicas "
                         "behind the router; replicas share one "
                         "fair-share ledger and one GrpTRES scope")
    ap.add_argument("--affinity", action="store_true",
                    help="prefix-affinity routing (consistent hash on "
                         "the first prompt page, spill to least-loaded); "
                         "default with --replicas is round-robin")
    ap.add_argument("--spill-factor", type=float, default=2.0,
                    help="with --affinity: shed to the least-loaded "
                         "replica once the affine one's queue runs this "
                         "many num_slots deeper (default 2.0)")
    ap.add_argument("--autoscale", action="store_true",
                    help="run replicas as scavenger jobs in a small "
                         "simulated cluster: the autoscaler grows to "
                         "--replicas while idle nodes exist and drains "
                         "replicas when a batch job preempts them")
    ap.add_argument("--trace", default="", metavar="OUT_JSON",
                    help="record request-lifecycle spans and write a "
                         "Chrome trace-event JSON (load in Perfetto or "
                         "chrome://tracing); also prints the SLO report")
    args = ap.parse_args(argv)

    import jax

    from repro.configs.base import RunConfig

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    params = init_params(cfg, args.seed)
    metrics = MetricsRegistry()
    tenants = parse_tenants(args.tenants, args.shares) if args.tenants \
        else {"default": 1}
    tracer = None
    if args.trace:
        from repro.monitoring import Tracer
        tracer = Tracer(metrics=metrics)
    admission = AdmissionController(tracer=tracer)
    for name, share in tenants.items():
        admission.add_tenant(name, shares=share)
    use_pallas = resolve_use_pallas(args.use_pallas, jax.default_backend())
    tp = resolve_tp(args.tp, len(jax.devices()))
    mesh = None
    if tp > 1:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(1, tp)
    kv_paging = resolve_prefix_paging(args.prefix_cache, args.kv_paging)
    kv_paging = resolve_chunked_paging(args.max_batch_tokens, kv_paging)
    kv_paging = resolve_spec_paging(args.speculate, kv_paging)
    draft_cfg = None
    if args.draft_model is not None:
        draft_cfg = get_reduced_config(args.draft_model)
        assert draft_cfg.vocab_size == cfg.vocab_size, \
            "--draft-model must share the target's vocabulary"
    if args.replicas > 1 or args.autoscale:
        return _serve_elastic(args, cfg, params, metrics, tenants,
                              use_pallas, kv_paging)
    engine = DecodeEngine(cfg, params, num_slots=args.slots,
                          cache_len=args.cache_len, metrics=metrics,
                          admission=admission,
                          run=RunConfig(remat="none", use_pallas=use_pallas),
                          decode_chunk=args.decode_chunk,
                          fused=not args.no_fused,
                          prefill_buckets=parse_buckets(args.prefill_buckets),
                          kv_page_size=kv_paging,
                          kv_pages=args.kv_pages,
                          prefix_cache=args.prefix_cache,
                          max_batch_tokens=args.max_batch_tokens,
                          tracer=tracer,
                          speculate=args.speculate,
                          spec_source=args.spec_source,
                          draft_model=draft_cfg,
                          mesh=mesh)
    names = list(tenants)
    qos_cycle = [q.strip() for q in args.qos.split(",") if q.strip()] \
        or ["normal"]
    requests = make_requests(args, cfg, names, qos_cycle)
    bursts = max(args.bursts, 1)
    per_wave = -(-len(requests) // bursts)       # ceil division
    t0 = time.perf_counter()
    for w in range(bursts):
        for req in requests[w * per_wave:(w + 1) * per_wave]:
            engine.submit(req)
        if w < bursts - 1:
            for _ in range(3):                    # let the wave decode a bit
                engine.step()
    engine.run_to_completion()
    wall = time.perf_counter() - t0
    total = int(metrics.counter("serve_tokens_generated").value())
    mode = ("host loop" if args.no_fused
            else f"fused chunk={args.decode_chunk}")
    print(f"served {args.requests} requests, {total} tokens in {wall:.1f}s "
          f"({total / wall:,.1f} tok/s, {args.slots} slots, {mode})")
    if engine.prefill_buckets:
        print(f"prefill buckets {engine.prefill_buckets}: "
              f"{engine.prefill_compilations()} compilations")
    if engine.paging is not None:
        print(f"paged KV: {engine.paging.page_size}-line pages, pool "
              f"{engine.paging.usable_pages} pages "
              f"(high-water {engine.allocator.high_water}, "
              f"{int(metrics.counter('serve_page_starvations').value())} "
              f"starvation requeues)")
    if engine.tp.tp > 1:
        ps = engine.tp.psums_per_token(cfg)
        print(f"tensor parallel: {engine.tp.describe(cfg)} on "
              f"{len(engine.tp.devices())} devices, "
              f"{sum(ps.values())} psums/token "
              f"(attn {ps['attn_out']}, mlp {ps['mlp_out']})")
    if engine.max_batch_tokens is not None:
        st = engine.serve_stats
        spent = st["decode_tokens"] + st["prefill_tokens"]
        cap = st["iterations"] * engine.max_batch_tokens
        print(f"continuous batching: budget {engine.max_batch_tokens} "
              f"tok/step, {st['iterations']} iterations, "
              f"fill {spent}/{cap} ({spent / cap if cap else 0:.0%}), "
              f"{st['prefill_chunks']} prefill chunks "
              f"({engine.chunk_compilations()} chunk compilations)")
    if engine.speculate:
        st = engine.spec_stats
        rate = st["accepted"] / st["proposed"] if st["proposed"] else 0.0
        per_round = st["emitted"] / st["rounds"] if st["rounds"] else 0.0
        print(f"speculative decoding: k={engine.speculate} "
              f"({args.spec_source}), {st['rounds']} verify rounds, "
              f"accepted {st['accepted']}/{st['proposed']} drafts "
              f"({rate:.0%}), {per_round:.2f} tokens/round")
    if engine.prefix is not None:
        hits = int(metrics.counter(METRIC_SERVE_PREFIX_HITS).value())
        misses = int(metrics.counter(METRIC_SERVE_PREFIX_MISSES).value())
        print(f"prefix cache: {hits} hits / {misses} misses, "
              f"{int(metrics.counter(METRIC_SERVE_PREFIX_REUSED_TOKENS).value())} "
              f"prompt tokens reused, "
              f"{int(metrics.counter(METRIC_SERVE_PREFIX_EVICTIONS).value())} "
              f"pages evicted, {engine.prefix.nodes} pages cached")
    if len(names) > 1 and total:
        tok = metrics.counter(METRIC_SERVE_TENANT_TOKENS)
        parts = []
        for name in names:
            n = int(tok.value(tenant=name))
            parts.append(f"{name}[{tenants[name]}sh]={n} "
                         f"({n / total:.0%})")
        print("per-tenant tokens: " + "  ".join(parts))
    print(f"decode p50 "
          f"{metrics.histogram('serve_decode_seconds').quantile(0.5)*1e3:.1f}"
          f"ms  p99 "
          f"{metrics.histogram('serve_decode_seconds').quantile(0.99)*1e3:.1f}"
          f"ms")
    if tracer is not None:
        data = tracer.export_chrome(args.trace)
        from repro.cluster.commands import sdiag
        print(f"trace: {len(data['traceEvents'])} events -> {args.trace} "
              f"(load in ui.perfetto.dev)")
        print(sdiag(admission=admission, tracer=tracer, engine=engine))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
