"""`python -m repro.launch.serve` — batched serving entry point: spin up the
DecodeEngine on a (reduced) architecture and push a synthetic request load
through it, reporting throughput/latency metrics.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import init_params
from repro.monitoring import MetricsRegistry
from repro.serving import DecodeEngine, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", default="stablelm-3b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    params = init_params(cfg, args.seed)
    metrics = MetricsRegistry()
    engine = DecodeEngine(cfg, params, num_slots=args.slots,
                          cache_len=args.cache_len, metrics=metrics)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.cache_len // 4))
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=float(rid % 2) * 0.8))
    import time
    t0 = time.perf_counter()
    engine.run_to_completion()
    wall = time.perf_counter() - t0
    total = int(metrics.counter("serve_tokens_generated").value())
    print(f"served {args.requests} requests, {total} tokens in {wall:.1f}s "
          f"({total / wall:,.1f} tok/s, {args.slots} slots)")
    print(f"decode p50 "
          f"{metrics.histogram('serve_decode_seconds').quantile(0.5)*1e3:.1f}"
          f"ms  p99 "
          f"{metrics.histogram('serve_decode_seconds').quantile(0.99)*1e3:.1f}"
          f"ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
