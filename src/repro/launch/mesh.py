"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state (device count locks on first jax init, and the dry-run must
set XLA_FLAGS before that happens).

Mesh shapes follow DESIGN.md: one TPU v5e pod = 16x16 chips = (data=16,
model=16); two pods join over DCN on a leading "pod" axis = (2, 16, 16).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh
try:                                    # jax >= 0.5.0 only
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _axis_kw(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(data: int = 1, model: int = 1, pod: int = 1) -> Mesh:
    """Small/explicit mesh (tests, examples, single-host runs)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             **_axis_kw(3))
    return jax.make_mesh((data, model), ("data", "model"), **_axis_kw(2))


def single_device_mesh() -> Mesh:
    return make_mesh(1, 1)
