from repro.models.init import abstract_params, init_params, param_bytes
from repro.models.inputs import input_specs, make_batch
from repro.models.model import (
    decode_step,
    forward_train,
    init_cache,
    loss_fn,
    prefill,
)
from repro.models.paging import PageAllocator, PagedKVConfig, pages_for
from repro.models.spec import count_params, model_spec

__all__ = [
    "abstract_params", "init_params", "param_bytes", "input_specs",
    "make_batch", "decode_step", "forward_train", "init_cache", "loss_fn",
    "prefill", "count_params", "model_spec", "PageAllocator",
    "PagedKVConfig", "pages_for",
]
