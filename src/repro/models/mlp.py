"""Dense FFN variants: SwiGLU (llama-family), GELU (starcoder2/musicgen),
squared-ReLU (nemotron/minitron)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.actshard import constrain, maybe_psum, tp_will_reduce


def mlp_apply(p: dict, x: jax.Array, mlp_type: str) -> jax.Array:
    dtype = x.dtype
    h = constrain(x @ p["w1"].astype(dtype), "ffn_hidden")
    if mlp_type == "swiglu":
        h = jax.nn.silu(h) * constrain(x @ p["w3"].astype(dtype), "ffn_hidden")
    elif mlp_type == "gelu":
        h = jax.nn.gelu(h)
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(mlp_type)
    # contracts d_ff — under serving TP (w1/w3 column- and w2 row-sharded
    # inside shard_map) each shard holds a partial sum here.  The partial
    # stays float32 through the psum: summing rounded bf16 partials can
    # flip near-tie logits vs the single-device contraction
    w2 = p["w2"].astype(dtype)
    if tp_will_reduce("mlp_out"):
        part = jnp.einsum("...f,fd->...d", h, w2,
                          preferred_element_type=jnp.float32)
        return maybe_psum(part, "mlp_out").astype(dtype)
    return maybe_psum(h @ w2, "mlp_out")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
