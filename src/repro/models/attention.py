"""GQA attention: training/prefill (blockwise, memory-efficient) and decode.

The training/prefill path is q-block-chunked so the (S, S) logits tensor is
never materialized — required at 32k context where a naive einsum would need
terabytes of HBM.  This pure-JAX path doubles as the oracle for the Pallas
flash-attention kernel (``repro.kernels.flash_attention``); ``use_pallas``
switches the hot loop to the kernel (TPU / interpret mode).

Sliding-window attention (``cfg.sliding_window``) is a first-class variant:
it bounds the KV range per query and, at decode time, turns the KV cache into
a ring buffer of ``window`` slots — this is what makes ``long_500k`` decode
sub-quadratic-feasible for dense architectures (see DESIGN.md).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.actshard import constrain, maybe_psum, tp_will_reduce

_NEG_INF = -1e30


# ---------------------------------------------------------------- rotary ----

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pe(positions: jax.Array, d_model: int) -> jax.Array:
    """(S,) -> (S, d_model) classic transformer sinusoidal embedding."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------- projections ----

def qkv_proj(p: dict, x: jax.Array, cfg: ModelConfig):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return (constrain(q, "heads"), constrain(k, "kv"), constrain(v, "kv"))


def out_proj(p: dict, o: jax.Array) -> jax.Array:
    # contracts the head dim — under serving TP (heads sharded over the
    # mesh inside shard_map) each shard holds a partial sum here, hence
    # the one cross-shard reduction per attention layer.  The partial
    # stays float32 through the psum: summing rounded bf16 partials can
    # flip near-tie logits vs the single-device contraction
    w = p["wo"].astype(o.dtype)
    if tp_will_reduce("attn_out"):
        part = jnp.einsum("bshk,hkd->bsd", o, w,
                          preferred_element_type=jnp.float32)
        return maybe_psum(part, "attn_out").astype(o.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, w)


# ------------------------------------------------- blockwise causal core ----

def _attend_block(q, k, v, q_pos, kv_pos, window, scale, kv_valid=None):
    """q: (B,qb,K,G,Dh)  k/v: (B,S,K,Dh)  -> (B,qb,K,G,Dh).

    Computes softmax over the full kv range with causal (+ window) masking.
    fp32 logits/softmax for stability.  ``kv_valid`` ((S,) bool) excludes
    pad kv lines entirely (front-padded prefill); a query whose kv range
    masks out completely stays NaN-free because ``_NEG_INF`` is finite —
    its softmax is uniform and its (garbage) output is never consumed.
    """
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    mask = kv_pos[None, :] <= q_pos[:, None]                 # causal
    if window is not None:
        mask &= (q_pos[:, None] - kv_pos[None, :]) < window  # sliding window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def causal_attention(q, k, v, cfg: ModelConfig, q_block: int = 512,
                     positions: Optional[jax.Array] = None,
                     kv_valid: Optional[jax.Array] = None,
                     unroll: bool = False, one_block: bool = False):
    """q: (B,S,H,Dh), k/v: (B,S,K,Dh) -> (B,S,H,Dh).  Full/sliding causal.

    ``unroll`` replaces the q-block scan with straight-line HLO (dry-run
    cost probes only — XLA cost_analysis counts loop bodies once).

    ``one_block`` computes all q rows in one _attend_block call.  Used by
    sequence parallelism: the q-block SCAN's interleaved S-tiling cannot
    merge back into a contiguously S-sharded hidden (GSPMD inserted 24 GiB
    logit all-gathers to reshard — measured, see EXPERIMENTS.md §Perf-1);
    with one block the S shards flow through scores -> probs -> output
    untouched.  The (S_shard, S) logits transient is remat-bounded.

    ``kv_valid`` ((S,) bool, optional) excludes pad kv lines for every
    query (front-padded bucketed prefill, where ``positions`` carries the
    shifted coordinates).  It forces the one-block path: the q-block scan
    derives each block's q positions from ``start + arange``, which only
    holds for the identity position map.
    """
    B, S, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    scale = Dh ** -0.5
    window = cfg.sliding_window
    qg = q.reshape(B, S, K, G, Dh)
    kv_pos = jnp.arange(S) if positions is None else positions

    if one_block or S <= q_block or kv_valid is not None:
        o = _attend_block(qg, k, v, kv_pos, kv_pos, window, scale,
                          kv_valid=kv_valid)
        return o.reshape(B, S, H, Dh)

    nb = S // q_block
    assert S % q_block == 0, (S, q_block)
    q_blocks = qg.reshape(B, nb, q_block, K, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    # under sequence parallelism the within-block q rows stay sharded over
    # `model`, so every model shard works on every scan iteration (the
    # OUTER nb dim is scanned sequentially — sharding it would idle chips)
    q_blocks = constrain(q_blocks, "q_blocks")

    # checkpoint the block body: otherwise the scan VJP stacks the softmax
    # residuals across blocks — the full (S, S) probs tensor, 6 GB/device in
    # f32 at 4k context (measured; see EXPERIMENTS.md §Perf).  Recomputing
    # scores in the backward is the flash-attention trade.
    @jax.checkpoint
    def body(_, inputs):
        qb, start = inputs
        q_pos = start + jnp.arange(q_block)
        o = _attend_block(qb, k, v, q_pos, kv_pos, window, scale)
        return None, o

    starts = jnp.arange(nb) * q_block
    if unroll:
        o_blocks = jnp.stack([body(None, (q_blocks[i], starts[i]))[1]
                              for i in range(nb)])
    else:
        _, o_blocks = jax.lax.scan(body, None, (q_blocks, starts))
    o = o_blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, Dh)
    return o


# -------------------------------------------------------------- training ----

def attention_train(p: dict, x: jax.Array, cfg: ModelConfig,
                    use_pallas: bool = False,
                    unroll: bool = False,
                    one_block: bool = False) -> jax.Array:
    """Full-sequence attention (training / prefill without cache return)."""
    B, S, _ = x.shape
    q, k, v = qkv_proj(p, x, cfg)
    if cfg.pos_embedding == "rope":
        pos = jnp.arange(S)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if use_pallas:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, window=cfg.sliding_window)
    else:
        o = causal_attention(q, k, v, cfg, unroll=unroll,
                             one_block=one_block)
    return out_proj(p, constrain(o, "heads"))


# ---------------------------------------------------------------- decode ----

def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, n_groups: int,
                  dtype=jnp.bfloat16, abstract: bool = False, paging=None):
    """Stacked (over scan groups) KV cache for one attention sublayer slot.

    For sliding-window configs the cache has ``window`` slots (ring buffer);
    otherwise ``cache_len`` slots.

    ``paging`` (a :class:`repro.models.paging.PagedKVConfig`) switches the
    layout from dense per-slot lines ``(G, B, slots, K, Dh)`` to one shared
    page pool ``(G, num_pages, page_size, K, Dh)``: requests address it
    through a per-slot page table instead of a batch index, so the pool is
    sized to the HBM budget rather than ``batch * cache_len``.  Callers
    that indexed the cache by batch must go through the page table in this
    mode (see README "Paged KV cache" migration note).
    """
    if paging is not None:
        shape = (n_groups, paging.num_pages, paging.page_size,
                 cfg.num_kv_heads, cfg.head_dim)
    else:
        slots = min(cache_len, cfg.sliding_window or cache_len)
        shape = (n_groups, batch, slots, cfg.num_kv_heads, cfg.head_dim)
    if abstract:
        arr = jax.ShapeDtypeStruct(shape, dtype)
        return {"k": arr, "v": arr}
    # distinct buffers: the serving engine donates the cache pytree into
    # jitted steps, and XLA rejects donating one buffer twice
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_prefill(p: dict, x: jax.Array, cfg: ModelConfig,
                      cache_slots: int, use_pallas: bool = False,
                      unroll: bool = False,
                      positions: Optional[jax.Array] = None,
                      valid: Optional[jax.Array] = None,
                      roll: Optional[jax.Array] = None):
    """Prefill: full attention + return the populated KV cache slice.

    Returns (out (B,S,d), {"k","v"} (B, slots, K, Dh)).  When
    ``cache_slots < S`` (sliding window) the last ``slots`` positions are
    kept, laid out at ring indices ``pos % slots``.

    ``positions``/``valid``/``roll`` support front-padded bucketed
    prefill (hybrid/SSM configs whose siblings need the real tokens
    chunk-aligned): ``positions`` ((S,) int32, may be negative at the
    front pad) replaces ``arange(S)`` for RoPE and the causal mask,
    ``valid`` ((S,) bool) masks pad kv out of every query, and ``roll``
    (traced int32, the front-pad width) rotates the returned KV slice so
    real tokens land at cache lines ``[0, num_real)`` — exactly where an
    unpadded prefill writes them.  Garbage lines at/past ``num_real``
    stay masked at decode until overwritten, same as the tail-pad path.
    """
    B, S, _ = x.shape
    q, k, v = qkv_proj(p, x, cfg)
    if cfg.pos_embedding == "rope":
        pos = jnp.arange(S) if positions is None else positions
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if valid is not None:
        assert not use_pallas, \
            "front-padded prefill has no Pallas path (flash kernel " \
            "lacks a kv-validity mask)"
        o = causal_attention(q, k, v, cfg, positions=positions,
                             kv_valid=valid)
    elif use_pallas:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, window=cfg.sliding_window)
    else:
        o = causal_attention(q, k, v, cfg)
    o = constrain(o, "heads")
    if cache_slots >= S:
        if roll is not None:
            k = jnp.roll(k, -roll, axis=1)
            v = jnp.roll(v, -roll, axis=1)
        pad = cache_slots - S
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        assert roll is None, \
            "front-padded prefill is gated off sliding-window ring caches"
        # last `slots` positions, placed at ring index pos % slots
        tail_k = k[:, S - cache_slots:]
        tail_v = v[:, S - cache_slots:]
        idx = (jnp.arange(S - cache_slots, S)) % cache_slots
        ck = jnp.zeros_like(tail_k).at[:, idx].set(tail_k)
        cv = jnp.zeros_like(tail_v).at[:, idx].set(tail_v)
    return out_proj(p, o), {"k": ck, "v": cv}


def attention_prefill_paged(p: dict, x: jax.Array, cache: dict,
                            page_table: jax.Array, start: jax.Array,
                            cfg: ModelConfig):
    """Suffix prefill against a cached prefix in the paged KV pool
    (prefix-cache reuse: only the un-cached tail of the prompt runs).

    x: (B, S, d) hidden states of the *suffix* tokens, at absolute
    positions ``start + [0, S)``; cache k/v: (num_pages, page_size, K,
    Dh) — the shared pool; page_table: (B, n_prefix_pages) int32 rows
    whose first ``ceil(start / page_size)`` entries are the request's
    READ-ONLY shared prefix pages (any remaining entries null — callers
    may bucket the row width to the match depth so cost scales with the
    actual prefix); start: scalar int32 prefix length.  ``start`` need
    NOT be page-aligned: the prefix mask below works at LINE granularity
    (``arange(L) < start``), so a partially-filled last page contributes
    exactly its live lines — this is what lets chunked prefill
    (``models.model.prefill_chunk``) resume from any position.

    Suffix queries attend causally over [the prefix gathered through the
    page table (positions < start), the suffix itself].  The masking and
    einsum strings are exactly the dense prefill's, so with compute
    dtype == pool dtype the logits match a full-prompt prefill bit for
    bit.  The pool is **never written** — shared pages are read-only by
    construction; the suffix K/V slice is returned for the caller to
    scatter into privately-owned pages (the copy-on-write fork: writes
    only ever land past the shared region).

    Returns (out (B,S,d), {"k","v"} suffix slice (B, S, K, Dh)).
    """
    B, S, _ = x.shape
    q, k, v = qkv_proj(p, x, cfg)
    pos = start + jnp.arange(S)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    H, Dh = q.shape[2], q.shape[3]
    K = k.shape[2]
    G = H // K
    ps = cache["k"].shape[1]
    L = page_table.shape[1] * ps                     # logical prefix width
    scale = Dh ** -0.5
    kd = cache["k"][page_table].reshape(B, L, K, Dh).astype(q.dtype)
    vd = cache["v"][page_table].reshape(B, L, K, Dh).astype(q.dtype)
    qg = q.reshape(B, S, K, G, Dh)
    # prefix part: every suffix query sees every position < start (all
    # causal by construction); pool garbage past start is masked out
    lp = jnp.einsum("bqkgd,bskd->bkgqs", qg, kd).astype(jnp.float32) * scale
    pre_valid = jnp.arange(L) < start                # (L,)
    lp = jnp.where(pre_valid[None, None, None, None, :], lp, _NEG_INF)
    # suffix part: causal within the suffix (pad tails in a bucketed
    # suffix sit at higher positions, so real queries never see them)
    ls = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    causal = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
    ls = jnp.where(causal[None, None, None], ls, _NEG_INF)
    probs = jax.nn.softmax(jnp.concatenate([lp, ls], axis=-1),
                           axis=-1).astype(x.dtype)
    # combine the two regions with f32 partial sums and ONE final cast:
    # a bf16 round between the partials would double-round vs the dense
    # path's single accumulation and drift the suffix hidden states
    f32 = jnp.float32
    o = (jnp.einsum("bkgqs,bskd->bqkgd", probs[..., :L].astype(f32),
                    vd.astype(f32))
         + jnp.einsum("bkgqs,bskd->bqkgd", probs[..., L:].astype(f32),
                      v.astype(f32)))
    o = o.astype(x.dtype).reshape(B, S, H, Dh)
    return out_proj(p, constrain(o, "heads")), {"k": k, "v": v}


def attention_verify(p: dict, x: jax.Array, cache: dict, pos0: jax.Array,
                     pages: jax.Array, offs: jax.Array,
                     page_table: jax.Array, cfg: ModelConfig):
    """Multi-position decode against a paged KV pool (speculative verify).

    x: (B, S, d) hidden states of S consecutive tokens per slot — the
    slot's last sampled token followed by S-1 drafted continuations, at
    absolute positions ``pos0 + [0, S)``; cache k/v: (num_pages,
    page_size, K, Dh) — the shared pool; pages/offs: (B, S) int32
    physical scatter targets for each token's KV line (the null page for
    lines past a slot's allocation or for dead slots); page_table:
    (B, pages_per_seq) as in :func:`attention_decode_paged`.

    Query row j is EXACTLY the one-token decode at position ``pos0 + j``:
    all S lines scatter first, then each row gathers the pool through
    the page table and masks ``arange <= pos0 + j`` — the same valid
    mask, scale, einsum strings, and cast points as
    :func:`attention_decode_paged`, so row j's output is bit-identical
    to a sequential decode that had written lines ``pos0..pos0+j``.
    Rejected drafts' lines are dead on arrival: the accept mask
    truncates ``pos`` host-side, and the next round's scatter overwrites
    them before any query can attend past its own position.

    Returns (out (B,S,d), updated cache).
    """
    assert cfg.sliding_window is None, "paged KV is full-attention only"
    B, S, _ = x.shape
    ps = cache["k"].shape[1]
    q, k, v = qkv_proj(p, x, cfg)                     # (B,S,H/K,Dh)
    posm = pos0[:, None].astype(jnp.int32) + jnp.arange(S)[None, :]
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, posm, cfg.rope_theta)
        k = apply_rope(k, posm, cfg.rope_theta)
    # scatter all S lines; duplicate writes only ever target the null
    # page (dead slots / over-capacity lines), same as bucket pad lines
    ck = cache["k"].at[pages, offs].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[pages, offs].set(v.astype(cache["v"].dtype))

    H, Dh = q.shape[2], q.shape[3]
    K = ck.shape[2]
    G = H // K
    n_pages = page_table.shape[1]
    L = n_pages * ps
    qg = q.reshape(B, S, K, G, Dh)
    kd = ck[page_table].reshape(B, L, K, Dh)
    vd = cv[page_table].reshape(B, L, K, Dh)
    valid = jnp.arange(L)[None, None, :] <= posm[:, :, None]   # (B,S,L)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kd).astype(jnp.float32)
    logits = logits * (Dh ** -0.5)
    logits = jnp.where(valid[:, None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, vd).reshape(B, S, H, Dh)
    return out_proj(p, constrain(o, "heads")), {"k": ck, "v": cv}


def attention_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                     cfg: ModelConfig, use_pallas: bool = False):
    """One-token decode.  x: (B,1,d); cache k/v: (B, slots, K, Dh);
    pos: scalar int32 OR (B,) int32 — absolute position of each new token
    (0-based).  Per-slot positions support continuous batching (each slot
    of the serving engine decodes at its own depth).

    ``use_pallas`` routes the attention through the split-KV flash-decode
    kernel (``repro.kernels.flash_decode``): full caches mask ``slot <=
    pos`` (slot i holds absolute position i), sliding-window ring caches
    pass ``window`` so the kernel masks through the wrapped
    slot-to-position map instead of falling back to the reference path.

    Returns (out (B,1,d), updated cache).
    """
    B = x.shape[0]
    slots = cache["k"].shape[1]
    q, k, v = qkv_proj(p, x, cfg)                     # (B,1,H/K,Dh)
    posv = jnp.broadcast_to(pos, (B,)).astype(jnp.int32)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, posv[:, None], cfg.rope_theta)
        k = apply_rope(k, posv[:, None], cfg.rope_theta)
    slot = posv % slots                               # (B,) ring index
    bidx = jnp.arange(B)
    ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))

    if use_pallas:
        from repro.kernels import ops as kops
        o = kops.flash_decode(q, ck, cv, posv, window=cfg.sliding_window)
        return out_proj(p, constrain(o, "heads")), {"k": ck, "v": cv}

    H, Dh = q.shape[2], q.shape[3]
    K = ck.shape[2]
    G = H // K
    qg = q.reshape(B, 1, K, G, Dh)
    # absolute position held by each ring slot i:  p - ((p - i) mod slots)
    slot_ids = jnp.arange(slots)
    slot_pos = posv[:, None] - ((posv[:, None] - slot_ids[None, :]) % slots)
    valid = (slot_pos >= 0) & (slot_pos <= posv[:, None])  # (B, slots)
    if cfg.sliding_window is not None:
        valid &= (posv[:, None] - slot_pos) < cfg.sliding_window
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck).astype(jnp.float32)
    logits = logits * (Dh ** -0.5)
    logits = jnp.where(valid[:, None, None, None, :], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, cv).reshape(B, 1, H, Dh)
    return out_proj(p, constrain(o, "heads")), {"k": ck, "v": cv}


def attention_decode_paged(p: dict, x: jax.Array, cache: dict,
                           pos: jax.Array, page_table: jax.Array,
                           cfg: ModelConfig, use_pallas: bool = False):
    """One-token decode against a paged KV pool.

    x: (B,1,d); cache k/v: (num_pages, page_size, K, Dh) — the shared
    pool, NOT per-batch; page_table: (B, pages_per_seq) int32 mapping each
    sequence's logical page j to a physical pool page (entry 0 = the null
    page for unallocated tails); pos: scalar or (B,) int32 absolute
    position of the new token.

    The new K/V line lands at physical ``(page_table[b, pos//ps],
    pos % ps)``; attention gathers the pool through the page table into a
    (B, pages_per_seq*ps, K, Dh) logical view and then runs exactly the
    dense full-cache math — masked logits are the same array the dense
    path produces, so greedy decode is bit-identical to the dense cache
    (pool garbage beyond ``pos`` is masked to the same ``_NEG_INF``).

    Full-attention only: the paged pool has no ring layout, so callers
    gate ``sliding_window`` configs to the dense path.

    Returns (out (B,1,d), updated cache).
    """
    assert cfg.sliding_window is None, "paged KV is full-attention only"
    B = x.shape[0]
    ps = cache["k"].shape[1]
    q, k, v = qkv_proj(p, x, cfg)                     # (B,1,H/K,Dh)
    posv = jnp.broadcast_to(pos, (B,)).astype(jnp.int32)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, posv[:, None], cfg.rope_theta)
        k = apply_rope(k, posv[:, None], cfg.rope_theta)
    bidx = jnp.arange(B)
    page = page_table[bidx, posv // ps]               # (B,) physical page
    off = posv % ps
    # live slots own disjoint pages; dead/frozen slots all target the null
    # page, whose contents are never read unmasked
    ck = cache["k"].at[page, off].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[page, off].set(v[:, 0].astype(cache["v"].dtype))

    if use_pallas:
        from repro.kernels import ops as kops
        o = kops.flash_decode_paged(q, ck, cv, page_table, posv)
        return out_proj(p, constrain(o, "heads")), {"k": ck, "v": cv}

    H, Dh = q.shape[2], q.shape[3]
    K = ck.shape[2]
    G = H // K
    n_pages = page_table.shape[1]
    qg = q.reshape(B, 1, K, G, Dh)
    # gather the logical view: (B, n_pages*ps, K, Dh)
    kd = ck[page_table].reshape(B, n_pages * ps, K, Dh)
    vd = cv[page_table].reshape(B, n_pages * ps, K, Dh)
    valid = jnp.arange(n_pages * ps)[None, :] <= posv[:, None]
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kd).astype(jnp.float32)
    logits = logits * (Dh ** -0.5)
    logits = jnp.where(valid[:, None, None, None, :], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, vd).reshape(B, 1, H, Dh)
    return out_proj(p, constrain(o, "heads")), {"k": ck, "v": cv}
