"""Mixture-of-Experts FFN — GShard-style grouped top-k dispatch with
capacity, shared experts (qwen2-moe), router z-loss and load-balance loss.

Tokens are processed in groups of ``moe.group_size`` so the one-hot
dispatch/combine tensors stay O(group * E * capacity) instead of
O(tokens * E * capacity_global).  The group dim carries the batch sharding
(data axis); the expert dim carries expert parallelism (model axis) when
``E % model_axis == 0`` (see core/sharding.py).

FLOPs are capacity-bounded: compiled compute ≈ active-expert compute *
capacity_factor, which is what the roofline's MODEL_FLOPS/HLO_FLOPs ratio
checks against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.actshard import constrain
from repro.models.mlp import mlp_apply


def _capacity(group: int, top_k: int, num_experts: int, factor: float) -> int:
    c = int(math.ceil(group * top_k / num_experts * factor))
    return max(4, ((c + 3) // 4) * 4)  # pad to a multiple of 4


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, d) -> (y: (B, S, d), aux: dict of scalar losses)."""
    moe = cfg.moe
    B, S, d = x.shape
    E, k = moe.num_experts, moe.top_k
    T = B * S
    gs = min(moe.group_size, T)
    xt = x.reshape(T, d)
    T_pad = ((T + gs - 1) // gs) * gs
    if T_pad != T:
        # padded tokens route like real ones but are sliced off at the end;
        # capacity waste is bounded by one group.
        xt = jnp.pad(xt, ((0, T_pad - T), (0, 0)))
    G = T_pad // gs
    C = _capacity(gs, k, E, moe.capacity_factor)
    dtype = x.dtype

    xt = constrain(xt.reshape(G, gs, d), "moe_tokens")
    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)  # (G,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (G,gs,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- aux losses (Switch/GShard) ----
    me = jnp.mean(probs, axis=1)                                   # (G,E)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32),
                  axis=1)                                          # (G,E)
    balance_loss = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- capacity-based dispatch ----
    dispatch = jnp.zeros((G, gs, E, C), dtype)
    combine = jnp.zeros((G, gs, E, C), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(gate_idx[..., j], E, dtype=jnp.int32)  # (G,gs,E)
        pos = jnp.cumsum(oh, axis=1) - 1 + counts[:, None, :]      # (G,gs,E)
        pos_j = jnp.sum(pos * oh, axis=-1)                         # (G,gs)
        within = pos_j < C
        sel = (oh * within[..., None].astype(jnp.int32))           # (G,gs,E)
        cap_oh = jax.nn.one_hot(pos_j, C, dtype=jnp.float32)       # (G,gs,C)
        d_j = sel[..., :, None].astype(jnp.float32) * cap_oh[..., None, :]
        dispatch = dispatch + d_j.astype(dtype)
        combine = combine + d_j * gate_vals[..., j][..., None, None]
        counts = counts + jnp.sum(oh, axis=1)

    # ---- expert compute (einsum over capacity slots) ----
    dispatch = constrain(dispatch, "moe_dispatch")
    ein = constrain(jnp.einsum("gsec,gsd->gecd", dispatch, xt),
                    "moe_expert_d")                                # (G,E,C,d)
    h = constrain(jnp.einsum("gecd,edf->gecf", ein, p["w1"].astype(dtype)),
                  "moe_expert_f")
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(h) * constrain(
            jnp.einsum("gecd,edf->gecf", ein, p["w3"].astype(dtype)),
            "moe_expert_f")
    elif cfg.mlp_type == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jnp.square(jax.nn.relu(h))
    eout = constrain(jnp.einsum("gecf,efd->gecd", h, p["w2"].astype(dtype)),
                     "moe_expert_d")                               # (G,E,C,d)
    y = constrain(jnp.einsum("gsec,gecd->gsd", combine.astype(dtype), eout),
                  "moe_tokens")

    if moe.num_shared:
        y = y + mlp_apply(p["shared"], xt, cfg.mlp_type)

    y = y.reshape(T_pad, d)[:T]

    aux = {
        "moe_balance_loss": balance_loss,
        "moe_z_loss": z_loss,
        "moe_overflow": 1.0 - jnp.mean(
            dispatch.astype(jnp.float32).sum(axis=(2, 3))) / k,
    }
    return y.reshape(B, S, d), aux
