"""Parameter initialization from spec trees (pure JAX, no flax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.spec import ParamSpec, iter_specs, model_spec


def _init_leaf(key, ps: ParamSpec, dtype) -> jax.Array:
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, dtype)
    if ps.init == "a_log":
        # A in [1, 16], stored as log (Mamba-2 convention)
        u = jax.random.uniform(key, ps.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if ps.init == "dt_bias":
        # dt ~ uniform in [1e-3, 1e-1], stored pre-softplus
        u = jax.random.uniform(key, ps.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    scale = ps.scale if ps.scale is not None else 0.02
    return (jax.random.normal(key, ps.shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, seed: int = 0):
    """Materialize the parameter pytree for ``cfg``."""
    dtype = jnp.dtype(cfg.param_dtype)
    spec = model_spec(cfg)
    names = [name for name, _ in iter_specs(spec)]
    keys = dict(zip(names, jax.random.split(jax.random.PRNGKey(seed),
                                            max(len(names), 2))))

    def build(tree, prefix=""):
        if isinstance(tree, ParamSpec):
            return _init_leaf(keys[prefix], tree, dtype)
        if isinstance(tree, dict):
            return {k: build(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        if isinstance(tree, list):
            return [build(v, f"{prefix}/{i}" if prefix else str(i))
                    for i, v in enumerate(tree)]
        raise TypeError(type(tree))

    return build(spec)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    dtype = jnp.dtype(cfg.param_dtype)

    def build(tree):
        if isinstance(tree, ParamSpec):
            return jax.ShapeDtypeStruct(tree.shape, dtype)
        if isinstance(tree, dict):
            return {k: build(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [build(v) for v in tree]
        raise TypeError(type(tree))

    return build(model_spec(cfg))


def param_bytes(cfg: ModelConfig) -> int:
    itemsize = np.dtype(cfg.param_dtype).itemsize
    return sum(ps.size for _, ps in iter_specs(model_spec(cfg))) * itemsize
