"""Model assembly: embedding/frontends -> scanned layer groups -> LM head.

Entry points (all pure functions of (params, batch/cache)):
  * ``forward_train(params, batch, cfg, run)``  -> (logits, aux)
  * ``loss_fn(params, batch, cfg, run)``        -> (loss, metrics)
  * ``prefill(params, batch, cfg, run)``        -> (logits, cache)
  * ``decode_step(params, cache, token, pos, cfg, run)`` -> (logits, cache)
  * ``decode_n(params, cache, token, pos, ...)`` -> N tokens per dispatch
    (``lax.scan`` over ``decode_step`` with fused sampling and device-side
    per-slot stop masking — the serving engine's device-resident fast path)

Layers run as a ``lax.scan`` over stacked layer groups (period P =
lcm(attn_every, moe.every)); compile time is flat in depth.  Remat policy
(``run.remat``) wraps the scan body with ``jax.checkpoint``.

Modality frontends (vision/audio) are stubs per the assignment: the batch
carries precomputed prefix/frame embeddings and this module consumes them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core.actshard import constrain
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.mlp import mlp_apply, rmsnorm
from repro.models.spec import group_period, layer_schedule

AUX_KEYS = ("moe_balance_loss", "moe_z_loss")


# ------------------------------------------------------------ embeddings ----

def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def embed_tokens(params, tokens, cfg: ModelConfig):
    tab = params["embed"]["tok"].astype(compute_dtype(cfg))
    return jnp.take(tab, tokens, axis=0)


def build_hidden(params, batch: dict, cfg: ModelConfig):
    """Assemble the input hidden states from tokens and/or stub embeddings."""
    dtype = compute_dtype(cfg)
    parts = []
    if "prefix_embeddings" in batch:                 # vlm: ViT stub output
        parts.append(batch["prefix_embeddings"].astype(dtype))
    if "frame_embeddings" in batch:                  # audio: codec stub output
        parts.append(batch["frame_embeddings"].astype(dtype))
    if "tokens" in batch:
        parts.append(embed_tokens(params, batch["tokens"], cfg))
    h = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.pos_embedding == "sinusoidal":
        S = h.shape[1]
        pe = A.sinusoidal_pe(jnp.arange(S), cfg.d_model).astype(dtype)
        h = h + pe[None]
    return constrain(h, "hidden")


def unembed(params, h, cfg: ModelConfig):
    w = params["embed"]["tok"] if cfg.tie_embeddings else params["lm_head"]["w"]
    return constrain(jnp.einsum("bsd,vd->bsv", h, w.astype(h.dtype)), "logits")


# ------------------------------------------------------------- sublayers ----

def _zeros_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def sublayer_train(p, x, mixer: str, ffn: str, cfg: ModelConfig,
                   run: RunConfig):
    aux = _zeros_aux()
    h = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
    if mixer == "attn":
        h = A.attention_train(p["attn"], h, cfg, use_pallas=run.use_pallas,
                              unroll=run.unroll,
                              one_block=run.seq_parallel)
    else:
        h = SSM.ssm_train(p["ssm"], h, cfg, use_pallas=run.use_pallas)
    x = constrain(x + h, "hidden")
    if ffn != "none":
        h = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
        if run.seq_parallel:
            # Megatron-SP: gather the bf16 post-norm hidden to full S for
            # the TP FFN; the output constraint below makes XLA emit a
            # reduce-scatter (not all-reduce) for the w2 partial sums.
            h = constrain(h, "hidden_full")
        if ffn == "moe":
            h, moe_aux = MOE.moe_apply(p["moe"], h, cfg)
            for k in AUX_KEYS:
                aux[k] = aux[k] + moe_aux[k]
        else:
            h = mlp_apply(p["mlp"], h, cfg.mlp_type)
        if run.seq_parallel:
            h = constrain(h, "hidden")
        x = constrain(x + h, "hidden")
    return x, aux


# --------------------------------------------------------------- forward ----

def backbone_train(params, h, cfg: ModelConfig, run: RunConfig):
    """Scan the layer groups; returns (h, aux-sums)."""
    P = group_period(cfg)
    sched = layer_schedule(cfg)[:P]

    def group_body(carry, group_params):
        x, acc = carry
        for i, (mixer, ffn) in enumerate(sched):
            x, aux = sublayer_train(group_params[i], x, mixer, ffn, cfg, run)
            acc = {k: acc[k] + aux[k] for k in AUX_KEYS}
        return (x, acc), None

    if run.remat in ("layer", "full"):
        group_body = jax.checkpoint(group_body,
                                    prevent_cse=False)
    if run.unroll:
        carry = (h, _zeros_aux())
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        for g in range(n_groups):
            gp = jax.tree.map(lambda l: l[g], tuple(params["layers"]))
            carry, _ = group_body(carry, gp)
        return carry
    (h, acc), _ = jax.lax.scan(group_body, (h, _zeros_aux()),
                               tuple(params["layers"]))
    return h, acc


def forward_train(params, batch: dict, cfg: ModelConfig, run: RunConfig):
    h = build_hidden(params, batch, cfg)
    h, aux = backbone_train(params, h, cfg, run)
    h = rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    return unembed(params, h, cfg), aux


def softmax_xent(logits, labels, mask):
    """Vocab-parallel-friendly cross entropy (one-hot formulation)."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    oh = jax.nn.one_hot(labels, lg.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(oh * lg, axis=-1)
    per_tok = (lse - ll) * mask
    return jnp.sum(per_tok) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, batch: dict, cfg: ModelConfig, run: RunConfig):
    logits, aux = forward_train(params, batch, cfg, run)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(batch["labels"].shape, jnp.float32)
    xent = softmax_xent(logits, batch["labels"], mask)
    loss = xent
    if cfg.moe is not None:
        loss = (loss + cfg.moe.balance_coef * aux["moe_balance_loss"]
                + cfg.moe.router_z_coef * aux["moe_z_loss"])
    metrics = {"loss": loss, "xent": xent, **aux}
    return loss, metrics


# ----------------------------------------------------------------- cache ----

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               abstract: bool = False, paging=None):
    """Decode cache pytree: one entry per sublayer slot, stacked over groups.

    ``paging`` (a :class:`repro.models.paging.PagedKVConfig`) makes every
    attention sublayer a shared page pool instead of ``batch`` dense rows;
    decode must then pass the matching ``page_table``.  Paged mode is
    full-attention only (SSM state is not line-addressable), so callers
    gate on ``cfg.ssm is None``.
    """
    P = group_period(cfg)
    n_groups = cfg.num_layers // P
    sched = layer_schedule(cfg)[:P]
    layers = []
    for mixer, _ in sched:
        if mixer == "attn":
            layers.append(A.init_kv_cache(cfg, batch, cache_len, n_groups,
                                          abstract=abstract, paging=paging))
        else:
            assert paging is None, "paged KV cache is attention-only"
            layers.append(SSM.init_ssm_cache(cfg, batch, n_groups,
                                             abstract=abstract))
    return {"layers": layers}


def cache_logical_axes(cfg: ModelConfig, paging: bool = False):
    """Logical axes pytree matching ``init_cache`` (see core/sharding.py)."""
    P = group_period(cfg)
    sched = layer_schedule(cfg)[:P]
    layers = []
    for mixer, _ in sched:
        if mixer == "attn":
            # paged pools have no batch dim: pages replace (batch, seq)
            ax = (("layers", None, "cache_seq", "kv_heads", "head_dim")
                  if paging else
                  ("layers", "batch", "cache_seq", "kv_heads", "head_dim"))
            layers.append({"k": ax, "v": ax})
        else:
            layers.append({
                "state": ("layers", "batch", "ssm_head", None, "ssm_state"),
                "conv": ("layers", "batch", "conv", None),
            })
    return {"layers": layers}


def cache_kv_head_dim(cfg: ModelConfig, paging: bool = False) -> int:
    """Index of the ``kv_heads`` axis in every attention KV-cache leaf.

    All engine-level cache layouts — the paged pool ``(G, pages, ps, K,
    Dh)``, dense rows ``(G, B, slots, K, Dh)``, and one-request
    prefill/chunk slices ``(G, B, S, K, Dh)`` — carry ``kv_heads`` at
    the same position, which is what lets serving TP cover the whole
    cache pytree with a single PartitionSpec prefix
    (``serving.tp.cache_pspec``).  Derived from
    :func:`cache_logical_axes` so a future layout change breaks loudly
    here instead of silently mis-sharding."""
    for leaf in cache_logical_axes(cfg, paging=paging)["layers"]:
        for axes in leaf.values():
            if "kv_heads" in axes:
                return axes.index("kv_heads")
    raise ValueError(
        f"no attention KV leaf in the cache for cfg with ssm={cfg.ssm!r}")


# ---------------------------------------------------------------- prefill ----

def prefill(params, batch: dict, cfg: ModelConfig, run: RunConfig,
            cache_len: Optional[int] = None,
            last_pos: Optional[jax.Array] = None,
            front_pad: Optional[jax.Array] = None,
            num_real: Optional[jax.Array] = None):
    """Run the full prompt, return (last-position logits, populated cache).

    ``last_pos`` (traced scalar int32, optional) selects which position's
    logits to return instead of the literal last one.  Bucketed prefill
    pads prompts up to a power-of-two length L and passes ``P - 1`` here:
    causal masking guarantees positions < P never attend the pad tail, so
    the logits at P-1 are exactly the unpadded prompt's (the pad KV lines
    written past P-1 stay masked at decode time until overwritten).

    ``front_pad``/``num_real`` (traced int32, optional, both or neither)
    switch to FRONT-padded bucketing for SSM/hybrid configs, whose state
    scan cannot ride the causal-mask-only tail-pad argument: the real
    tokens sit at ``[front_pad, front_pad + num_real)``, pad positions
    are explicitly masked out of attention kv and of the SSD recurrence
    (``dt=0`` identity steps), RoPE/causal positions shift to
    ``arange(S) - front_pad``, and attention KV lines rotate back so
    real tokens land at cache lines ``[0, num_real)``.  Callers align
    ``front_pad`` to a multiple of ``cfg.ssm.chunk`` so the real tokens'
    chunk offsets — and the f32 scan — match the unpadded run bit for
    bit.  Pass ``last_pos = front_pad + num_real - 1``.  Requires
    ``cfg.pos_embedding != "sinusoidal"`` (that PE is added before the
    shift is known) and no sliding-window ring.
    """
    P = group_period(cfg)
    sched = layer_schedule(cfg)[:P]
    h = build_hidden(params, batch, cfg)
    S = h.shape[1]
    cache_len = cache_len or S
    slots = min(cache_len, cfg.sliding_window or cache_len)
    positions = valid = None
    if front_pad is not None:
        assert num_real is not None
        assert cfg.pos_embedding != "sinusoidal", \
            "front-padded prefill: sinusoidal PE is applied in " \
            "build_hidden, before the position shift"
        idx = jnp.arange(S)
        valid = (idx >= front_pad) & (idx < front_pad + num_real)
        positions = idx - front_pad

    def group_body(x, group_params):
        new_caches = []
        for i, (mixer, _ffn) in enumerate(sched):
            p = group_params[i]
            hh = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
            if mixer == "attn":
                hh, c = A.attention_prefill(p["attn"], hh, cfg, slots,
                                            use_pallas=run.use_pallas,
                                            unroll=run.unroll,
                                            positions=positions,
                                            valid=valid, roll=front_pad)
            else:
                hh, c = SSM.ssm_prefill(
                    p["ssm"], hh, cfg, use_pallas=run.use_pallas,
                    valid=valid,
                    conv_end=(None if front_pad is None
                              else front_pad + num_real))
            x = constrain(x + hh, "hidden")
            ffn = sched[i][1]
            if ffn != "none":
                hh = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
                if ffn == "moe":
                    hh, _ = MOE.moe_apply(p["moe"], hh, cfg)
                else:
                    hh = mlp_apply(p["mlp"], hh, cfg.mlp_type)
                x = constrain(x + hh, "hidden")
            new_caches.append(c)
        return x, tuple(new_caches)

    if run.remat in ("layer", "full"):
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    if run.unroll:
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        per_group = []
        for g in range(n_groups):
            gp = jax.tree.map(lambda l: l[g], tuple(params["layers"]))
            h, c = group_body(h, gp)
            per_group.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    else:
        h, caches = jax.lax.scan(group_body, h, tuple(params["layers"]))
    h = rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if last_pos is None:
        h_last = h[:, -1:]
    else:
        h_last = jax.lax.dynamic_slice_in_dim(h, last_pos, 1, axis=1)
    logits = unembed(params, h_last, cfg)
    return logits, {"layers": list(caches)}


def prefill_suffix(params, batch: dict, cache, page_table, start,
                   cfg: ModelConfig, run: RunConfig,
                   last_pos: Optional[jax.Array] = None):
    """Prefill from a page-aligned offset against cached prefix pages
    (the prefix-cache reuse path: only the prompt's un-cached tail runs).

    ``batch["tokens"]``: (B, S) the *suffix* tokens, at absolute
    positions ``start + [0, S)``; ``cache``: the paged pool pytree
    (read-only here); ``page_table``: (B, n_prefix_pages) rows whose
    first ``ceil(start / page_size)`` entries are the request's prefix
    pages; ``start``: scalar int32 prefix length — page-aligned on the
    prefix-cache path, but ANY position works (the prefix mask is
    line-granular; see :func:`prefill_chunk`); ``last_pos``: like
    :func:`prefill` — bucketed suffixes pass the true last *local*
    index.

    Returns (logits (B,1,V), {"layers": [...]} suffix cache slices, each
    (G, B, S, K, Dh)) — the caller scatters the slices into its
    privately-owned pages; the shared pages are never written.
    Full-attention configs only (paging already gates SSM/ring out).
    """
    P = group_period(cfg)
    sched = layer_schedule(cfg)[:P]
    assert all(mixer == "attn" for mixer, _ in sched), \
        "prefix reuse is full-attention only"
    h = embed_tokens(params, batch["tokens"], cfg)
    S = h.shape[1]
    if cfg.pos_embedding == "sinusoidal":
        pe = A.sinusoidal_pe(start + jnp.arange(S), cfg.d_model)
        h = h + pe[None].astype(h.dtype)
    h = constrain(h, "hidden")

    def group_body(x, inp):
        group_params, group_cache = inp
        new_caches = []
        for i, (_mixer, ffn) in enumerate(sched):
            p = group_params[i]
            hh = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
            hh, c = A.attention_prefill_paged(p["attn"], hh, group_cache[i],
                                              page_table, start, cfg)
            x = constrain(x + hh, "hidden")
            if ffn != "none":
                hh = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
                if ffn == "moe":
                    hh, _ = MOE.moe_apply(p["moe"], hh, cfg)
                else:
                    hh = mlp_apply(p["mlp"], hh, cfg.mlp_type)
                x = constrain(x + hh, "hidden")
            new_caches.append(c)
        return x, tuple(new_caches)

    if run.remat in ("layer", "full"):
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    if run.unroll:
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        per_group = []
        for g in range(n_groups):
            gp = jax.tree.map(lambda l: l[g], tuple(params["layers"]))
            gc = jax.tree.map(lambda l: l[g], tuple(cache["layers"]))
            h, c = group_body(h, (gp, gc))
            per_group.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    else:
        h, caches = jax.lax.scan(
            group_body, h, (tuple(params["layers"]), tuple(cache["layers"])))
    h = rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if last_pos is None:
        h_last = h[:, -1:]
    else:
        h_last = jax.lax.dynamic_slice_in_dim(h, last_pos, 1, axis=1)
    return unembed(params, h_last, cfg), {"layers": list(caches)}


def prefill_chunk(params, batch: dict, cache, page_table, start,
                  cfg: ModelConfig, run: RunConfig,
                  last_pos: Optional[jax.Array] = None):
    """One chunk of a chunked (continuous-batching) prefill.

    Chunked prefill IS suffix prefill applied repeatedly: chunk ``i``
    treats the ``start = pos_filled`` tokens already written to the
    request's pages as the "prefix" and its own ``S`` tokens as the
    "suffix", so this is :func:`prefill_suffix` with two relaxations the
    underlying attention already supports:

    * ``start`` is NOT page-aligned in general — a chunk boundary can
      land mid-page.  ``attention_prefill_paged`` masks the gathered
      prefix at line granularity (``arange(L) < start``), so the
      partially-filled last page contributes exactly its live lines.
      Only the caller's KV *scatter* needs mid-page placement (the
      engine's per-line chunk insert).
    * ``page_table`` rows cover the pages holding ``[0, start + S)`` —
      the engine grows the request's holdings to ceil((start+S)/page)
      pages before dispatching the chunk.

    Chunks pad to a small fixed bucket set (powers of two up to the
    token budget), so the serving step compiles O(chunk buckets) times;
    ``last_pos`` selects the last REAL token's logits, and the final
    chunk's logits seed decode exactly like a whole-prompt prefill's.
    Greedy decode after N chunks is bit-identical to one whole-prompt
    prefill: every chunk runs the same masked attention math over the
    same absolute positions, and the f32-partial-sum combine in
    ``attention_prefill_paged`` avoids the double-rounding a bf16
    prefix/suffix split would introduce.

    Returns (logits (B,1,V), {"layers": [...]} chunk KV slices, each
    (G, B, S, K, Dh)).
    """
    return prefill_suffix(params, batch, cache, page_table, start, cfg,
                          run, last_pos=last_pos)


# ----------------------------------------------------------------- decode ----

def decode_step(params, cache, token, pos, cfg: ModelConfig, run: RunConfig,
                page_table=None):
    """One decoding step.  token: (B, 1) int32; pos: scalar int32 OR (B,)
    int32 (0-based absolute position of each new token — vector form for
    continuous batching).  ``page_table`` ((B, n_pages) int32) routes
    attention through the paged KV pool instead of dense per-slot rows.
    Returns (logits (B,1,V), new cache)."""
    P = group_period(cfg)
    sched = layer_schedule(cfg)[:P]
    B = token.shape[0]
    h = embed_tokens(params, token, cfg)
    if cfg.pos_embedding == "sinusoidal":
        posv = jnp.broadcast_to(pos, (B,))
        pe = A.sinusoidal_pe(posv[:, None], cfg.d_model)   # (B,1,d)
        h = h + pe.astype(h.dtype)

    def group_body(x, inp):
        group_params, group_cache = inp
        new_caches = []
        for i, (mixer, ffn) in enumerate(sched):
            p = group_params[i]
            hh = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
            if mixer == "attn":
                if page_table is not None:
                    hh, c = A.attention_decode_paged(
                        p["attn"], hh, group_cache[i], pos, page_table,
                        cfg, use_pallas=run.use_pallas)
                else:
                    hh, c = A.attention_decode(p["attn"], hh, group_cache[i],
                                               pos, cfg,
                                               use_pallas=run.use_pallas)
            else:
                hh, c = SSM.ssm_decode(p["ssm"], hh, group_cache[i], cfg)
            x = constrain(x + hh, "hidden")
            if ffn != "none":
                hh = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
                if ffn == "moe":
                    hh, _ = MOE.moe_apply(p["moe"], hh, cfg)
                else:
                    hh = mlp_apply(p["mlp"], hh, cfg.mlp_type)
                x = constrain(x + hh, "hidden")
            new_caches.append(c)
        return x, tuple(new_caches)

    if run.unroll:
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        per_group = []
        for g in range(n_groups):
            gp = jax.tree.map(lambda l: l[g], tuple(params["layers"]))
            gc = jax.tree.map(lambda l: l[g], tuple(cache["layers"]))
            h, c = group_body(h, (gp, gc))
            per_group.append(c)
        new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    else:
        h, new_layers = jax.lax.scan(
            group_body, h, (tuple(params["layers"]), tuple(cache["layers"])))
    h = rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params, h, cfg)
    return logits, {"layers": list(new_layers)}


def verify_tokens(params, cache, tokens, pos0, pages, offs, page_table,
                  cfg: ModelConfig, run: RunConfig):
    """Score S = k+1 tokens per slot in ONE dispatch (speculative
    draft-and-verify).

    tokens: (B, S) int32 — ``tokens[:, 0]`` is the slot's last sampled
    token (its KV line is written now, exactly where the next decode
    step would have written it) and ``tokens[:, 1:]`` are the k drafted
    continuations; pos0: (B,) int32 absolute position of ``tokens[:,
    0]``; pages/offs: (B, S) per-line physical scatter targets (null
    page beyond a slot's allocation / for dead slots); page_table as in
    :func:`decode_step`.

    ``logits[:, j]`` predicts position ``pos0 + j + 1``, so comparing
    ``argmax(logits[:, j])`` against ``tokens[:, j + 1]`` decides
    acceptance of draft j: the longest agreeing run under greedy, or a
    rejection-sampling walk under temperature.  Each row's math is the
    paged decode step's bit for bit (see
    :func:`repro.models.attention.attention_verify`), so greedy
    speculation is bit-identical to sequential decode.

    Returns (logits (B, S, V), new cache).  Full-attention configs only
    (paging already gates SSM/ring out).
    """
    P = group_period(cfg)
    sched = layer_schedule(cfg)[:P]
    assert all(mixer == "attn" for mixer, _ in sched), \
        "speculative verify is full-attention only"
    h = embed_tokens(params, tokens, cfg)
    if cfg.pos_embedding == "sinusoidal":
        S = tokens.shape[1]
        posm = pos0[:, None] + jnp.arange(S)[None, :]
        pe = A.sinusoidal_pe(posm, cfg.d_model)            # (B,S,d)
        h = h + pe.astype(h.dtype)
    h = constrain(h, "hidden")

    def group_body(x, inp):
        group_params, group_cache = inp
        new_caches = []
        for i, (_mixer, ffn) in enumerate(sched):
            p = group_params[i]
            hh = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
            hh, c = A.attention_verify(p["attn"], hh, group_cache[i],
                                       pos0, pages, offs, page_table, cfg)
            x = constrain(x + hh, "hidden")
            if ffn != "none":
                hh = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
                if ffn == "moe":
                    hh, _ = MOE.moe_apply(p["moe"], hh, cfg)
                else:
                    hh = mlp_apply(p["mlp"], hh, cfg.mlp_type)
                x = constrain(x + hh, "hidden")
            new_caches.append(c)
        return x, tuple(new_caches)

    if run.unroll:
        n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
        per_group = []
        for g in range(n_groups):
            gp = jax.tree.map(lambda l: l[g], tuple(params["layers"]))
            gc = jax.tree.map(lambda l: l[g], tuple(cache["layers"]))
            h, c = group_body(h, (gp, gc))
            per_group.append(c)
        new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    else:
        h, new_layers = jax.lax.scan(
            group_body, h, (tuple(params["layers"]), tuple(cache["layers"])))
    h = rmsnorm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params, h, cfg)
    return logits, {"layers": list(new_layers)}


# ------------------------------------------------- fused decode fast path ----

#: token emitted by finished slots inside a decode_n chunk (host drops them)
PAD_TOKEN_ID = 0


def sample_tokens(key, logits, temps):
    """Fused per-slot sampling on device.  logits: (B, V); temps: (B,)
    (0 => greedy).  Splits ``key`` exactly like the host sampler did
    (``categorical`` on ``logits / max(t, 1e-4)``), so host and fused
    paths are bit-identical given the same key stream."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temps, 1e-4)[:, None]
    sampled = jax.random.categorical(
        key, logits.astype(jnp.float32) / t, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def decode_n(params, cache, token, pos, remaining, done, eos, temps, key,
             cfg: ModelConfig, run: RunConfig, num_tokens: int,
             cache_len: int, page_table=None, limit=None):
    """Generate up to ``num_tokens`` tokens per slot in ONE dispatch.

    A ``lax.scan`` over ``decode_step`` with sampling and stop handling
    fused on device, so the host syncs (and pays a dispatch) once per
    chunk instead of once per token:

      * sampling — per-slot temperature vector, PRNG key threaded through
        the scan (split once per generated token, matching the host path);
      * stop masking — a slot finishes on EOS, on ``remaining`` hitting 0,
        or at the cache boundary; finished slots emit ``PAD_TOKEN_ID``,
        stop advancing ``pos``/``remaining``, and re-feed their frozen
        final (token, pos) — deterministic, repeated writes confined to
        the finished slot's own cache row (replaced wholesale at the next
        admission), so live slots stay bit-stable.

    Args (all device arrays, B = num_slots):
      token (B,) int32   last sampled token per slot
      pos (B,) int32     absolute position of ``token`` (its KV write index)
      remaining (B,) int32  tokens the slot may still generate
      done (B,) bool     slot finished / empty (frozen for the whole chunk)
      eos (B,) int32     per-slot EOS id, -1 = none
      temps (B,) float32 per-slot sampling temperature, 0 = greedy
      key                PRNG key (consumed; the advanced key is returned)
      page_table (B, n_pages) int32, optional — paged-KV routing
      limit (B,) int32, optional — per-slot cache capacity (paged mode:
        ``allocated_pages * page_size``, so a slot freezes at its own
        allocation boundary instead of the global ``cache_len``; None
        keeps the dense scalar boundary, bit-identical to before)

    Returns ``(tokens (B, N), cache, token, pos, remaining, done, key)``;
    per slot the first ``new_pos - old_pos`` entries of ``tokens`` are
    real, the rest pad.
    """
    boundary = (cache_len - 1) if limit is None else (limit - 1)

    def body(carry, _):
        cache, tok, pos, rem, done, key = carry
        logits, cache = decode_step(params, cache, tok[:, None], pos, cfg,
                                    run, page_table=page_table)
        key, sub = jax.random.split(key)
        nxt = sample_tokens(sub, logits[:, 0], temps)
        live = jnp.logical_not(done)
        emit = jnp.where(live, nxt, PAD_TOKEN_ID)
        new_pos = jnp.where(live, pos + 1, pos)
        new_rem = jnp.where(live, rem - 1, rem)
        hit_eos = (eos >= 0) & (nxt == eos)
        new_done = done | (live & (hit_eos | (new_rem <= 0)
                                   | (new_pos >= boundary)))
        new_tok = jnp.where(live, nxt, tok)
        return (cache, new_tok, new_pos, new_rem, new_done, key), emit

    carry = (cache, token, pos, remaining, done, key)
    (cache, token, pos, remaining, done, key), toks = jax.lax.scan(
        body, carry, None, length=num_tokens)
    return toks.T, cache, token, pos, remaining, done, key
