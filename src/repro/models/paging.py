"""Paged KV cache: the page-table memory manager behind the serving engine.

The dense decode cache pins ``cache_len`` KV lines per slot for a request's
whole lifetime — a short request strands HBM exactly the way an idle node
strands a SLURM partition.  Paging (vLLM's PagedAttention) breaks the cache
into fixed-size *pages* drawn from one device-resident pool:

* **pool** — ``(n_groups, num_pages, page_size, K, Dh)`` per attention
  sublayer, allocated once (``models.attention.init_kv_cache(paging=...)``);
* **page table** — per-slot ``(pages_per_seq,)`` int32 mapping logical page
  ``j`` (KV lines ``[j*page_size, (j+1)*page_size)``) to a physical page in
  the pool, shared by every layer/group (each layer has its own pool but
  the same logical allocation);
* **allocator** (this module, host-side) — free-list with all-or-nothing
  grants, on-demand growth at decode-time page boundaries, and
  eviction-aware reclaim (the engine frees a preempted victim's pages back
  here before retrying a blocked allocation).

Physical page 0 is the **null page**: never granted, it backs unallocated
page-table entries so frozen/dead slots have a harmless in-bounds write
target inside jitted decode chunks.  Its contents are garbage by design
and are always masked out of attention.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

#: physical page id backing every unallocated page-table entry
NULL_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` KV lines (ceil division)."""
    return -(-int(tokens) // int(page_size)) if tokens > 0 else 0


@dataclass(frozen=True)
class PagedKVConfig:
    """Shape of one paged cache pool.

    ``num_pages`` counts the null page, so usable capacity is
    ``(num_pages - 1) * page_size`` KV lines.
    """
    page_size: int                 # KV lines per page
    num_pages: int                 # physical pages in the pool (incl. null)
    pages_per_seq: int             # logical pages per request (= page-table width)

    def __post_init__(self):
        assert self.page_size >= 1
        assert self.num_pages >= 2, "pool needs the null page + 1 usable page"
        assert self.pages_per_seq >= 1

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    @property
    def capacity_tokens(self) -> int:
        return self.usable_pages * self.page_size

    @classmethod
    def for_budget(cls, budget_tokens: int, page_size: int,
                   cache_len: int) -> "PagedKVConfig":
        """Pool sized to a dense-equivalent HBM budget of
        ``budget_tokens`` KV lines (plus the null page)."""
        assert cache_len % page_size == 0, (cache_len, page_size)
        return cls(page_size=page_size,
                   num_pages=pages_for(budget_tokens, page_size) + 1,
                   pages_per_seq=cache_len // page_size)


class PageAllocator:
    """Host-side free-list over the physical pages of one pool, with
    per-page reference counts.

    Grants are **all-or-nothing**: a request that needs ``n`` pages either
    gets ``n`` or ``None``, so a half-grown request never wedges the pool.
    Page 0 (the null page) is reserved and never granted.

    Reference counts back prefix sharing (``serving.prefix``): a page
    mapped read-only into several page tables — or pinned by the radix
    index itself — carries one reference per holder.  :meth:`alloc`
    grants pages at refcount 1, :meth:`ref` adds holders, and
    :meth:`free` *decrements*: the page returns to the free list only
    when its last holder lets go, so a shared prefix page outlives any
    single request.  A page is never simultaneously free and referenced
    (asserted; property-tested in ``tests/test_prefix.py``).
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, num_pages
        self.num_pages = num_pages
        # LIFO free list: recently-freed pages are re-granted first, which
        # keeps the hot working set of physical pages small
        self._free = list(range(num_pages - 1, NULL_PAGE, -1))
        self._refs = [0] * num_pages
        self._in_use = 0
        self.high_water = 0

    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Pages with at least one holder."""
        return self._in_use

    def refcount(self, page: int) -> int:
        return self._refs[page]

    @property
    def total_refs(self) -> int:
        """Sum of refcounts over all pages (= page-table occupancy plus
        index pins; the property tests' conservation quantity)."""
        return sum(self._refs)

    def alloc(self, n: int):
        """Grant ``n`` pages (refcount 1 each) or None (all-or-nothing)."""
        if n <= 0:
            return []
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self._refs[p] == 0, (p, self._refs[p])
            self._refs[p] = 1
        self._in_use += n
        self.high_water = max(self.high_water, self._in_use)
        return pages

    def ref(self, pages):
        """Add one holder to each page (must already be allocated)."""
        for p in pages:
            assert NULL_PAGE < p < self.num_pages, p
            assert self._refs[p] > 0, f"ref on free page {p}"
            self._refs[p] += 1

    def free(self, pages):
        """Drop one holder per page; a page whose last holder leaves
        returns to the pool (idempotence is the caller's job)."""
        for p in pages:
            assert NULL_PAGE < p < self.num_pages, p
            assert self._refs[p] > 0, f"double free of page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                self._in_use -= 1
        assert self._in_use >= 0, self._in_use


class ShardedAllocatorView:
    """Per-shard budget view over one :class:`PageAllocator`.

    Under tensor parallelism every device holds its own head-slice of
    every physical page, so one *logical* page id stands for ``shards``
    device-local page slices allocated and freed in lockstep.  Today the
    slices are symmetric — granting logical page ``p`` consumes one page
    on every shard — so each budget vector is the scalar broadcast.  The
    vector API is the contract that matters: admission
    (``_fits_pages``/``_ensure_pages``/GrpTRES billing) consumes
    per-shard minima, which is exactly the shape disaggregated serving
    (prefill and decode pools on different shard sets) needs.
    """

    def __init__(self, allocator: PageAllocator, shards: int = 1):
        assert shards >= 1, shards
        self.allocator = allocator
        self.shards = shards

    def available_vector(self) -> np.ndarray:
        """(shards,) free pages per shard."""
        return np.full(self.shards, self.allocator.available(), np.int64)

    def in_use_vector(self) -> np.ndarray:
        """(shards,) pages with >= 1 holder, per shard."""
        return np.full(self.shards, self.allocator.in_use, np.int64)

    def min_available(self) -> int:
        """Pages grantable on EVERY shard — the admission budget."""
        return int(self.available_vector().min())


class TwoLevelPageTable:
    """(directory, leaf) two-level logical->physical page map (host side).

    A flat per-slot row is ``pages_per_seq`` int32 wide — growing
    ``cache_len`` to long-context sizes scales every slot's table with
    it even when the slot holds a 30-token chat turn.  Here each slot
    keeps a *directory* (dict: leaf index -> ``leaf_size``-wide int32
    leaf, allocated on first touch), so host memory scales with pages
    actually mapped, not with ``slots * pages_per_seq``.

    Device dispatches still need a dense array; :meth:`dense`
    materializes rows at a caller-chosen width (the engine buckets the
    dispatch width to powers of two and grows it monotonically, so the
    jitted decode programs recompile O(log pages_per_seq) times, not per
    width).  :meth:`max_width` reports the minimal width covering every
    live mapping.
    """

    def __init__(self, num_slots: int, pages_per_seq: int,
                 leaf_size: int = 32):
        assert num_slots >= 1 and pages_per_seq >= 1
        self.num_slots = num_slots
        self.pages_per_seq = pages_per_seq
        self.leaf_size = min(int(leaf_size), pages_per_seq)
        self._dirs: list[dict] = [{} for _ in range(num_slots)]
        #: per-slot logical width = 1 + highest mapped index (0 = empty)
        self._widths = [0] * num_slots

    def _leaf(self, slot: int, li: int) -> np.ndarray:
        leaf = self._dirs[slot].get(li)
        if leaf is None:
            leaf = np.full(self.leaf_size, NULL_PAGE, np.int32)
            self._dirs[slot][li] = leaf
        return leaf

    def clear(self, slot: int):
        """Reset a slot's row to all-NULL (drops its leaves)."""
        self._dirs[slot] = {}
        self._widths[slot] = 0

    def set_range(self, slot: int, start: int, pages):
        """Map logical pages ``[start, start + len(pages))`` to ``pages``."""
        n = len(pages)
        if n == 0:
            return
        assert start >= 0 and start + n <= self.pages_per_seq, \
            (start, n, self.pages_per_seq)
        arr = np.asarray(pages, np.int32)
        i = 0
        while i < n:
            li, off = divmod(start + i, self.leaf_size)
            take = min(self.leaf_size - off, n - i)
            self._leaf(slot, li)[off:off + take] = arr[i:i + take]
            i += take
        self._widths[slot] = max(self._widths[slot], start + n)

    def row(self, slot: int, width: int = None) -> np.ndarray:
        """Dense (width,) int32 row for one slot (default: full width)."""
        width = self.pages_per_seq if width is None else width
        out = np.full(width, NULL_PAGE, np.int32)
        for li, leaf in self._dirs[slot].items():
            lo = li * self.leaf_size
            if lo >= width:
                continue
            take = min(self.leaf_size, width - lo)
            out[lo:lo + take] = leaf[:take]
        return out

    def dense(self, width: int = None) -> np.ndarray:
        """Dense (num_slots, width) materialization (device dispatch /
        test introspection)."""
        width = self.pages_per_seq if width is None else width
        return np.stack([self.row(s, width) for s in
                         range(self.num_slots)])

    def max_width(self) -> int:
        """Smallest dense width covering every live mapping."""
        return max(self._widths, default=0)

    @property
    def directory_leaves(self) -> int:
        """Allocated leaves across all slots (host-memory footprint in
        units of ``leaf_size`` int32 — the two-level win over
        ``num_slots * pages_per_seq``)."""
        return sum(len(d) for d in self._dirs)
