"""Parameter spec trees — the single source of truth for shapes, logical
sharding axes, and init styles of every model family.

A spec tree mirrors the parameter pytree; each leaf is a :class:`ParamSpec`.
``repro.models.init`` materializes arrays from it, and
``repro.core.sharding`` maps the logical axes onto mesh axes per parallelism
strategy — so model code never mentions mesh axes directly.

Layer stacking: layers repeat with period ``P = lcm(attn_every, moe.every)``;
parameters of the P sublayers are stacked with a leading ``layers`` axis of
size ``num_layers // P`` and the forward pass is a ``lax.scan`` over groups
(compile time stays flat in depth).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ModelConfig

# Logical axis vocabulary (see core/sharding.py for the mesh mapping):
#   layers     — scan-stacking axis (sharded only under pipeline parallelism)
#   vocab      — vocabulary dim              (tensor-parallel candidate)
#   embed      — model/residual dim          (ZeRO/FSDP candidate)
#   heads      — attention query heads       (tensor-parallel candidate)
#   kv_heads   — attention kv heads          (tensor-parallel candidate)
#   head_dim   — per-head dim                (never sharded)
#   ffn        — FFN hidden dim              (tensor-parallel candidate)
#   experts    — MoE expert dim              (expert-parallel candidate)
#   ssm_inner  — SSD inner dim               (tensor-parallel candidate)
#   ssm_head   — SSD heads                   (tensor-parallel candidate)
#   ssm_state / conv — SSD small dims        (never sharded)


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | a_log | dt_bias
    scale: Optional[float] = None  # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    def stacked(self, n: int) -> "ParamSpec":
        return ParamSpec((n,) + self.shape, ("layers",) + self.axes,
                         self.init, self.scale)


def _norm(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def attention_spec(cfg: ModelConfig) -> dict:
    d, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = d ** -0.5
    spec = {
        "wq": ParamSpec((d, H, Dh), ("embed", "heads", "head_dim"), scale=s),
        "wk": ParamSpec((d, K, Dh), ("embed", "kv_heads", "head_dim"), scale=s),
        "wv": ParamSpec((d, K, Dh), ("embed", "kv_heads", "head_dim"), scale=s),
        "wo": ParamSpec((H, Dh, d), ("heads", "head_dim", "embed"),
                        scale=(H * Dh) ** -0.5),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((H, Dh), ("heads", "head_dim"), "zeros")
        spec["bk"] = ParamSpec((K, Dh), ("kv_heads", "head_dim"), "zeros")
        spec["bv"] = ParamSpec((K, Dh), ("kv_heads", "head_dim"), "zeros")
    return spec


def ssm_spec(cfg: ModelConfig) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    H = ssm.num_heads(d)
    N = ssm.state
    conv_ch = di + 2 * N              # conv over [x, B, C]
    s = d ** -0.5
    return {
        "wz": ParamSpec((d, di), ("embed", "ssm_inner"), scale=s),
        "wx": ParamSpec((d, di), ("embed", "ssm_inner"), scale=s),
        "wB": ParamSpec((d, N), ("embed", "ssm_state"), scale=s),
        "wC": ParamSpec((d, N), ("embed", "ssm_state"), scale=s),
        "wdt": ParamSpec((d, H), ("embed", "ssm_head"), scale=s),
        # conv channel dim is the concat [x, B, C] — semantically unsplittable
        # under TP (B/C must be replicated per head shard); ZeRO may still
        # storage-shard it on the data axis.
        "conv_w": ParamSpec((ssm.conv_width, conv_ch), ("conv", None),
                            scale=ssm.conv_width ** -0.5),
        "conv_b": ParamSpec((conv_ch,), (None,), "zeros"),
        "A_log": ParamSpec((H,), ("ssm_head",), "a_log"),
        "D": ParamSpec((H,), ("ssm_head",), "ones"),
        "dt_bias": ParamSpec((H,), ("ssm_head",), "dt_bias"),
        "norm_scale": ParamSpec((di,), ("ssm_inner",), "ones"),
        "wo": ParamSpec((di, d), ("ssm_inner", "embed"), scale=di ** -0.5),
    }


def mlp_spec(d: int, f: int, mlp_type: str) -> dict:
    s_in, s_out = d ** -0.5, f ** -0.5
    spec = {
        "w1": ParamSpec((d, f), ("embed", "ffn"), scale=s_in),
        "w2": ParamSpec((f, d), ("ffn", "embed"), scale=s_out),
    }
    if mlp_type == "swiglu":
        spec["w3"] = ParamSpec((d, f), ("embed", "ffn"), scale=s_in)
    return spec


def moe_spec(cfg: ModelConfig) -> dict:
    moe = cfg.moe
    d, E, f = cfg.d_model, moe.num_experts, moe.d_ff
    s_in, s_out = d ** -0.5, f ** -0.5
    spec = {
        "router": ParamSpec((d, E), ("embed", None), scale=s_in),
        "w1": ParamSpec((E, d, f), ("experts", "embed", "ffn"), scale=s_in),
        "w2": ParamSpec((E, f, d), ("experts", "ffn", "embed"), scale=s_out),
    }
    if cfg.mlp_type == "swiglu":
        spec["w3"] = ParamSpec((E, d, f), ("experts", "embed", "ffn"),
                               scale=s_in)
    if moe.num_shared:
        spec["shared"] = mlp_spec(d, f * moe.num_shared, cfg.mlp_type)
    return spec


def sublayer_spec(cfg: ModelConfig, mixer: str, ffn: str) -> dict:
    d = cfg.d_model
    spec = {"norm1": _norm(d)}
    if mixer == "attn":
        spec["attn"] = attention_spec(cfg)
    else:
        spec["ssm"] = ssm_spec(cfg)
    if ffn != "none":
        spec["norm2"] = _norm(d)
        if ffn == "moe":
            spec["moe"] = moe_spec(cfg)
        else:
            spec["mlp"] = mlp_spec(d, cfg.d_ff, cfg.mlp_type)
    return spec


def layer_schedule(cfg: ModelConfig) -> list[tuple[str, str]]:
    """(mixer, ffn) per layer.

    Pure-SSM archs (mamba2) have no separate FFN — the mamba block is the
    whole layer.  MoE-every-layer archs have ffn='moe' everywhere.
    """
    mixers = cfg.layer_kinds()
    if cfg.family == "ssm":
        ffns = ["none"] * cfg.num_layers
    else:
        ffns = cfg.ffn_kinds()
    return list(zip(mixers, ffns))


def group_period(cfg: ModelConfig) -> int:
    sched = layer_schedule(cfg)
    for p in range(1, len(sched) + 1):
        if len(sched) % p == 0 and all(
            sched[i] == sched[i % p] for i in range(len(sched))
        ):
            return p
    return len(sched)


def model_spec(cfg: ModelConfig) -> dict:
    """Full parameter spec tree (stacked layer groups)."""
    P = group_period(cfg)
    n_groups = cfg.num_layers // P
    sched = layer_schedule(cfg)
    sublayers = []
    for i in range(P):
        mixer, ffn = sched[i]
        sub = sublayer_spec(cfg, mixer, ffn)
        sublayers.append(_map_specs(sub, lambda ps: ps.stacked(n_groups)))
    spec = {
        "embed": {
            "tok": ParamSpec((cfg.vocab_size, cfg.d_model),
                             ("vocab", "embed"), scale=1.0 * cfg.d_model ** -0.5)
        },
        "layers": sublayers,
        "final_norm": _norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = {
            "w": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           scale=cfg.d_model ** -0.5)
        }
    return spec


def _map_specs(tree, fn):
    if isinstance(tree, ParamSpec):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: _map_specs(v, fn) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_map_specs(v, fn) for v in tree]
    raise TypeError(type(tree))


def iter_specs(tree, prefix=""):
    if isinstance(tree, ParamSpec):
        yield prefix, tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from iter_specs(v, f"{prefix}/{k}" if prefix else k)
    elif isinstance(tree, list):
        for i, v in enumerate(tree):
            yield from iter_specs(v, f"{prefix}/{i}" if prefix else str(i))
    else:
        raise TypeError(type(tree))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count; ``active_only`` counts top-k+shared experts
    instead of all routed experts (for MODEL_FLOPS = 6*N_active*D)."""
    total = 0
    for name, ps in iter_specs(model_spec(cfg)):
        n = ps.size
        if active_only and "/moe/w" in name and cfg.moe:
            n = n * (cfg.moe.top_k / cfg.moe.num_experts)
        total += int(n)
    return total
