"""Mamba-2 SSD (state-space duality) block — chunked train/prefill scan and
O(1)-state recurrent decode.  [arXiv:2405.21060]

The chunked algorithm (``ssd_chunked``) splits the sequence into chunks of
length Q: within a chunk the dual "attention-like" quadratic form is used
(MXU-friendly), across chunks a linear recurrence carries the (H, P, N)
state.  This pure-jnp implementation is the oracle for the Pallas kernel in
``repro.kernels.ssd_scan``; ``use_pallas`` switches the hot loop.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.actshard import constrain


def _rmsnorm_gated(y, z, scale, eps):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(z.dtype)


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  u: (B,S,C), w: (W,C), b: (C,).

    Returns (y (B,S,C), new_state (B,W-1,C)) — state = last W-1 inputs.
    """
    W = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    else:
        pad = init_state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)           # (B, S+W-1, C)
    y = jnp.zeros_like(u)
    for i in range(W):
        y = y + up[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
    y = y + b.astype(u.dtype)
    new_state = up[:, up.shape[1] - (W - 1):]
    return y, new_state


def _segsum_exp(dA_cs):
    """L[..., q, k] = exp(dA_cs[..., q] - dA_cs[..., k]) for q >= k else 0.

    dA_cs: (B, nc, Q, H) -> (B, nc, Q, Q, H)
    """
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]
    Q = dA_cs.shape[2]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    return L


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    x: (B,S,H,P) head inputs; dt: (B,S,H) post-softplus steps; A: (H,) < 0;
    Bm/Cm: (B,S,N) input/output projections (shared across heads, 1 group).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    S_orig = S
    if S % Q:
        # pad with dt=0 tokens: decay exp(0)=1, zero input — identity for the
        # recurrence, so the final state is exact; padded outputs are sliced.
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    f32 = jnp.float32

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A.astype(f32)                          # (B,nc,Q,H)
    dA_cs = jnp.cumsum(dA, axis=2)                    # (B,nc,Q,H)
    xdt = (xc.astype(f32) * dtc[..., None]).astype(x.dtype)

    # ---- intra-chunk (quadratic, MXU-shaped) ----
    L = _segsum_exp(dA_cs)                            # (B,nc,Q,Q,H) fp32
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(f32), Bc.astype(f32))
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp",
                        scores, L, xdt.astype(f32))

    # ---- chunk states ----
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)   # (B,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn",
                        Bc.astype(f32), decay_states, xdt.astype(f32))

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])              # (B,nc,H)
    s0 = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def body(s, inp):
        st, dec = inp
        return s * dec[:, :, None, None] + st, s           # emit prev state

    (s_final, prev_states) = jax.lax.scan(
        body, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,N)

    # ---- off-diagonal (state) contribution ----
    state_decay = jnp.exp(dA_cs)                            # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       Cc.astype(f32), prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, S, H, P).astype(x.dtype)
    return y[:, :S_orig], s_final.astype(f32)


def _proj_split(p: dict, x: jax.Array, cfg: ModelConfig):
    """Project residual stream to (z, conv-input u=[xin,B,C], dt_raw)."""
    dtype = x.dtype
    z = x @ p["wz"].astype(dtype)
    xin = constrain(x @ p["wx"].astype(dtype), "ssm_inner")
    Bm = x @ p["wB"].astype(dtype)
    Cm = x @ p["wC"].astype(dtype)
    dt_raw = x @ p["wdt"].astype(dtype)
    u = jnp.concatenate([xin, Bm, Cm], axis=-1)
    return z, u, dt_raw


def _post_conv_split(u, cfg: ModelConfig):
    di = cfg.ssm.d_inner(cfg.d_model)
    N = cfg.ssm.state
    xin, Bm, Cm = u[..., :di], u[..., di:di + N], u[..., di + N:]
    return xin, Bm, Cm


def ssm_train(p: dict, x: jax.Array, cfg: ModelConfig,
              use_pallas: bool = False) -> jax.Array:
    """(B,S,d) -> (B,S,d), full-sequence (training / prefill core)."""
    B, S, _ = x.shape
    ssm = cfg.ssm
    H = ssm.num_heads(cfg.d_model)
    P = ssm.head_dim
    z, u, dt_raw = _proj_split(p, x, cfg)
    u, _ = _causal_conv(u, p["conv_w"], p["conv_b"])
    u = jax.nn.silu(u)
    xin, Bm, Cm = _post_conv_split(u, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = constrain(xin.reshape(B, S, H, P), "ssm_heads")
    if use_pallas:
        from repro.kernels import ops as kops
        y = kops.ssd_scan(xh, dt, A, Bm, Cm, chunk=ssm.chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk)
    y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
    y = constrain(y, "ssm_heads").reshape(B, S, H * P)
    y = _rmsnorm_gated(y, z, p["norm_scale"], cfg.norm_eps)
    return y @ p["wo"].astype(y.dtype)


def init_ssm_cache(cfg: ModelConfig, batch: int, n_groups: int,
                   dtype=jnp.float32, abstract: bool = False):
    """Stacked SSD decode state for one ssm sublayer slot."""
    ssm = cfg.ssm
    H = ssm.num_heads(cfg.d_model)
    P = ssm.head_dim
    N = ssm.state
    conv_dtype = jnp.dtype(cfg.dtype)
    conv_ch = ssm.d_inner(cfg.d_model) + 2 * N
    st_shape = (n_groups, batch, H, P, N)
    cv_shape = (n_groups, batch, ssm.conv_width - 1, conv_ch)
    if abstract:
        return {"state": jax.ShapeDtypeStruct(st_shape, dtype),
                "conv": jax.ShapeDtypeStruct(cv_shape, conv_dtype)}
    return {"state": jnp.zeros(st_shape, dtype),
            "conv": jnp.zeros(cv_shape, conv_dtype)}


def ssm_prefill(p: dict, x: jax.Array, cfg: ModelConfig,
                use_pallas: bool = False, valid=None, conv_end=None):
    """Full-sequence forward that also returns the decode cache.

    ``valid`` ((S,) bool, optional) marks pad positions in a two-sided
    padded prompt (front-padded bucketed prefill).  Pads cannot ride the
    tail-pad identity alone: ``dt = softplus(dt_raw + dt_bias)`` is
    nonzero even for zero input, so pad positions are explicitly masked
    at the two recurrence inputs — the conv input ``u`` (pads contribute
    exactly the zeros the unpadded run's conv init-state provides) and
    ``dt`` (``dt=0`` makes a pad an identity step for the SSD scan, the
    same trick :func:`ssd_chunked` uses for its internal tail pad).  The
    caller aligns the front pad to a chunk boundary so the real tokens'
    chunk offsets — and therefore the f32 scan math — match the unpadded
    run bit for bit.

    ``conv_end`` (traced int32, optional: ``front_pad + num_real``) ends
    the conv-state window at the last REAL token instead of the padded
    tail, so decode resumes from the exact state the unpadded prefill
    would have left.
    """
    B, S, _ = x.shape
    ssm = cfg.ssm
    H, P = ssm.num_heads(cfg.d_model), ssm.head_dim
    z, u, dt_raw = _proj_split(p, x, cfg)
    if valid is not None:
        u = u * valid[None, :, None].astype(u.dtype)
    u_conv, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"])
    u_conv = jax.nn.silu(u_conv)
    xin, Bm, Cm = _post_conv_split(u_conv, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    if valid is not None:
        dt = dt * valid[None, :, None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, S, H, P)
    y, state = ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk)
    y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, H * P)
    y = _rmsnorm_gated(y, z, p["norm_scale"], cfg.norm_eps)
    out = y @ p["wo"].astype(y.dtype)
    if conv_end is not None:
        # window of W-1 inputs ending at the last real token; when the
        # prompt is shorter than the window this slides into the masked
        # front pad, whose zeros match the unpadded run's zero init-state
        W = p["conv_w"].shape[0]
        up = jnp.concatenate(
            [jnp.zeros((B, W - 1, u.shape[-1]), u.dtype), u], axis=1)
        conv_state = jax.lax.dynamic_slice_in_dim(up, conv_end, W - 1,
                                                  axis=1)
    # conv state: last (W-1) *pre-activation* conv inputs
    return out, {"state": state, "conv": conv_state.astype(jnp.dtype(cfg.dtype))}


def ssm_decode(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    """One-token recurrent decode.  x: (B,1,d).  Returns (out, cache)."""
    B = x.shape[0]
    ssm = cfg.ssm
    H, P, N = ssm.num_heads(cfg.d_model), ssm.head_dim, ssm.state
    z, u, dt_raw = _proj_split(p, x, cfg)                 # (B,1,*)
    # conv: window = [conv_state, u_t]
    win = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
    y_conv = jnp.einsum("bwc,wc->bc", win, p["conv_w"].astype(u.dtype))
    y_conv = jax.nn.silu(y_conv + p["conv_b"].astype(u.dtype))  # (B,C)
    new_conv = win[:, 1:]
    xin, Bm, Cm = _post_conv_split(y_conv, cfg)           # (B,*)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))      # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                   # (B,H)
    xh = xin.reshape(B, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm.astype(jnp.float32))
    state = cache["state"] * a[:, :, None, None] + dBx    # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, H * P).astype(x.dtype)
    y = _rmsnorm_gated(y, z, p["norm_scale"], cfg.norm_eps)
    out = y @ p["wo"].astype(y.dtype)
    return out, {"state": state, "conv": new_conv.astype(jnp.dtype(cfg.dtype))}
