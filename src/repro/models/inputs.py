"""Input construction: abstract specs (dry-run) and concrete synthetic
batches (smoke tests / examples) for every (arch x input-shape) pair.

``input_specs`` returns ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, no device allocation — one entry per model input.  The modality
frontends are stubs per the assignment: VLM batches carry precomputed patch
embeddings, audio batches carry precomputed frame embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig

VLM_NUM_PATCHES = 256


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: InputShape,
                      batch_override: int | None = None) -> dict:
    B = batch_override or shape.global_batch
    S = shape.seq_len
    specs = {}
    if cfg.frontend == "vision":
        P = min(VLM_NUM_PATCHES, S // 2)
        specs["prefix_embeddings"] = _sds((B, P, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = _sds((B, S - P), jnp.int32)
    elif cfg.frontend == "audio":
        specs["frame_embeddings"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = _sds((B, S), jnp.int32)
    specs["labels"] = _sds((B, S), jnp.int32)
    specs["loss_mask"] = _sds((B, S), jnp.float32)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: InputShape,
                       batch_override: int | None = None) -> dict:
    B = batch_override or shape.global_batch
    return {
        "token": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: InputShape,
                batch_override: int | None = None) -> dict:
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape, batch_override)
    return train_input_specs(cfg, shape, batch_override)


# ------------------------------------------------------ concrete batches ----

def make_batch(cfg: ModelConfig, shape: InputShape, seed: int = 0,
               batch_override: int | None = None) -> dict:
    """Synthetic batch with the exact structure of ``input_specs``."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in train_input_specs(cfg, shape, batch_override).items():
        if k == "loss_mask":
            m = np.ones(s.shape, np.float32)
            if cfg.frontend == "vision":
                P = min(VLM_NUM_PATCHES, shape.seq_len // 2)
                m[:, :P] = 0.0          # no loss on image prefix
            out[k] = jnp.asarray(m)
        elif s.dtype == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, s.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(
                rng.standard_normal(s.shape, np.float32) * 0.02, s.dtype)
    return out
