"""AdamW from scratch (no optax) with global-norm clipping and
warmup+cosine schedule.  Optimizer state dtype is configurable so very
large models (jamba-398b) can run bf16 m/v under the single-pod HBM budget
(see DESIGN.md napkin math); ZeRO placement comes from core.sharding.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def lr_schedule(step, opt: OptimizerConfig):
    step = step.astype(jnp.float32)
    warm = opt.peak_lr * step / max(opt.warmup_steps, 1)
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(opt.decay_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = opt.min_lr_ratio + (1 - opt.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < opt.warmup_steps, warm, opt.peak_lr * cos)


def init_opt_state(params, opt: OptimizerConfig):
    dt = jnp.dtype(opt.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params, opt: OptimizerConfig):
    dt = jnp.dtype(opt.state_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree.map(sds, abstract_params),
        "v": jax.tree.map(sds, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, state, opt: OptimizerConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(step, opt)
    dt = jnp.dtype(opt.state_dtype)
    b1c = 1 - opt.b1 ** step.astype(jnp.float32)
    b2c = 1 - opt.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * opt.b1 + g * (1 - opt.b1)
        v32 = v.astype(jnp.float32) * opt.b2 + jnp.square(g) * (1 - opt.b2)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + opt.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
