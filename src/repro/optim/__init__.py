from repro.optim.adamw import (
    OptimizerConfig,
    abstract_opt_state,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_schedule,
)

__all__ = ["OptimizerConfig", "abstract_opt_state", "adamw_update",
           "global_norm", "init_opt_state", "lr_schedule"]
