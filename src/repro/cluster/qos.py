"""Compatibility shim — QOS tiers moved to :mod:`repro.policy.qos`.

QOS policy (priority boosts, GrpTRES caps, preemption rules) applies to
serving slots as much as to batch jobs, so it lives in the engine-agnostic
``repro.policy`` package now.  This module keeps the historical import
path working::

    from repro.cluster.qos import QOS, default_qos_table   # still fine
    from repro.policy import QOS, default_qos_table        # preferred
"""
from repro.policy.qos import (
    PREEMPT_CANCEL, PREEMPT_REQUEUE, QOS, add_tres, default_qos_table,
    format_tres, job_tres, tres_within,
)

__all__ = [
    "PREEMPT_CANCEL", "PREEMPT_REQUEUE", "QOS", "add_tres",
    "default_qos_table", "format_tres", "job_tres", "tres_within",
]
