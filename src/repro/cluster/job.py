"""Jobs: resource requests, lifecycle state machine, dependencies, arrays.

Mirrors the paper's §5.2 submission model: every ``#SBATCH`` option in the
guide's example script has a field here (job-name, partition, nodes, gres,
cpus-per-task, mem, time), plus dependencies (``-d afterok:<id>``) and job
arrays (``-a``).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Optional


class JobState(enum.Enum):
    PENDING = "PD"
    RUNNING = "R"
    COMPLETED = "CD"
    FAILED = "F"
    CANCELLED = "CA"
    TIMEOUT = "TO"
    PREEMPTED = "PR"         # transient: evicted, about to requeue

    @property
    def finished(self) -> bool:
        return self in (JobState.COMPLETED, JobState.FAILED,
                        JobState.CANCELLED, JobState.TIMEOUT)

    @property
    def ok(self) -> bool:
        return self == JobState.COMPLETED


#: Job.kind for serving-replica placeholder jobs: the autoscaler submits
#: one per decode-engine replica (scavenger QOS) so the replica's nodes
#: are owned, billed, and preemptable like any other job.
JOB_KIND_SERVE_REPLICA = "serve_replica"


class DependencyKind(enum.Enum):
    AFTER = "after"          # dep started (or finished)
    AFTEROK = "afterok"      # dep completed successfully
    AFTERNOTOK = "afternotok"
    AFTERANY = "afterany"    # dep finished in any state


@dataclass(frozen=True)
class Dependency:
    kind: DependencyKind
    job_id: int

    @classmethod
    def parse(cls, text: str) -> list["Dependency"]:
        """Parse SLURM syntax ``afterok:12:13,afterany:14``."""
        deps = []
        for clause in text.split(","):
            kind, *ids = clause.split(":")
            for jid in ids:
                deps.append(cls(DependencyKind(kind), int(jid)))
        return deps


@dataclass(frozen=True)
class ResourceRequest:
    """What one job asks for (per the guide's sbatch options)."""
    nodes: int = 1
    gres_per_node: dict = field(default_factory=dict)   # {"tpu": 4}
    cpus_per_node: int = 1
    mem_mb_per_node: int = 1024
    time_limit_s: int = 3600
    contiguous: bool = True     # TPU: allocation must tile a mesh rectangle

    def __post_init__(self):
        assert self.nodes >= 1 and self.cpus_per_node >= 1
        assert self.time_limit_s > 0


@dataclass
class Job:
    job_id: int
    name: str
    user: str
    partition: str
    req: ResourceRequest
    priority: int = 0
    submit_time: float = 0.0
    # what the job "runs": either a simulated duration, or a real callable
    # (the Mesh bridge launches JAX work through this).
    run_time_s: float = 60.0
    script: Optional[Callable] = None     # called at start in real mode
    dependencies: tuple[Dependency, ...] = ()
    array_index: Optional[int] = None     # set for array members
    comment: str = ""

    # multi-tenancy (sacctmgr association + QOS)
    account: str = "root"
    qos: str = "normal"

    # workload class: plain batch work, or a serving-replica placeholder
    # the autoscaler manages (its "script" is a decode engine outside the
    # simulation; the job holds the nodes and rides QOS preemption)
    kind: str = "batch"

    # preemption / requeue
    requeue_count: int = 0                # times evicted back to PENDING
    progress_s: float = 0.0               # checkpointed work retained
    ckpt_interval_s: Optional[float] = None   # sim: progress granularity
    checkpoint_dir: Optional[str] = None  # real mode: repro.checkpoint.store

    # lifecycle
    state: JobState = JobState.PENDING
    reason: str = "Priority"
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    nodes_alloc: tuple[str, ...] = ()
    exit_code: Optional[int] = None
    result: object = None                 # script return value (real mode)

    @property
    def time_limit_s(self) -> int:
        return self.req.time_limit_s

    def remaining_s(self) -> float:
        """Work left after checkpointed progress (full run if never saved)."""
        return max(self.run_time_s - self.progress_s, 0.0)

    def runtime(self) -> float:
        """Actual runtime of the *current segment* (capped by the limit —
        TIMEOUT if it would exceed; the limit resets per requeue segment,
        matching SLURM's requeue semantics)."""
        return min(self.remaining_s(), self.req.time_limit_s)

    def will_timeout(self) -> bool:
        return self.remaining_s() > self.req.time_limit_s

    def record_preemption(self, elapsed_s: float):
        """Evicted after ``elapsed_s`` of this segment: keep checkpointed
        progress (last full ``ckpt_interval_s`` multiple; none → restart)."""
        if self.ckpt_interval_s:
            kept = (elapsed_s // self.ckpt_interval_s) * self.ckpt_interval_s
            self.progress_s += kept
        self.requeue_count += 1

    def sort_key(self) -> tuple:
        """Queue order: higher priority first, then FIFO by submit time."""
        return (-self.priority, self.submit_time, self.job_id)

    def real_failed(self) -> bool:
        """Real-mode script raised at start (exit code already recorded)."""
        return self.exit_code == 1 and self.script is not None
