"""Software-defined SLURM/DeepOps cluster: inventory, scheduler, job
lifecycle, SLURM command surface, provisioning + validation, Mesh bridge,
and the multi-tenant policy layer (accounts, fair-share, QOS, preemption)."""
from repro.cluster.cluster import AccountingRecord, Cluster
from repro.cluster.fairshare import (
    Account, FairShareTree, MultifactorPriority, PriorityWeights,
)
from repro.cluster.job import (
    Dependency, DependencyKind, JOB_KIND_SERVE_REPLICA, Job, JobState,
    ResourceRequest,
)
from repro.cluster.node import Node, NodeState, Partition
from repro.cluster.provision import (
    ClusterSpec, HostSpec, PartitionSpec, provision, tpu_pod_spec, validate,
)
from repro.cluster.qos import QOS, default_qos_table
from repro.cluster import commands

__all__ = [
    "Account", "AccountingRecord", "Cluster", "Dependency", "DependencyKind",
    "FairShareTree", "JOB_KIND_SERVE_REPLICA", "Job", "JobState",
    "MultifactorPriority",
    "PriorityWeights", "QOS", "ResourceRequest", "Node", "NodeState",
    "Partition", "ClusterSpec", "HostSpec", "PartitionSpec",
    "default_qos_table", "provision", "tpu_pod_spec", "validate", "commands",
]
