"""Software-defined SLURM/DeepOps cluster: inventory, scheduler, job
lifecycle, SLURM command surface, provisioning + validation, Mesh bridge."""
from repro.cluster.cluster import AccountingRecord, Cluster
from repro.cluster.job import (
    Dependency, DependencyKind, Job, JobState, ResourceRequest,
)
from repro.cluster.node import Node, NodeState, Partition
from repro.cluster.provision import (
    ClusterSpec, HostSpec, PartitionSpec, provision, tpu_pod_spec, validate,
)
from repro.cluster import commands

__all__ = [
    "AccountingRecord", "Cluster", "Dependency", "DependencyKind", "Job",
    "JobState", "ResourceRequest", "Node", "NodeState", "Partition",
    "ClusterSpec", "HostSpec", "PartitionSpec", "provision", "tpu_pod_spec",
    "validate", "commands",
]
