"""The SLURM user-command surface from paper §5.2.1: sinfo, squeue, sbatch,
srun, salloc, scancel, scontrol, sacct — plus the multi-tenant accounting
surface (sacctmgr, sshare, sprio) — each returns the formatted text a user
would see, against a :class:`Cluster`.
"""
from __future__ import annotations

from typing import Optional

from repro.cluster.cluster import Cluster
from repro.cluster.job import Job, JobState, ResourceRequest
from repro.cluster.node import NodeState
from repro.policy import format_tres


def _fmt_time(seconds: Optional[float]) -> str:
    if seconds is None:
        return "N/A"
    s = int(seconds)
    d, s = divmod(s, 86_400)
    h, s = divmod(s, 3_600)
    m, s = divmod(s, 60)
    if d:
        return f"{d}-{h:02d}:{m:02d}:{s:02d}"
    return f"{h:02d}:{m:02d}:{s:02d}"


def _compress(names) -> str:
    return ",".join(names) if names else ""


def sinfo(cluster: Cluster, partition: Optional[str] = None,
          node_oriented: bool = False, summarize: bool = False) -> str:
    """`sinfo` / `sinfo -N` / `sinfo -s`."""
    rows = []
    parts = ([cluster.partitions[partition]] if partition
             else list(cluster.partitions.values()))
    if node_oriented:
        rows.append(f"{'NODELIST':<14}{'PARTITION':<12}{'STATE':<8}"
                    f"{'CPUS':<6}{'GRES':<12}{'FREE_GRES':<10}")
        for p in parts:
            for nm in p.nodes:
                n = cluster.nodes[nm]
                gres = ",".join(f"{g}:{c}" for g, c in n.gres.items())
                free = ",".join(f"{g}:{n.free_gres(g)}" for g in n.gres)
                rows.append(f"{n.name:<14}{p.name:<12}{n.state.value:<8}"
                            f"{n.cpus:<6}{gres:<12}{free:<10}")
        return "\n".join(rows)
    rows.append(f"{'PARTITION':<12}{'AVAIL':<7}{'TIMELIMIT':<12}"
                f"{'NODES':<7}{'STATE':<8}NODELIST")
    for p in parts:
        by_state: dict[NodeState, list[str]] = {}
        for nm in p.nodes:
            by_state.setdefault(cluster.nodes[nm].state, []).append(nm)
        if summarize:
            alive = sum(len(v) for s, v in by_state.items()
                        if s != NodeState.DOWN)
            rows.append(f"{p.name + ('*' if p.default else ''):<12}"
                        f"{'up':<7}{_fmt_time(p.max_time_s):<12}"
                        f"{alive}/{len(p.nodes):<6}{'mixed':<8}")
            continue
        for state, names in sorted(by_state.items(), key=lambda kv: kv[0].value):
            rows.append(f"{p.name + ('*' if p.default else ''):<12}"
                        f"{'up':<7}{_fmt_time(p.max_time_s):<12}"
                        f"{len(names):<7}{state.value:<8}{_compress(names)}")
    return "\n".join(rows)


def squeue(cluster: Cluster, user: Optional[str] = None,
           states: Optional[list[str]] = None,
           partition: Optional[str] = None) -> str:
    """`squeue [-u user] [-t states] [-p partition]`."""
    rows = [f"{'JOBID':<8}{'PARTITION':<12}{'NAME':<20}{'USER':<10}"
            f"{'ACCOUNT':<10}{'QOS':<11}"
            f"{'ST':<4}{'TIME':<12}{'NODES':<7}NODELIST(REASON)"]
    for job in sorted(cluster.jobs.values(), key=Job.sort_key):
        if job.state.finished:
            continue
        if user and job.user != user:
            continue
        if partition and job.partition != partition:
            continue
        if states and job.state.value not in states:
            continue
        elapsed = (cluster.clock - job.start_time
                   if job.start_time is not None else 0)
        where = (_compress(job.nodes_alloc) if job.nodes_alloc
                 else f"({job.reason})")
        nm = job.name if job.array_index is None else \
            f"{job.name}[{job.array_index}]"
        rows.append(f"{job.job_id:<8}{job.partition:<12}{nm[:19]:<20}"
                    f"{job.user:<10}{job.account[:9]:<10}{job.qos[:10]:<11}"
                    f"{job.state.value:<4}"
                    f"{_fmt_time(elapsed):<12}{job.req.nodes:<7}{where}")
    return "\n".join(rows)


def sbatch(cluster: Cluster, name: str = "job", nodes: int = 1,
           gres: str = "", cpus_per_task: int = 1, mem: str = "1G",
           time: str = "01:00:00", partition: Optional[str] = None,
           dependency: str = "", array: int = 0, priority: int = 0,
           run_time_s: float = 60.0, script=None, user: str = "ubuntu",
           account: Optional[str] = None, qos: str = "normal",
           ckpt_interval_s: Optional[float] = None,
           checkpoint_dir: Optional[str] = None) -> str:
    """`sbatch` with the guide's §5.2.4 options (plus ``--account``/``--qos``).
    Returns the SLURM message."""
    req = ResourceRequest(
        nodes=nodes,
        gres_per_node=_parse_gres(gres),
        cpus_per_node=cpus_per_task,
        mem_mb_per_node=_parse_mem(mem),
        time_limit_s=_parse_time(time),
    )
    ids = cluster.submit(name, req, user=user, partition=partition,
                         priority=priority, run_time_s=run_time_s,
                         script=script, dependency=dependency, array=array,
                         account=account, qos=qos,
                         ckpt_interval_s=ckpt_interval_s,
                         checkpoint_dir=checkpoint_dir)
    if array:
        return f"Submitted batch job {ids[0]} (array {len(ids)} tasks)"
    return f"Submitted batch job {ids[0]}"


def srun(cluster: Cluster, script, name: str = "interactive", nodes: int = 1,
         gres: str = "tpu:4", time: str = "01:00:00",
         partition: Optional[str] = None, user: str = "ubuntu"):
    """`srun` — submit, run the queue until this job finishes, return its
    result (the interactive analogue of §5.2.2)."""
    req = ResourceRequest(nodes=nodes, gres_per_node=_parse_gres(gres),
                          time_limit_s=_parse_time(time))
    jid = cluster.submit(name, req, user=user, partition=partition,
                         script=script, run_time_s=1.0)[0]
    while not cluster.jobs[jid].state.finished:
        if not cluster.tick():
            break
    job = cluster.jobs[jid]
    if job.state != JobState.COMPLETED:
        raise RuntimeError(
            f"srun job {jid} {job.state.name}: {job.comment}")
    return job.result


salloc = srun     # salloc differs only in shell semantics; same allocation path


def scancel(cluster: Cluster, job_id: int) -> str:
    cluster.cancel(job_id)
    return f"scancel: job {job_id}"


def scontrol_show_job(cluster: Cluster, job_id: int) -> str:
    j = cluster.jobs[job_id]
    return (f"JobId={j.job_id} JobName={j.name} UserId={j.user} "
            f"Account={j.account} QOS={j.qos} Restarts={j.requeue_count} "
            f"Priority={j.priority} Partition={j.partition} "
            f"JobState={j.state.name} Reason={j.reason or 'None'} "
            f"NumNodes={j.req.nodes} "
            f"TRES=cpu={j.req.cpus_per_node},mem={j.req.mem_mb_per_node}M,"
            + ",".join(f"gres/{g}={n}" for g, n in
                       j.req.gres_per_node.items())
            + f" TimeLimit={_fmt_time(j.req.time_limit_s)} "
            f"NodeList={_compress(j.nodes_alloc) or '(null)'} "
            f"SubmitTime={j.submit_time:.0f} "
            f"StartTime={j.start_time if j.start_time is not None else 'N/A'} "
            f"EndTime={j.end_time if j.end_time is not None else 'N/A'}")


def scontrol_show_nodes(cluster: Cluster) -> str:
    rows = []
    for n in cluster.nodes.values():
        gres = ",".join(f"{g}:{c}" for g, c in n.gres.items())
        rows.append(
            f"NodeName={n.name} State={n.state.name} CPUTot={n.cpus} "
            f"CPUAlloc={n.alloc_cpus} RealMemory={n.mem_mb} "
            f"AllocMem={n.alloc_mem_mb} Gres={gres} "
            f"Coord={n.coord} Reason={n.reason or 'None'}")
    return "\n".join(rows)


def scontrol_update_node(cluster: Cluster, nodename: str, state: str,
                         reason: str = "") -> str:
    cluster.set_node_state(nodename, NodeState[state.upper()], reason)
    return f"scontrol: node {nodename} -> {state}"


def sacct(cluster: Cluster, user: Optional[str] = None,
          account: Optional[str] = None) -> str:
    """``sacct [-u user] [-A account]`` — one row per job *segment* (a
    preempted-then-requeued job shows a PREEMPTED row and a final row)."""
    rows = [f"{'JobID':<8}{'JobName':<20}{'Partition':<12}{'Account':<10}"
            f"{'QOS':<11}{'State':<12}"
            f"{'Elapsed':<12}{'NNodes':<8}{'ExitCode':<8}"]
    for r in cluster.accounting:
        if user and r.user != user:
            continue
        if account and r.account != account:
            continue
        rows.append(f"{r.job_id:<8}{r.name[:19]:<20}{r.partition:<12}"
                    f"{r.account[:9]:<10}{r.qos[:10]:<11}"
                    f"{r.state:<12}{_fmt_time(r.elapsed):<12}"
                    f"{len(r.nodes):<8}{r.exit_code or 0}:0")
    return "\n".join(rows)


# ----------------------------------------------- multi-tenant accounting ----

def sacctmgr_add_account(cluster: Cluster, name: str, parent: str = "root",
                         fairshare: int = 1, description: str = "") -> str:
    """``sacctmgr add account <name> parent=<p> fairshare=<n>``."""
    cluster.fairshare.add_account(name, parent=parent, shares=fairshare,
                                  description=description)
    return f" Adding Account(s)\n  {name}\n Settings\n  Fairshare={fairshare}"


def sacctmgr_add_user(cluster: Cluster, user: str, account: str) -> str:
    """``sacctmgr add user <u> account=<a>``."""
    cluster.fairshare.add_user(user, account)
    return f" Adding User(s)\n  {user}\n Settings\n  Account={account}"


def sacctmgr_modify_account(cluster: Cluster, name: str,
                            fairshare: Optional[int] = None,
                            parent: Optional[str] = None,
                            description: Optional[str] = None) -> str:
    """``sacctmgr modify account <name> set fairshare=<n> [parent=<p>]`` —
    live shares edit; the very next scheduling/sshare pass computes
    priorities from the new values (NormShares is derived on read)."""
    cluster.fairshare.modify_account(name, shares=fairshare, parent=parent,
                                     description=description)
    settings = []
    if fairshare is not None:
        settings.append(f"Fairshare={fairshare}")
    if parent is not None:
        settings.append(f"Parent={parent}")
    if description is not None:
        settings.append(f"Description={description}")
    return (" Modified account...\n  " + name + "\n Settings\n  "
            + "\n  ".join(settings or ["(no change)"]))


def sacctmgr_modify_qos(cluster: Cluster, name: str,
                        priority: Optional[int] = None,
                        preempt: Optional[tuple] = None,
                        grp_tres: Optional[dict] = None,
                        usage_factor: Optional[float] = None) -> str:
    """``sacctmgr modify qos <name> set priority=<n> grptres=... `` — live
    QOS edit.  QOS objects are frozen, so the catalogue entry is replaced
    wholesale; everything that consults ``cluster.qos_table`` (priority
    engine, preemption, GrpTRES holds) sees the new tier on its next
    pass."""
    import dataclasses as _dc

    assert name in cluster.qos_table, f"unknown QOS {name!r}"
    changes = {}
    if priority is not None:
        changes["priority"] = priority
    if preempt is not None:
        changes["preempt"] = tuple(preempt)
    if grp_tres is not None:
        changes["grp_tres"] = dict(grp_tres)
    if usage_factor is not None:
        changes["usage_factor"] = usage_factor
    cluster.qos_table[name] = _dc.replace(cluster.qos_table[name], **changes)
    settings = [f"{k}={v}" for k, v in changes.items()] or ["(no change)"]
    return (" Modified qos...\n  " + name + "\n Settings\n  "
            + "\n  ".join(settings))


def sacctmgr_show_assoc(cluster: Cluster) -> str:
    """``sacctmgr show assoc format=Account,ParentName,User,Fairshare``."""
    t = cluster.fairshare
    rows = [f"{'Account':<12}{'Par Name':<12}{'User':<10}{'Share':>6}"]
    for name in sorted(t.accounts):
        a = t.accounts[name]
        rows.append(f"{a.name:<12}{a.parent or '':<12}{'':<10}"
                    f"{a.shares:>6}")
        for u in sorted(u for u, acct in t.user_account.items()
                        if acct == name):
            rows.append(f"{a.name:<12}{'':<12}{u:<10}{1:>6}")
    return "\n".join(rows)


def sacctmgr_show_qos(cluster: Cluster) -> str:
    """``sacctmgr show qos format=Name,Priority,Preempt,PreemptMode,GrpTRES``."""
    rows = [f"{'Name':<12}{'Priority':>9} {'Preempt':<18}{'PreemptMode':<13}"
            "GrpTRES"]
    for name in sorted(cluster.qos_table):
        q = cluster.qos_table[name]
        rows.append(f"{q.name:<12}{q.priority:>9} "
                    f"{','.join(q.preempt) or '':<18}"
                    f"{q.preempt_mode:<13}{format_tres(q.grp_tres)}")
    return "\n".join(rows)


def sshare(cluster: Cluster, tres: bool = False) -> str:
    """``sshare -l``: the fair-share tree with live usage and factors.

    ``tres=True`` appends a TRESUsage column with the decayed raw
    per-resource consumption (``sshare -l -o ...,TRESRunMins``-style) —
    for a paged serving tenant, ``gres/kv_page`` there is its true HBM
    residency (page-steps held), not a whole-slot approximation."""
    t = cluster.fairshare
    t.decay_to(cluster.clock)
    header = (f"{'Account':<14}{'RawShares':>10}{'NormShares':>11}"
              f"{'RawUsage':>12}{'NormUsage':>10}{'FairShare':>10}")
    rows = [header + ("  TRESUsage" if tres else "")]

    def walk(name: str, depth: int):
        a = t.accounts[name]
        label = (" " * depth) + a.name
        row = (f"{label:<14}{a.shares:>10}{t.norm_shares(name):>11.4f}"
               f"{t.usage.get(name, 0.0):>12.0f}"
               f"{t.norm_usage(name):>10.4f}"
               f"{t.fair_share_factor(name):>10.4f}")
        if tres:
            usage = {k: round(v) for k, v in
                     t.tres_usage_of(name).items() if v >= 0.5}
            row += "  " + (format_tres(usage) if usage else "(none)")
        rows.append(row)
        for child in sorted(t.children(name), key=lambda c: c.name):
            walk(child.name, depth + 1)

    walk("root", 0)
    return "\n".join(rows)


def sdiag(cluster: Optional[Cluster] = None, tracer=None,
          admission=None, engine=None, router=None,
          autoscaler=None) -> str:
    """``sdiag``-style diagnostics: scheduler cycle statistics (from the
    cluster controller), admission-controller cycle statistics (from the
    serving layer), per-tenant serving SLO percentiles (from the
    tracer's derived histograms), serve-step utilization (from a
    budgeted DecodeEngine's per-iteration counters), speculative
    decoding acceptance (from a speculating engine), and tensor
    parallelism (from a mesh-attached engine — shard layout, per-device
    KV-pool occupancy, cross-shard reductions per token).  Any subset
    of sources may be given; sections for absent sources are simply
    omitted.  With the elastic tier, ``router`` adds routing decisions
    plus per-replica load/radix occupancy, and ``autoscaler`` adds
    capacity-probe and scale-up/drain counts."""
    sections = []
    if cluster is not None:
        st = cluster.sched_stats
        mean = st["total_us"] / st["passes"] if st["passes"] else 0.0
        sections.append("\n".join([
            "Main schedule statistics (microseconds):",
            f"\tTotal cycles:     {st['passes']}",
            f"\tLast cycle:       {st['last_us']:.0f}",
            f"\tMean cycle:       {mean:.0f}",
            f"\tMax cycle:        {st['max_us']:.0f}",
            f"\tJobs started:     {st['starts']}",
            f"\tJobs pending:     {len(cluster._pending())}",
            f"\tJobs running:     {len(cluster._running())}",
            f"\tPreemptions:      {cluster.preemptions_total}",
        ]))
    if admission is not None:
        st = admission.stats
        sections.append("\n".join([
            "Admission controller statistics:",
            f"\tCycles:           {st['cycles']}",
            f"\tPicks:            {st['picks']}",
            f"\tPreemptive picks: {st['preempt_picks']}",
            f"\tRequeues:         {st['requeues']}",
            f"\tQueued now:       {admission.pending()}",
        ]))
    if engine is not None and getattr(engine, "max_batch_tokens",
                                      None) is not None:
        st = engine.serve_stats
        it, T = st["iterations"], engine.max_batch_tokens
        spent = st["decode_tokens"] + st["prefill_tokens"]
        cap = it * T
        fill = spent / cap if cap else 0.0
        d_pct = st["decode_tokens"] / spent if spent else 0.0
        p_pct = st["prefill_tokens"] / spent if spent else 0.0
        sections.append("\n".join([
            "Serve-step utilization (token budget):",
            f"\tIterations:       {it}",
            f"\tToken budget:     {T}/step",
            f"\tBudget fill:      {spent}/{cap} ({fill:.0%})",
            f"\tDecode tokens:    {st['decode_tokens']} ({d_pct:.0%})",
            f"\tPrefill tokens:   {st['prefill_tokens']} ({p_pct:.0%}, "
            f"{st['prefill_chunks']} chunks)",
        ]))
    if engine is not None and getattr(engine, "speculate", 0):
        st = engine.spec_stats
        rate = st["accepted"] / st["proposed"] if st["proposed"] else 0.0
        run_len = st["emitted"] / st["rounds"] if st["rounds"] else 0.0
        by = ", ".join(f"{k}: {v}"
                       for k, v in sorted(st["proposed_by"].items()))
        sections.append("\n".join([
            "Speculative decoding:",
            f"\tDraft length (k): {engine.speculate}",
            f"\tVerify rounds:    {st['rounds']}",
            f"\tProposed:         {st['proposed']}"
            + (f" ({by})" if by else ""),
            f"\tAccepted:         {st['accepted']} ({rate:.0%})",
            f"\tTokens/round:     {run_len:.2f}",
        ]))
    if engine is not None and getattr(engine, "tp", None) is not None \
            and engine.tp.tp > 1:
        st = engine.tp_stats()
        ps = st["psums_per_token"]
        lines = [
            "Tensor parallelism:",
            f"\tPlan:             {st['plan']}",
            f"\tDevices:          {len(st['devices'])}"
            + (f" ({', '.join(st['devices'])})" if st["devices"] else ""),
            f"\tPsums/token:      {sum(ps.values())} "
            f"(attn_out {ps['attn_out']}, mlp_out {ps['mlp_out']})",
        ]
        if "kv_pages_in_use" in st:
            total = st["kv_pages_total"]
            for k, n in enumerate(st["kv_pages_in_use"]):
                pct = n / total if total else 0.0
                lines.append(f"\tKV pool shard {k}:  {n}/{total} pages "
                             f"({pct:.0%})")
        for note in st["notices"]:
            lines.append(f"\tNotice:           {note}")
        sections.append("\n".join(lines))
    if router is not None:
        st = router.stats
        routed = st["routed"]
        hit_pct = st["affinity_hits"] / routed if routed else 0.0
        lines = [
            "Prefix-affinity router:",
            f"\tReplicas:         {len(router.replicas)}",
            f"\tPolicy:           {router.policy} "
            f"(spill factor {router.spill_factor:g})",
            f"\tRouted:           {routed}",
            f"\tAffinity hits:    {st['affinity_hits']} ({hit_pct:.0%})",
            f"\tSpills:           {st['spills']}",
            f"\tDrains:           {st['drains']} "
            f"({st['resubmitted']} requests re-routed)",
        ]
        for rid in sorted(router.replicas):
            rep = router.replicas[rid]
            occ = rep.engine.radix_occupancy()
            lines.append(
                f"\tReplica {rid}:        load {router.load(rid)} "
                f"({rep.engine.active()} active, "
                f"{rep.engine.pending()} queued), "
                f"{occ['nodes']} radix nodes")
        sections.append("\n".join(lines))
    if autoscaler is not None:
        st = autoscaler.stats
        jobs = ", ".join(f"{rid}->job {jid}"
                         for rid, jid in sorted(autoscaler.jobs.items()))
        sections.append("\n".join([
            "Autoscaler (scavenger replicas):",
            f"\tTicks:            {st['ticks']}",
            f"\tLast probe:       {st['last_probe']} idle "
            f"node(s) @ {autoscaler.req.nodes}/replica",
            f"\tScale-ups:        {st['scale_ups']}",
            f"\tDrains:           {st['drains']} "
            f"({st['requeued_requests']} requests requeued)",
            f"\tReplica jobs:     {jobs or '(none)'}",
        ]))
    if tracer is not None:
        sections.append("Serving SLO (per tenant/QOS):\n"
                        + tracer.slo.format_report())
    return "\n\n".join(sections) if sections else "sdiag: nothing to report"


def sprio(cluster: Cluster) -> str:
    """``sprio -l``: multifactor priority breakdown for pending jobs."""
    rows = [f"{'JOBID':<8}{'USER':<10}{'ACCOUNT':<10}{'PRIORITY':>9}"
            f"{'AGE':>7}{'FAIRSHARE':>10}{'JOBSIZE':>8}{'PARTITION':>10}"
            f"{'QOS':>7}{'NICE':>6}"]
    engine = cluster.priority_engine
    pending = [j for j in cluster.jobs.values()
               if j.state == JobState.PENDING]
    for job in sorted(pending, key=lambda j: j.job_id):
        b = engine.breakdown(job, cluster.clock, cluster.partitions,
                             len(cluster.nodes))
        rows.append(f"{job.job_id:<8}{job.user:<10}{job.account[:9]:<10}"
                    f"{b.total:>9.0f}{b.age:>7.0f}{b.fairshare:>10.0f}"
                    f"{b.job_size:>8.0f}{b.partition:>10.0f}"
                    f"{b.qos:>7.0f}{b.nice:>6.0f}")
    return "\n".join(rows)


# ------------------------------------------------------------- parsing ------

def _parse_gres(text: str) -> dict:
    """``tpu:4`` or ``gpu:2,tpu:4`` -> {"tpu": 4, ...}."""
    out = {}
    if not text:
        return out
    for part in text.split(","):
        name, _, count = part.partition(":")
        out[name.strip()] = int(count or 1)
    return out


def _parse_mem(text: str) -> int:
    """``32G`` / ``512M`` -> MB."""
    text = text.strip().upper()
    if text.endswith("G"):
        return int(float(text[:-1]) * 1024)
    if text.endswith("M"):
        return int(float(text[:-1]))
    return int(text)


def _parse_time(text: str) -> int:
    """``D-HH:MM:SS`` / ``HH:MM:SS`` / ``MM:SS`` / minutes -> seconds."""
    text = text.strip()
    days = 0
    if "-" in text:
        d, text = text.split("-", 1)
        days = int(d)
    parts = [int(p) for p in text.split(":")]
    if len(parts) == 3:
        h, m, s = parts
    elif len(parts) == 2:
        h, (m, s) = 0, parts
    else:
        h, m, s = 0, parts[0], 0
    return days * 86_400 + h * 3_600 + m * 60 + s
