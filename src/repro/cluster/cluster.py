"""The cluster engine: inventory + job table + virtual clock + event loop.

Deterministic discrete-event simulation of a SLURM-managed TPU cluster.
`sbatch`-style submission enqueues jobs; `tick()` advances the clock to the
next event (job end), releases resources, resolves dependencies, and runs a
scheduling pass.  Jobs carrying a real ``script`` callable execute it at
start time — this is how the examples launch actual JAX work through the
Mesh bridge.

HA (paper §4 step 3 note on ``slurm_enable_ha``): the full controller state
serializes to a dict (``snapshot()``) and a standby controller restores from
it (``Cluster.restore``) — the failover test proves no job state is lost.
The fair-share ledger rides along, so a failover keeps every tenant's decayed
usage (no free reset for the hog).

Multi-tenancy: every job belongs to an (account, QOS) pair.  Queue order
comes from the multifactor fair-share engine (``repro.policy``); finished
and preempted segments charge TRES-seconds to the account tree; a high-QOS
job that cannot start may preempt scavenger/normal victims, which requeue
(keeping checkpointed progress via ``repro.checkpoint.store``) or are
cancelled, per the victim QOS's ``preempt_mode``.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.cluster.job import (
    Dependency, DependencyKind, Job, JobState, ResourceRequest,
)
from repro.cluster.node import Node, NodeState, Partition
from repro.cluster.scheduler import Decision, capacity_probe, schedule_pass
from repro.policy import (
    PREEMPT_CANCEL, QOS, FairShareTree, MultifactorPriority,
    PriorityWeights, default_qos_table,
)

#: bound on preempt -> requeue -> rerun cycles inside one schedule() call
_MAX_PREEMPT_ROUNDS = 8


@dataclass
class AccountingRecord:
    """One sacct row (a preempted job contributes one row per segment)."""
    job_id: int
    name: str
    user: str
    partition: str
    submit: float
    start: Optional[float]
    end: Optional[float]
    state: str
    nodes: tuple[str, ...]
    elapsed: float
    exit_code: Optional[int]
    account: str = "root"
    qos: str = "normal"
    tres_charged: float = 0.0          # weighted TRES-seconds billed


class Cluster:
    """Software-defined SLURM cluster (controller + inventory)."""

    def __init__(self, nodes: list[Node], partitions: list[Partition],
                 sched_mode: str = "easy", real_mode: bool = False,
                 fairshare: Optional[FairShareTree] = None,
                 qos_table: Optional[dict[str, QOS]] = None,
                 priority_weights: Optional[PriorityWeights] = None):
        self.nodes: dict[str, Node] = {n.name: n for n in nodes}
        self.partitions: dict[str, Partition] = {p.name: p for p in partitions}
        for p in partitions:
            for nm in p.nodes:
                assert nm in self.nodes, f"partition {p.name}: unknown {nm}"
        self.sched_mode = sched_mode
        self.real_mode = real_mode
        self.clock: float = 0.0
        self.jobs: dict[int, Job] = {}
        # live view of non-terminal jobs, so scheduling passes stay O(active)
        # instead of rescanning the whole (append-only) job table — the
        # difference between O(n) and O(n^2) over a long simulation
        self._active: dict[int, Job] = {}
        self.accounting: list[AccountingRecord] = []
        self._next_id = itertools.count(1)
        self.metrics = None            # optional monitoring registry hook
        self.tracer = None             # optional lifecycle tracer hook
        # sdiag-style scheduler statistics: wall time per schedule_pass
        # (the virtual clock stamps the spans; these stats time the REAL
        # cost of a controller cycle, what SLURM's sdiag reports)
        self.sched_stats = {"passes": 0, "last_us": 0.0, "total_us": 0.0,
                            "max_us": 0.0, "starts": 0}
        # slurm_now-style capacity probes (autoscaler growth signal)
        self.probe_stats = {"probes": 0, "last_nodes": 0}
        self.fairshare = fairshare or FairShareTree()
        self.qos_table = dict(qos_table) if qos_table is not None \
            else default_qos_table()
        self.priority_engine = MultifactorPriority(
            self.fairshare, self.qos_table,
            priority_weights or PriorityWeights())
        self.preemptions_total = 0

    # ------------------------------------------------------------ submit ----
    def default_partition(self) -> str:
        for p in self.partitions.values():
            if p.default:
                return p.name
        return next(iter(self.partitions))

    def submit(self, name: str, req: ResourceRequest, user: str = "ubuntu",
               partition: Optional[str] = None, priority: int = 0,
               run_time_s: float = 60.0, script: Optional[Callable] = None,
               dependency: str = "", array: int = 0,
               comment: str = "", account: Optional[str] = None,
               qos: str = "normal", ckpt_interval_s: Optional[float] = None,
               checkpoint_dir: Optional[str] = None,
               kind: str = "batch") -> list[int]:
        """sbatch.  Returns job id(s) (``array > 0`` submits an array)."""
        partition = partition or self.default_partition()
        if partition not in self.partitions:
            raise ValueError(f"invalid partition {partition!r}")
        if qos not in self.qos_table:
            raise ValueError(f"invalid qos {qos!r} "
                             f"(have {sorted(self.qos_table)})")
        q = self.qos_table[qos]
        if q.max_wall_s is not None and req.time_limit_s > q.max_wall_s:
            raise ValueError(f"time limit {req.time_limit_s}s exceeds QOS "
                             f"{qos} MaxWall {q.max_wall_s}s")
        if req.time_limit_s > self.partitions[partition].max_time_s:
            raise ValueError(
                f"time limit {req.time_limit_s}s exceeds partition max "
                f"{self.partitions[partition].max_time_s}s")
        if account is None:
            account = self.fairshare.account_of(user)
        elif account not in self.fairshare.accounts:
            self.fairshare.add_account(account)   # lenient auto-association
        deps = tuple(Dependency.parse(dependency)) if dependency else ()
        for d in deps:
            if d.job_id not in self.jobs:
                raise ValueError(f"dependency on unknown job {d.job_id}")
        n = max(array, 1)
        ids = []
        for i in range(n):
            jid = next(self._next_id)
            job = Job(
                job_id=jid, name=name, user=user, partition=partition,
                req=req, priority=priority, submit_time=self.clock,
                run_time_s=run_time_s, script=script, dependencies=deps,
                array_index=i if array else None, comment=comment,
                account=account, qos=qos, ckpt_interval_s=ckpt_interval_s,
                checkpoint_dir=checkpoint_dir, kind=kind)
            self.jobs[jid] = job
            if not job.state.finished:
                self._active[jid] = job
            self._refresh_dependency(job)
            self._trace_job_submit(job)
            ids.append(jid)
        self.schedule()
        return ids

    def capacity_now(self, req: ResourceRequest,
                     partition: Optional[str] = None) -> int:
        """slurm_now: the largest node count a job shaped like ``req``
        could start immediately (the autoscaler's growth probe).  Pure
        read — nothing is submitted, reserved, or preempted."""
        part = self.partitions[partition or self.default_partition()]
        n = capacity_probe(self.nodes, part, req)
        self.probe_stats["probes"] += 1
        self.probe_stats["last_nodes"] = n
        return n

    def cancel(self, job_id: int):
        """scancel."""
        job = self.jobs[job_id]
        if job.state.finished:
            return
        if job.state == JobState.RUNNING:
            self._finish(job, JobState.CANCELLED)
        else:
            job.state = JobState.CANCELLED
            job.end_time = self.clock
            self._trace_job_close(job, "CANCELLED")
            self._retire(job)
            self._account(job)
        self.schedule()

    def update_job(self, job_id: int, **kwargs):
        """scontrol update job — only pending jobs may change resources."""
        job = self.jobs[job_id]
        if "priority" in kwargs:
            job.priority = int(kwargs.pop("priority"))
        if kwargs and job.state != JobState.PENDING:
            raise ValueError("cannot modify a non-pending job's resources")
        for k, v in kwargs.items():
            setattr(job, k, v)
        self.schedule()

    def set_node_state(self, name: str, state: NodeState, reason: str = ""):
        """scontrol update nodename=... state=... (drain/down/resume)."""
        node = self.nodes[name]
        node.set_state(state, reason)
        if state == NodeState.DOWN:
            # requeue jobs that lost their node (SLURM requeues on failure)
            for jid in list(node.running_jobs):
                job = self.jobs[jid]
                self._release_nodes(job)
                job.state = JobState.PENDING
                job.reason = "BeginTime"
                job.start_time = None
                job.nodes_alloc = ()
                self._trace_job_state(job, "PENDING", reason="NodeDown")
        self.schedule()

    # ----------------------------------------------------------- tracing ----
    # Job lifecycle spans share the serving tracer's timeline; the virtual
    # clock stamps them (ts=self.clock), so simulated jobs and wall-clock
    # serving requests render side by side in Perfetto.  One root span per
    # job, with back-to-back state child spans (PENDING/RUNNING/...).
    def _trace_job_submit(self, job: Job):
        tr = self.tracer
        if tr is None or job.state.finished:
            return
        root = tr.begin(f"job {job.job_id}", cat="job",
                        track=(f"cluster:{job.account}",
                               f"job {job.job_id}"),
                        ts=self.clock, job_name=job.name, user=job.user,
                        partition=job.partition, qos=job.qos,
                        account=job.account)
        tr.event("SUBMIT", root, ts=self.clock)
        state = tr.begin("PENDING", cat="state", parent=root,
                         ts=self.clock, reason=job.reason)
        job._trace = {"root": root, "state": state}

    def _trace_job_state(self, job: Job, name: str, **attrs):
        """End the current state span, open the next one."""
        tr = self.tracer
        trace = getattr(job, "_trace", None)
        if tr is None or not trace:
            return
        cur = trace.pop("state", None)
        if cur is not None:
            tr.end(cur, ts=self.clock)
        trace["state"] = tr.begin(name, cat="state", parent=trace["root"],
                                  ts=self.clock, **attrs)

    def _trace_job_close(self, job: Job, state: str):
        """Terminal transition: close the state span and the root."""
        tr = self.tracer
        trace = getattr(job, "_trace", None)
        if tr is None or not trace:
            return
        cur = trace.pop("state", None)
        if cur is not None:
            tr.end(cur, ts=self.clock)
        root = trace.pop("root", None)
        if root is not None:
            tr.event(state, root, ts=self.clock)
            tr.end(root, ts=self.clock, state=state)

    # --------------------------------------------------------- scheduling ----
    def _retire(self, job: Job):
        """Drop a job that reached a terminal state from the active view."""
        self._active.pop(job.job_id, None)

    def _pending(self) -> list[Job]:
        return [j for j in self._active.values()
                if j.state == JobState.PENDING]

    def _running(self) -> list[Job]:
        return [j for j in self._active.values()
                if j.state == JobState.RUNNING]

    def _refresh_dependency(self, job: Job):
        """Update the Dependency gate / fail jobs with impossible deps."""
        blocked = False
        for d in job.dependencies:
            dep = self.jobs.get(d.job_id)
            if dep is None:
                continue
            if d.kind == DependencyKind.AFTEROK:
                if dep.state.finished and not dep.state.ok:
                    job.state = JobState.CANCELLED   # DependencyNeverSatisfied
                    job.end_time = self.clock
                    job.reason = "DependencyNeverSatisfied"
                    self._trace_job_close(job, "CANCELLED")
                    self._retire(job)
                    self._account(job)
                    return
                blocked |= not dep.state.ok
            elif d.kind == DependencyKind.AFTERNOTOK:
                if dep.state.ok:
                    job.state = JobState.CANCELLED
                    job.end_time = self.clock
                    job.reason = "DependencyNeverSatisfied"
                    self._trace_job_close(job, "CANCELLED")
                    self._retire(job)
                    self._account(job)
                    return
                blocked |= not dep.state.finished
            elif d.kind == DependencyKind.AFTERANY:
                blocked |= not dep.state.finished
            elif d.kind == DependencyKind.AFTER:
                blocked |= dep.start_time is None
        job.reason = "Dependency" if blocked else "Priority"

    def schedule(self) -> Decision:
        for job in self._pending():
            self._refresh_dependency(job)
        self.fairshare.decay_to(self.clock)
        decision = None
        for _ in range(_MAX_PREEMPT_ROUNDS):
            priority_fn = self.priority_engine.priority_fn(
                self.clock, self.partitions, len(self.nodes))
            t0 = time.perf_counter()
            decision = schedule_pass(
                self.clock, self._pending(), self._running(), self.nodes,
                self.partitions, self.sched_mode, priority_fn=priority_fn,
                qos_table=self.qos_table, tracer=self.tracer)
            dt_us = (time.perf_counter() - t0) * 1e6
            st = self.sched_stats
            st["passes"] += 1
            st["last_us"] = dt_us
            st["total_us"] += dt_us
            st["max_us"] = max(st["max_us"], dt_us)
            st["starts"] += len(decision.starts)
            for job_id, alloc in decision.starts:
                self._start(self.jobs[job_id], alloc)
            for job_id, reason in decision.holds:
                job = self.jobs.get(job_id)
                if job and job.state == JobState.PENDING:
                    job.reason = reason
            for res in decision.reservations:
                job = self.jobs.get(res.job_id)
                if job and job.state == JobState.PENDING:
                    job.reason = "Resources"
            if not decision.preemptions:
                break
            for pre in decision.preemptions:
                for vid in pre.victims:
                    victim = self.jobs[vid]
                    if victim.state == JobState.RUNNING:
                        self._preempt(victim, by_job_id=pre.job_id)
        self._export_metrics()
        return decision

    def _export_metrics(self):
        if self.metrics is None:
            return
        from repro.monitoring.metrics import (
            METRIC_ACCOUNT_FAIRSHARE, METRIC_ACCOUNT_USAGE,
            METRIC_JOBS_PENDING, METRIC_JOBS_RUNNING, METRIC_PREEMPTIONS,
        )
        self.metrics.gauge(METRIC_JOBS_PENDING).set(len(self._pending()))
        self.metrics.gauge(METRIC_JOBS_RUNNING).set(len(self._running()))
        self.metrics.gauge(METRIC_PREEMPTIONS).set(self.preemptions_total)
        usage = self.metrics.gauge(
            METRIC_ACCOUNT_USAGE, "decayed weighted TRES-seconds per account")
        factor = self.metrics.gauge(
            METRIC_ACCOUNT_FAIRSHARE, "fair-share factor 2^(-usage/shares)")
        for name in self.fairshare.accounts:
            usage.set(self.fairshare.usage.get(name, 0.0), account=name)
            factor.set(self.fairshare.fair_share_factor(name), account=name)

    def _start(self, job: Job, alloc: tuple[str, ...]):
        for nm in alloc:
            self.nodes[nm].allocate(job.job_id, job.req.cpus_per_node,
                                    job.req.mem_mb_per_node,
                                    job.req.gres_per_node)
        job.state = JobState.RUNNING
        job.start_time = self.clock
        job.nodes_alloc = alloc
        job.reason = ""
        self._trace_job_state(job, "RUNNING", nodes=len(alloc))
        if self.real_mode and job.script is not None:
            try:
                job.result = job.script(job, alloc)
                job.exit_code = 0
            except Exception as e:              # noqa: BLE001 — job failure
                job.exit_code = 1
                job.comment = f"{type(e).__name__}: {e}"

    def _release_nodes(self, job: Job):
        for nm in job.nodes_alloc:
            self.nodes[nm].release(job.job_id, job.req.cpus_per_node,
                                   job.req.mem_mb_per_node,
                                   job.req.gres_per_node)

    def _finish(self, job: Job, state: JobState):
        self._release_nodes(job)
        job.state = state
        job.end_time = self.clock
        if job.exit_code is None:
            job.exit_code = 0 if state == JobState.COMPLETED else 1
        self._trace_job_close(job, state.name)
        self._retire(job)
        self._account(job)

    def _preempt(self, job: Job, by_job_id: int):
        """Evict a running job for a higher-QOS one: account the finished
        segment, charge its usage, then requeue (or cancel, per the victim
        QOS's preempt_mode)."""
        assert job.state == JobState.RUNNING
        elapsed = self.clock - job.start_time
        mode = self.qos_table[job.qos].preempt_mode if job.qos in \
            self.qos_table else "requeue"
        self._release_nodes(job)
        job.end_time = self.clock
        self.preemptions_total += 1
        if self.metrics is not None:
            from repro.monitoring.metrics import METRIC_PREEMPTIONS_BY
            self.metrics.counter(
                METRIC_PREEMPTIONS_BY, "preempted segments").inc(
                qos=job.qos, account=job.account)
        if mode == PREEMPT_CANCEL:
            job.state = JobState.CANCELLED
            job.reason = f"PreemptedBy={by_job_id}"
            if job.exit_code is None:
                job.exit_code = 1
            self._trace_job_close(job, "CANCELLED")
            self._retire(job)
            self._account(job)
            return
        # requeue path: one accounting row for the evicted segment
        job.state = JobState.PREEMPTED
        job.reason = f"PreemptedBy={by_job_id}"
        # zero-length PREEMPTED state between RUNNING and the requeued
        # PENDING: both transitions happen at the same virtual instant
        self._trace_job_state(job, "PREEMPTED", by=by_job_id)
        self._account(job)
        job.record_preemption(elapsed)
        self._restore_progress(job)
        job.state = JobState.PENDING
        job.reason = "Requeued"
        job.start_time = None
        job.end_time = None
        job.nodes_alloc = ()
        self._trace_job_state(job, "PENDING", reason="Requeued")

    def _restore_progress(self, job: Job):
        """Checkpoint-restore hook: a preempted job with a checkpoint dir
        resumes from its last saved step (convention: the trainer saves
        ``step = seconds of completed work``) instead of restarting."""
        if job.checkpoint_dir is None:
            return
        from repro.checkpoint import store
        step = store.latest_step(job.checkpoint_dir)
        if step is not None:
            job.progress_s = max(job.progress_s, float(step))

    def _account(self, job: Job):
        elapsed = ((job.end_time - job.start_time)
                   if job.start_time is not None and job.end_time is not None
                   else 0.0)
        charged = 0.0
        if elapsed > 0:
            usage_factor = (self.qos_table[job.qos].usage_factor
                            if job.qos in self.qos_table else 1.0)
            charged = self.fairshare.charge(
                job.account, job.req, elapsed, self.clock,
                usage_factor=usage_factor)
        self.accounting.append(AccountingRecord(
            job.job_id, job.name, job.user, job.partition, job.submit_time,
            job.start_time, job.end_time, job.state.name,
            job.nodes_alloc, elapsed, job.exit_code,
            account=job.account, qos=job.qos, tres_charged=charged))

    # -------------------------------------------------------- event loop ----
    def next_event_time(self) -> Optional[float]:
        ends = [j.start_time + j.runtime() for j in self._running()]
        return min(ends) if ends else None

    def tick(self) -> bool:
        """Advance to the next job-end event.  False if nothing to do."""
        t = self.next_event_time()
        if t is None:
            return False
        self.clock = t
        for job in self._running():
            if job.start_time + job.runtime() <= self.clock + 1e-9:
                if job.real_failed():
                    self._finish(job, JobState.FAILED)
                elif job.will_timeout():
                    self._finish(job, JobState.TIMEOUT)
                else:
                    self._finish(job, JobState.COMPLETED)
        self.schedule()
        return True

    def run(self, max_events: int = 100_000):
        """Run until the queue drains (or the event budget is spent)."""
        for _ in range(max_events):
            if not self.tick():
                break
        stuck = [j.job_id for j in self._pending()]
        return stuck

    # ------------------------------------------------------------- HA -------
    def snapshot(self) -> dict:
        """Serializable controller state (for HA failover)."""
        import copy
        return {
            "clock": self.clock,
            "jobs": copy.deepcopy(self.jobs),
            "nodes": copy.deepcopy(self.nodes),
            "accounting": copy.deepcopy(self.accounting),
            "next_id": next(self._next_id),
            "sched_mode": self.sched_mode,
            "partitions": list(self.partitions.values()),
            "fairshare": self.fairshare.snapshot(),
            "qos_table": dict(self.qos_table),     # QOS objects are frozen
            "priority_weights": self.priority_engine.weights,
            "preemptions_total": self.preemptions_total,
        }

    @classmethod
    def restore(cls, snap: dict) -> "Cluster":
        c = cls.__new__(cls)
        c.nodes = snap["nodes"]
        c.partitions = {p.name: p for p in snap["partitions"]}
        c.sched_mode = snap["sched_mode"]
        c.real_mode = False
        c.clock = snap["clock"]
        c.jobs = snap["jobs"]
        c._active = {jid: j for jid, j in c.jobs.items()
                     if not j.state.finished}
        c.accounting = snap["accounting"]
        c._next_id = itertools.count(snap["next_id"])
        c.metrics = None
        c.tracer = None
        c.sched_stats = {"passes": 0, "last_us": 0.0, "total_us": 0.0,
                         "max_us": 0.0, "starts": 0}
        c.probe_stats = {"probes": 0, "last_nodes": 0}
        c.fairshare = FairShareTree.restore(
            snap.get("fairshare", FairShareTree().snapshot()))
        c.qos_table = dict(snap.get("qos_table") or default_qos_table())
        c.priority_engine = MultifactorPriority(
            c.fairshare, c.qos_table,
            snap.get("priority_weights") or PriorityWeights())
        c.preemptions_total = snap.get("preemptions_total", 0)
        return c
