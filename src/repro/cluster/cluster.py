"""The cluster engine: inventory + job table + virtual clock + event loop.

Deterministic discrete-event simulation of a SLURM-managed TPU cluster.
`sbatch`-style submission enqueues jobs; `tick()` advances the clock to the
next event (job end), releases resources, resolves dependencies, and runs a
scheduling pass.  Jobs carrying a real ``script`` callable execute it at
start time — this is how the examples launch actual JAX work through the
Mesh bridge.

HA (paper §4 step 3 note on ``slurm_enable_ha``): the full controller state
serializes to a dict (``snapshot()``) and a standby controller restores from
it (``Cluster.restore``) — the failover test proves no job state is lost.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.cluster.job import (
    Dependency, DependencyKind, Job, JobState, ResourceRequest,
)
from repro.cluster.node import Node, NodeState, Partition
from repro.cluster.scheduler import Decision, schedule_pass


@dataclass
class AccountingRecord:
    """One sacct row."""
    job_id: int
    name: str
    user: str
    partition: str
    submit: float
    start: Optional[float]
    end: Optional[float]
    state: str
    nodes: tuple[str, ...]
    elapsed: float
    exit_code: Optional[int]


class Cluster:
    """Software-defined SLURM cluster (controller + inventory)."""

    def __init__(self, nodes: list[Node], partitions: list[Partition],
                 sched_mode: str = "easy", real_mode: bool = False):
        self.nodes: dict[str, Node] = {n.name: n for n in nodes}
        self.partitions: dict[str, Partition] = {p.name: p for p in partitions}
        for p in partitions:
            for nm in p.nodes:
                assert nm in self.nodes, f"partition {p.name}: unknown {nm}"
        self.sched_mode = sched_mode
        self.real_mode = real_mode
        self.clock: float = 0.0
        self.jobs: dict[int, Job] = {}
        self.accounting: list[AccountingRecord] = []
        self._next_id = itertools.count(1)
        self.metrics = None            # optional monitoring registry hook

    # ------------------------------------------------------------ submit ----
    def default_partition(self) -> str:
        for p in self.partitions.values():
            if p.default:
                return p.name
        return next(iter(self.partitions))

    def submit(self, name: str, req: ResourceRequest, user: str = "ubuntu",
               partition: Optional[str] = None, priority: int = 0,
               run_time_s: float = 60.0, script: Optional[Callable] = None,
               dependency: str = "", array: int = 0,
               comment: str = "") -> list[int]:
        """sbatch.  Returns job id(s) (``array > 0`` submits an array)."""
        partition = partition or self.default_partition()
        if partition not in self.partitions:
            raise ValueError(f"invalid partition {partition!r}")
        if req.time_limit_s > self.partitions[partition].max_time_s:
            raise ValueError(
                f"time limit {req.time_limit_s}s exceeds partition max "
                f"{self.partitions[partition].max_time_s}s")
        deps = tuple(Dependency.parse(dependency)) if dependency else ()
        for d in deps:
            if d.job_id not in self.jobs:
                raise ValueError(f"dependency on unknown job {d.job_id}")
        n = max(array, 1)
        ids = []
        for i in range(n):
            jid = next(self._next_id)
            job = Job(
                job_id=jid, name=name, user=user, partition=partition,
                req=req, priority=priority, submit_time=self.clock,
                run_time_s=run_time_s, script=script, dependencies=deps,
                array_index=i if array else None, comment=comment)
            self._refresh_dependency(job)
            self.jobs[jid] = job
            ids.append(jid)
        self.schedule()
        return ids

    def cancel(self, job_id: int):
        """scancel."""
        job = self.jobs[job_id]
        if job.state.finished:
            return
        if job.state == JobState.RUNNING:
            self._finish(job, JobState.CANCELLED)
        else:
            job.state = JobState.CANCELLED
            job.end_time = self.clock
            self._account(job)
        self.schedule()

    def update_job(self, job_id: int, **kwargs):
        """scontrol update job — only pending jobs may change resources."""
        job = self.jobs[job_id]
        if "priority" in kwargs:
            job.priority = int(kwargs.pop("priority"))
        if kwargs and job.state != JobState.PENDING:
            raise ValueError("cannot modify a non-pending job's resources")
        for k, v in kwargs.items():
            setattr(job, k, v)
        self.schedule()

    def set_node_state(self, name: str, state: NodeState, reason: str = ""):
        """scontrol update nodename=... state=... (drain/down/resume)."""
        node = self.nodes[name]
        node.set_state(state, reason)
        if state == NodeState.DOWN:
            # requeue jobs that lost their node (SLURM requeues on failure)
            for jid in list(node.running_jobs):
                job = self.jobs[jid]
                self._release_nodes(job)
                job.state = JobState.PENDING
                job.reason = "BeginTime"
                job.start_time = None
                job.nodes_alloc = ()
        self.schedule()

    # --------------------------------------------------------- scheduling ----
    def _pending(self) -> list[Job]:
        return [j for j in self.jobs.values() if j.state == JobState.PENDING]

    def _running(self) -> list[Job]:
        return [j for j in self.jobs.values() if j.state == JobState.RUNNING]

    def _refresh_dependency(self, job: Job):
        """Update the Dependency gate / fail jobs with impossible deps."""
        blocked = False
        for d in job.dependencies:
            dep = self.jobs.get(d.job_id)
            if dep is None:
                continue
            if d.kind == DependencyKind.AFTEROK:
                if dep.state.finished and not dep.state.ok:
                    job.state = JobState.CANCELLED   # DependencyNeverSatisfied
                    job.end_time = self.clock
                    job.reason = "DependencyNeverSatisfied"
                    self._account(job)
                    return
                blocked |= not dep.state.ok
            elif d.kind == DependencyKind.AFTERNOTOK:
                if dep.state.ok:
                    job.state = JobState.CANCELLED
                    job.end_time = self.clock
                    job.reason = "DependencyNeverSatisfied"
                    self._account(job)
                    return
                blocked |= not dep.state.finished
            elif d.kind == DependencyKind.AFTERANY:
                blocked |= not dep.state.finished
            elif d.kind == DependencyKind.AFTER:
                blocked |= dep.start_time is None
        job.reason = "Dependency" if blocked else "Priority"

    def schedule(self) -> Decision:
        for job in self._pending():
            self._refresh_dependency(job)
        decision = schedule_pass(
            self.clock, self._pending(), self._running(), self.nodes,
            self.partitions, self.sched_mode)
        for job_id, alloc in decision.starts:
            self._start(self.jobs[job_id], alloc)
        for res in decision.reservations:
            job = self.jobs.get(res.job_id)
            if job and job.state == JobState.PENDING:
                job.reason = "Resources"
        if self.metrics is not None:
            self.metrics.gauge("slurm_jobs_pending").set(len(self._pending()))
            self.metrics.gauge("slurm_jobs_running").set(len(self._running()))
        return decision

    def _start(self, job: Job, alloc: tuple[str, ...]):
        for nm in alloc:
            self.nodes[nm].allocate(job.job_id, job.req.cpus_per_node,
                                    job.req.mem_mb_per_node,
                                    job.req.gres_per_node)
        job.state = JobState.RUNNING
        job.start_time = self.clock
        job.nodes_alloc = alloc
        job.reason = ""
        if self.real_mode and job.script is not None:
            try:
                job.result = job.script(job, alloc)
                job.exit_code = 0
            except Exception as e:              # noqa: BLE001 — job failure
                job.exit_code = 1
                job.comment = f"{type(e).__name__}: {e}"

    def _release_nodes(self, job: Job):
        for nm in job.nodes_alloc:
            self.nodes[nm].release(job.job_id, job.req.cpus_per_node,
                                   job.req.mem_mb_per_node,
                                   job.req.gres_per_node)

    def _finish(self, job: Job, state: JobState):
        self._release_nodes(job)
        job.state = state
        job.end_time = self.clock
        if job.exit_code is None:
            job.exit_code = 0 if state == JobState.COMPLETED else 1
        self._account(job)

    def _account(self, job: Job):
        self.accounting.append(AccountingRecord(
            job.job_id, job.name, job.user, job.partition, job.submit_time,
            job.start_time, job.end_time, job.state.name,
            job.nodes_alloc,
            (job.end_time - job.start_time) if job.start_time is not None
            and job.end_time is not None else 0.0,
            job.exit_code))

    # -------------------------------------------------------- event loop ----
    def next_event_time(self) -> Optional[float]:
        ends = [j.start_time + j.runtime() for j in self._running()]
        return min(ends) if ends else None

    def tick(self) -> bool:
        """Advance to the next job-end event.  False if nothing to do."""
        t = self.next_event_time()
        if t is None:
            return False
        self.clock = t
        for job in self._running():
            if job.start_time + job.runtime() <= self.clock + 1e-9:
                if job.real_failed():
                    self._finish(job, JobState.FAILED)
                elif job.will_timeout():
                    self._finish(job, JobState.TIMEOUT)
                else:
                    self._finish(job, JobState.COMPLETED)
        self.schedule()
        return True

    def run(self, max_events: int = 100_000):
        """Run until the queue drains (or the event budget is spent)."""
        for _ in range(max_events):
            if not self.tick():
                break
        stuck = [j.job_id for j in self._pending()]
        return stuck

    # ------------------------------------------------------------- HA -------
    def snapshot(self) -> dict:
        """Serializable controller state (for HA failover)."""
        import copy
        return {
            "clock": self.clock,
            "jobs": copy.deepcopy(self.jobs),
            "nodes": copy.deepcopy(self.nodes),
            "accounting": copy.deepcopy(self.accounting),
            "next_id": next(self._next_id),
            "sched_mode": self.sched_mode,
            "partitions": list(self.partitions.values()),
        }

    @classmethod
    def restore(cls, snap: dict) -> "Cluster":
        c = cls.__new__(cls)
        c.nodes = snap["nodes"]
        c.partitions = {p.name: p for p in snap["partitions"]}
        c.sched_mode = snap["sched_mode"]
        c.real_mode = False
        c.clock = snap["clock"]
        c.jobs = snap["jobs"]
        c.accounting = snap["accounting"]
        c._next_id = itertools.count(snap["next_id"])
        c.metrics = None
        return c
