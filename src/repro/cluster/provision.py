"""DeepOps-style provisioning (paper §4): declarative inventory -> cluster,
plus the validation suite (our ``slurm-validation.yml``).

The Ansible inventory file of §4.2 becomes :class:`ClusterSpec`; running
``provision()`` "deploys" the software-defined cluster; ``validate()``
is the analogue of ``ansible-playbook ... slurm-validation.yml`` — it checks
connectivity (every node reachable = present & not DOWN), GRES visibility
(the `nvidia-smi` check of §5.2.2 becomes a per-node gres probe), and runs a
canary job through the scheduler end-to-end.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.cluster.cluster import Cluster
from repro.cluster.job import JobState, ResourceRequest
from repro.cluster.node import Node, NodeState, Partition


@dataclass(frozen=True)
class HostSpec:
    """One inventory line: hostname + resources (+ TPU grid coordinate)."""
    name: str
    cpus: int = 16
    mem_mb: int = 131_072
    gres: tuple[tuple[str, int], ...] = (("tpu", 4),)
    coord: tuple[int, int] | None = None


@dataclass(frozen=True)
class PartitionSpec:
    name: str
    hosts: tuple[str, ...]
    max_time_s: int = 24 * 3600
    priority_tier: int = 1
    default: bool = False


@dataclass(frozen=True)
class ClusterSpec:
    """The whole inventory (config/inventory in DeepOps terms)."""
    name: str
    hosts: tuple[HostSpec, ...]
    partitions: tuple[PartitionSpec, ...]
    slurm_enable_ha: bool = False
    sched_mode: str = "easy"


def tpu_pod_spec(name: str = "v5e-pod", hosts_x: int = 8, hosts_y: int = 8,
                 chips_per_host: int = 4, **kw) -> ClusterSpec:
    """A single TPU v5e pod: hosts_x*hosts_y hosts x 4 chips = 16x16 chips.

    Host (r, c) owns the 2x2 chip block at chip coords (2r..2r+1, 2c..2c+1).
    """
    hosts = tuple(
        HostSpec(name=f"tpu-{r:02d}-{c:02d}", gres=(("tpu", chips_per_host),),
                 coord=(r, c))
        for r in range(hosts_x) for c in range(hosts_y))
    parts = (
        PartitionSpec("batch", tuple(h.name for h in hosts), default=True),
        PartitionSpec("interactive", tuple(h.name for h in hosts[:8]),
                      max_time_s=4 * 3600, priority_tier=2),
    )
    return ClusterSpec(name=name, hosts=hosts, partitions=parts, **kw)


def provision(spec: ClusterSpec, real_mode: bool = False) -> Cluster:
    """Deploy: inventory -> Cluster (the ansible-playbook step of §4.2)."""
    nodes = [
        Node(name=h.name, cpus=h.cpus, mem_mb=h.mem_mb,
             gres=dict(h.gres), coord=h.coord)
        for h in spec.hosts
    ]
    partitions = [
        Partition(p.name, p.hosts, p.max_time_s, p.priority_tier, p.default)
        for p in spec.partitions
    ]
    return Cluster(nodes, partitions, sched_mode=spec.sched_mode,
                   real_mode=real_mode)


@dataclass
class ValidationReport:
    ok: bool
    checks: list = field(default_factory=list)

    def add(self, name: str, ok: bool, detail: str = ""):
        self.checks.append((name, ok, detail))
        self.ok = self.ok and ok

    def __str__(self):
        lines = [f"[{'ok' if ok else 'FAIL'}] {name}"
                 + (f" — {d}" if d else "")
                 for name, ok, d in self.checks]
        lines.append(f"validation: {'PASSED' if self.ok else 'FAILED'}")
        return "\n".join(lines)


def validate(cluster: Cluster, spec: ClusterSpec) -> ValidationReport:
    """slurm-validation.yml: reachability, GRES, partition sanity, canary."""
    rep = ValidationReport(ok=True)

    missing = [h.name for h in spec.hosts if h.name not in cluster.nodes]
    rep.add("inventory: all hosts registered", not missing,
            f"missing={missing}" if missing else f"{len(spec.hosts)} hosts")

    down = [n.name for n in cluster.nodes.values()
            if n.state == NodeState.DOWN]
    rep.add("connectivity: no DOWN nodes", not down, ",".join(down))

    bad_gres = [
        (h.name, g) for h in spec.hosts for g, c in h.gres
        if cluster.nodes.get(h.name) is not None
        and cluster.nodes[h.name].gres.get(g, 0) != c
    ]
    rep.add("gres: every host exposes its accelerators", not bad_gres,
            str(bad_gres) if bad_gres else "")

    orphans = [p.name for p in cluster.partitions.values() if not p.nodes]
    rep.add("partitions: none empty", not orphans, ",".join(orphans))

    # canary job per partition (the §5.2.2 `srun nvidia-smi` analogue)
    for p in cluster.partitions.values():
        jid = cluster.submit(
            f"validate-{p.name}",
            ResourceRequest(nodes=1, gres_per_node={"tpu": 1},
                            time_limit_s=60),
            partition=p.name, run_time_s=1.0)[0]
        for _ in range(10_000):
            if cluster.jobs[jid].state.finished or not cluster.tick():
                break
        ok = cluster.jobs[jid].state == JobState.COMPLETED
        rep.add(f"canary job on partition {p.name}", ok,
                cluster.jobs[jid].state.name)
    return rep
