"""Compatibility shim — the tenancy layer moved to :mod:`repro.policy`.

The account tree, TRES usage ledger, and multifactor priority engine are
engine-agnostic policy (the serving admission controller consults the same
ledger), so they live in ``repro.policy.{accounts,usage,priority}`` now.
This module keeps the historical import path working::

    from repro.cluster.fairshare import FairShareTree   # still fine
    from repro.policy import FairShareTree              # preferred
"""
from repro.policy.accounts import Account, AccountTree
from repro.policy.priority import (
    MultifactorPriority, PriorityBreakdown, PriorityWeights,
)
from repro.policy.usage import DEFAULT_TRES_WEIGHTS, FairShareTree

__all__ = [
    "Account", "AccountTree", "DEFAULT_TRES_WEIGHTS", "FairShareTree",
    "MultifactorPriority", "PriorityBreakdown", "PriorityWeights",
]
