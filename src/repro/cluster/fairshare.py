"""Fair-share: sacctmgr-style account tree + TRES usage ledger + multifactor
priority.

This is the policy substrate behind the paper's §3.2.3 "fairness policies"
claim.  Three pieces, mirroring real SLURM's priority/multifactor plugin:

* **Account tree** — a hierarchy of accounts (``root`` → org → team) with
  raw *shares*; users associate to exactly one account.  Normalized shares
  are computed sibling-relative and multiplied down the tree, exactly like
  ``sshare``'s NormShares column.

* **Usage ledger** — every finished (or preempted) job segment charges its
  account ``elapsed × TRES-cost`` where the cost weights GPU/TPU-seconds
  far above CPU/mem (``TRESBillingWeights``).  Usage decays with an
  exponential half-life (``PriorityDecayHalfLife``), so yesterday's hog is
  not punished forever.  Charges propagate to all ancestors.

* **Multifactor priority** — the classic SLURM composition::

      prio = W_age  * age_factor
           + W_fs   * 2^(-usage/shares)        (the fair-share factor)
           + W_size * job_size_factor
           + W_part * partition_factor
           + W_qos  * qos_factor
           + nice   (the job's static priority)

  Starved accounts rise (usage decays toward 0 → factor → 1); dominant
  accounts sink (usage ≫ shares → factor → 0).  The convergence property
  is proven in ``tests/test_multitenant.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.cluster.job import Job
from repro.cluster.qos import QOS, job_tres

#: TRESBillingWeights — accelerator-seconds dominate the charge.
DEFAULT_TRES_WEIGHTS = {
    "gres/tpu": 1.0,
    "gres/gpu": 1.0,
    "cpu": 0.04,
    "mem": 1e-5,          # per MB-second
}


@dataclass
class Account:
    """One node of the sacctmgr association tree."""
    name: str
    parent: Optional[str] = "root"      # None only for root itself
    shares: int = 1
    description: str = ""


class FairShareTree:
    """Account hierarchy + decayed TRES usage ledger."""

    def __init__(self, half_life_s: float = 7 * 86_400.0,
                 tres_weights: Optional[dict] = None):
        assert half_life_s > 0
        self.half_life_s = half_life_s
        self.tres_weights = dict(tres_weights or DEFAULT_TRES_WEIGHTS)
        self.accounts: dict[str, Account] = {
            "root": Account("root", parent=None, shares=1)}
        self.user_account: dict[str, str] = {}
        self.usage: dict[str, float] = {"root": 0.0}
        self._last_decay: float = 0.0

    # ------------------------------------------------------------- admin ----
    def add_account(self, name: str, parent: str = "root",
                    shares: int = 1, description: str = "") -> Account:
        """``sacctmgr add account <name> parent=<p> fairshare=<shares>``."""
        assert name not in self.accounts, f"account {name!r} exists"
        assert parent in self.accounts, f"unknown parent {parent!r}"
        assert shares >= 1
        acct = Account(name, parent=parent, shares=shares,
                       description=description)
        self.accounts[name] = acct
        self.usage.setdefault(name, 0.0)
        return acct

    def add_user(self, user: str, account: str):
        """``sacctmgr add user <u> account=<a>`` (one association/user)."""
        assert account in self.accounts, f"unknown account {account!r}"
        self.user_account[user] = account

    def account_of(self, user: str, default: str = "root") -> str:
        return self.user_account.get(user, default)

    def children(self, name: str) -> list[Account]:
        return [a for a in self.accounts.values() if a.parent == name]

    def _ancestors(self, name: str):
        """name, parent, ..., root."""
        while name is not None:
            acct = self.accounts[name]
            yield acct
            name = acct.parent

    # ------------------------------------------------------------- usage ----
    def decay_to(self, now: float):
        """Apply exponential half-life decay up to ``now``."""
        dt = now - self._last_decay
        if dt <= 0:
            return
        factor = 2.0 ** (-dt / self.half_life_s)
        for name in self.usage:
            self.usage[name] *= factor
        self._last_decay = now

    def tres_cost_per_s(self, req) -> float:
        """Billing rate of one job-second for this resource request."""
        cost = 0.0
        for key, amount in job_tres(req).items():
            cost += self.tres_weights.get(key, 0.0) * amount
        return cost

    def charge(self, account: str, req, elapsed_s: float, now: float,
               usage_factor: float = 1.0) -> float:
        """Charge ``elapsed_s`` of the request's TRES to the account chain.

        Returns the charged amount (weighted TRES-seconds).
        """
        if account not in self.accounts:        # auto-associate unknowns
            self.add_account(account)
        self.decay_to(now)
        amount = self.tres_cost_per_s(req) * max(elapsed_s, 0.0) * usage_factor
        for acct in self._ancestors(account):
            self.usage[acct.name] = self.usage.get(acct.name, 0.0) + amount
        return amount

    # ----------------------------------------------------------- factors ----
    def norm_shares(self, name: str) -> float:
        """Sibling-relative shares multiplied down from root (sshare col)."""
        assert name in self.accounts, f"unknown account {name!r}"
        frac = 1.0
        for acct in self._ancestors(name):
            if acct.parent is None:
                break
            level = sum(a.shares for a in self.children(acct.parent))
            frac *= acct.shares / max(level, 1)
        return frac

    def norm_usage(self, name: str) -> float:
        total = self.usage.get("root", 0.0)
        if total <= 0:
            return 0.0
        return self.usage.get(name, 0.0) / total

    def fair_share_factor(self, account: str) -> float:
        """The classic SLURM ``2^(-usage/shares)`` in [0, 1]."""
        if account not in self.accounts:
            return 1.0                          # never-seen account: fresh
        shares = self.norm_shares(account)
        if shares <= 0:
            return 0.0
        return 2.0 ** (-self.norm_usage(account) / shares)

    # ---------------------------------------------------------- snapshot ----
    def snapshot(self) -> dict:
        return {
            "half_life_s": self.half_life_s,
            "tres_weights": dict(self.tres_weights),
            "accounts": [(a.name, a.parent, a.shares, a.description)
                         for a in self.accounts.values()],
            "user_account": dict(self.user_account),
            "usage": dict(self.usage),
            "last_decay": self._last_decay,
        }

    @classmethod
    def restore(cls, snap: dict) -> "FairShareTree":
        t = cls(half_life_s=snap["half_life_s"],
                tres_weights=snap["tres_weights"])
        for name, parent, shares, desc in snap["accounts"]:
            if name == "root":
                continue
            t.accounts[name] = Account(name, parent=parent, shares=shares,
                                       description=desc)
        t.user_account = dict(snap["user_account"])
        t.usage = dict(snap["usage"])
        t._last_decay = snap["last_decay"]
        return t


@dataclass(frozen=True)
class PriorityWeights:
    """slurm.conf ``PriorityWeight*`` knobs."""
    age: float = 1_000.0
    fairshare: float = 10_000.0
    job_size: float = 500.0
    partition: float = 1_000.0
    qos: float = 2_000.0
    max_age_s: float = 7 * 86_400.0     # PriorityMaxAge


@dataclass(frozen=True)
class PriorityBreakdown:
    """One sprio row: the weighted components and their sum."""
    job_id: int
    age: float
    fairshare: float
    job_size: float
    partition: float
    qos: float
    nice: float

    @property
    def total(self) -> float:
        return (self.age + self.fairshare + self.job_size + self.partition
                + self.qos + self.nice)


class MultifactorPriority:
    """The priority/multifactor plugin: compose factors into one number."""

    def __init__(self, tree: FairShareTree,
                 qos_table: dict[str, QOS],
                 weights: PriorityWeights = PriorityWeights()):
        self.tree = tree
        self.qos_table = qos_table
        self.weights = weights

    def breakdown(self, job: Job, now: float, partitions: dict,
                  cluster_nodes: int) -> PriorityBreakdown:
        w = self.weights
        age = min(max(now - job.submit_time, 0.0) / w.max_age_s, 1.0)
        fs = self.tree.fair_share_factor(job.account)
        size = job.req.nodes / max(cluster_nodes, 1)
        part = partitions[job.partition].priority_tier if job.partition in \
            partitions else 1
        max_tier = max((p.priority_tier for p in partitions.values()),
                       default=1)
        qos = self.qos_table.get(job.qos)
        max_qos = max((q.priority for q in self.qos_table.values()),
                      default=1) or 1
        return PriorityBreakdown(
            job_id=job.job_id,
            age=w.age * age,
            fairshare=w.fairshare * fs,
            job_size=w.job_size * size,
            partition=w.partition * part / max(max_tier, 1),
            qos=w.qos * (qos.priority / max_qos if qos else 0.0),
            nice=float(job.priority),
        )

    def priority(self, job: Job, now: float, partitions: dict,
                 cluster_nodes: int) -> float:
        return self.breakdown(job, now, partitions, cluster_nodes).total

    def priority_fn(self, now: float, partitions: dict, cluster_nodes: int):
        """A ``job -> priority`` callable for one scheduling pass (the
        fair-share factor is frozen at pass start, like SLURM's decay tick).
        """
        cache: dict[int, float] = {}

        def fn(job: Job) -> float:
            p = cache.get(job.job_id)
            if p is None:
                p = self.priority(job, now, partitions, cluster_nodes)
                cache[job.job_id] = p
            return p
        return fn
