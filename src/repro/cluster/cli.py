"""`python -m repro.cluster.cli` — drive a demo cluster through the guide's
§5 workflow from the shell: provision, validate, submit, watch, account.

A stateful daemon is out of scope for a CI container, so the CLI runs a
scripted session against a fresh software-defined pod — the point is that
every command from the paper's tables (sinfo/squeue/sbatch/srun/scancel/
scontrol/sacct) exists and produces SLURM-shaped output.
"""
from __future__ import annotations

import argparse

from repro.cluster import commands as C
from repro.cluster.provision import provision, tpu_pod_spec, validate


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.cluster")
    ap.add_argument("--hosts", type=int, default=8,
                    help="pod host-grid side (hosts = side^2)")
    ap.add_argument("--demo-jobs", type=int, default=6)
    args = ap.parse_args(argv)

    spec = tpu_pod_spec(hosts_x=args.hosts, hosts_y=args.hosts)
    cluster = provision(spec)
    print(f"== provisioned {spec.name}: {len(spec.hosts)} hosts ==")
    print(validate(cluster, spec))

    print("\n== sinfo ==")
    print(C.sinfo(cluster))

    print("\n== submitting demo jobs ==")
    print(C.sbatch(cluster, name="resnet-train", nodes=4, gres="tpu:4",
                   time="04:00:00", run_time_s=3600))
    print(C.sbatch(cluster, name="llm-pretrain", nodes=16, gres="tpu:4",
                   time="1-00:00:00", run_time_s=86_000, priority=10))
    print(C.sbatch(cluster, name="sweep", nodes=1, gres="tpu:4",
                   time="00:30:00", array=args.demo_jobs, run_time_s=600))
    print(C.sbatch(cluster, name="eval-after", nodes=2, gres="tpu:4",
                   time="01:00:00", dependency="afterok:2", run_time_s=300))

    print("\n== squeue ==")
    print(C.squeue(cluster))

    print("\n== scontrol show job 2 ==")
    print(C.scontrol_show_job(cluster, 2))

    print("\n== draining a node ==")
    print(C.scontrol_update_node(cluster, "tpu-00-00", "drain", "maintenance"))

    stuck = cluster.run()
    print(f"\n== queue drained (clock={cluster.clock:.0f}s, "
          f"stuck={stuck}) ==")

    print("\n== sacct ==")
    print(C.sacct(cluster))

    # ---- multi-tenant: accounts, QOS, fair-share, preemption (§3.2.3) ----
    print(C.scontrol_update_node(cluster, "tpu-00-00", "idle"))
    cluster.run()                       # drain the single-tenant backlog

    print("\n== sacctmgr: two tenants sharing the pod ==")
    print(C.sacctmgr_add_account(cluster, "prod", fairshare=10))
    print(C.sacctmgr_add_account(cluster, "research", fairshare=1))
    C.sacctmgr_add_user(cluster, "alice", "prod")
    C.sacctmgr_add_user(cluster, "bob", "research")
    print(C.sacctmgr_show_qos(cluster))

    print("\n== scavenger fills idle capacity; prod preempts ==")
    print(C.sbatch(cluster, name="bg-sweep", nodes=args.hosts ** 2,
                   gres="tpu:4", time="04:00:00", run_time_s=7200,
                   user="bob", qos="scavenger", ckpt_interval_s=600))
    print(C.sbatch(cluster, name="prod-train", nodes=args.hosts ** 2 // 2,
                   gres="tpu:4", time="02:00:00", run_time_s=1800,
                   user="alice", qos="high"))
    print(C.squeue(cluster))

    print("\n== sprio ==")
    print(C.sprio(cluster))

    cluster.run()
    print(f"\n== drained; {cluster.preemptions_total} preemption(s) ==")
    print("\n== sshare ==")
    print(C.sshare(cluster))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
