"""The SLURM scheduling policy: priority queue + EASY/conservative backfill.

This is the paper's §3.2.3 artifact ("Slurm: scalability, fairness policies")
implemented as a deterministic, property-testable engine:

* **Priority order** — pending jobs sorted by (priority desc, submit FIFO).
* **Backfill** — when the head job can't start, it gets a *reservation* at
  the earliest projected time it fits (from running jobs' expected ends).
  Lower-priority jobs may start out of order only if they cannot delay a
  reservation (finish before it starts, or touch disjoint nodes).
  ``mode="easy"`` reserves for the first blocked job only (SLURM's default
  sched/backfill behaviour); ``mode="conservative"`` reserves for every
  blocked job.
* **TPU contiguity** — allocations must tile a rectangle of hosts in the
  pod's host grid (GPUs don't have this constraint; TPU ICI does).

Pure policy: given cluster state, produce decisions.  The event engine in
``cluster.py`` applies them.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional

from repro.cluster.job import Job, JobState
from repro.cluster.node import Node, NodeState, Partition


@dataclass(frozen=True)
class Reservation:
    job_id: int
    start: float
    nodes: tuple[str, ...]


@dataclass(frozen=True)
class Decision:
    """One scheduling pass outcome."""
    starts: tuple[tuple[int, tuple[str, ...]], ...]  # (job_id, nodes)
    reservations: tuple[Reservation, ...]


def _rect_candidates(nodes: list[Node], count: int):
    """All host-grid rectangles of exactly `count` nodes from `nodes`.

    Nodes without coordinates fall back to arbitrary combinations (non-TPU
    partitions).  Yields tuples of node names.
    """
    coords = {n.coord: n for n in nodes if n.coord is not None}
    if not coords or len(coords) < count:
        if len(nodes) >= count:
            yield tuple(n.name for n in nodes[:count])
        return
    rows = sorted({c[0] for c in coords})
    cols = sorted({c[1] for c in coords})
    # factor pairs h x w == count
    for h in range(1, count + 1):
        if count % h:
            continue
        w = count // h
        for r0 in rows:
            for c0 in cols:
                rect = [(r0 + dr, c0 + dc)
                        for dr in range(h) for dc in range(w)]
                if all(rc in coords for rc in rect):
                    yield tuple(coords[rc].name for rc in rect)


def find_allocation(job: Job, nodes: dict[str, Node],
                    partition: Partition) -> Optional[tuple[str, ...]]:
    """Nodes that can run `job` right now, or None."""
    req = job.req
    avail = [
        nodes[nm] for nm in partition.nodes
        if nodes[nm].fits(req.cpus_per_node, req.mem_mb_per_node,
                          req.gres_per_node)
    ]
    if len(avail) < req.nodes:
        return None
    if req.contiguous:
        for cand in _rect_candidates(avail, req.nodes):
            return cand
        return None
    return tuple(n.name for n in avail[:req.nodes])


def _projected_allocation(job: Job, nodes: dict[str, Node],
                          partition: Partition, running: list[Job],
                          now: float) -> Optional[Reservation]:
    """Earliest-start reservation from projected job-end releases."""
    # replay releases in end-time order on a copy of the free state
    import copy
    shadow = {nm: copy.deepcopy(nodes[nm]) for nm in partition.nodes}
    events = sorted(
        ((j.start_time + j.runtime(), j.job_id, j) for j in running
         if j.start_time is not None),
        key=lambda t: t[:2])          # job_id tiebreak: Jobs don't order
    events = [(when, j) for when, _, j in events]
    # try now, then after each release
    t = now
    for when, ending in itertools.chain([(now, None)], events):
        if ending is not None:
            for nm in ending.nodes_alloc:
                if nm in shadow:
                    shadow[nm].release(
                        ending.job_id, ending.req.cpus_per_node,
                        ending.req.mem_mb_per_node, ending.req.gres_per_node)
            t = when
        alloc = find_allocation(job, shadow, partition)
        if alloc is not None:
            return Reservation(job.job_id, t, alloc)
    return None


def schedule_pass(now: float, pending: list[Job], running: list[Job],
                  nodes: dict[str, Node], partitions: dict[str, Partition],
                  mode: str = "easy") -> Decision:
    """One scheduling cycle.  Mutates nothing; returns the decision."""
    assert mode in ("easy", "conservative", "fifo")
    queue = sorted((j for j in pending if j.state == JobState.PENDING
                    and j.reason != "Dependency"), key=Job.sort_key)
    # partition priority tier outranks job priority (SLURM PriorityTier)
    queue.sort(key=lambda j: -partitions[j.partition].priority_tier)

    starts: list[tuple[int, tuple[str, ...]]] = []
    reservations: list[Reservation] = []
    # working copy of node state so successive starts see earlier ones
    import copy
    work = {nm: copy.deepcopy(n) for nm, n in nodes.items()}
    run_proj = list(running)

    for job in queue:
        part = partitions[job.partition]
        alloc = find_allocation(job, work, part)
        if alloc is not None:
            # backfill guard: starting now must not delay any reservation
            end = now + job.runtime()
            conflict = any(
                end > r.start and set(alloc) & set(r.nodes)
                for r in reservations)
            if not conflict:
                starts.append((job.job_id, alloc))
                for nm in alloc:
                    work[nm].allocate(job.job_id, job.req.cpus_per_node,
                                      job.req.mem_mb_per_node,
                                      job.req.gres_per_node)
                # projected running job for later reservations
                proj = copy.copy(job)
                proj.start_time = now
                proj.nodes_alloc = alloc
                run_proj.append(proj)
                continue
        if mode == "fifo":
            break                       # strict FIFO: head blocks the queue
        if mode == "easy" and reservations:
            continue                    # EASY: only the first blocked job
        res = _projected_allocation(job, work, part, run_proj, now)
        if res is not None:
            reservations.append(res)

    return Decision(tuple(starts), tuple(reservations))
