"""The SLURM scheduling policy: priority queue + EASY/conservative backfill
+ QOS preemption.

This is the paper's §3.2.3 artifact ("Slurm: scalability, fairness policies")
implemented as a deterministic, property-testable engine:

* **Priority order** — pending jobs sorted by a pluggable ``priority_fn``
  (the multifactor fair-share engine in ``fairshare.py``) falling back to
  the static (priority desc, submit FIFO) order.
* **Backfill** — when the head job can't start, it gets a *reservation* at
  the earliest projected time it fits (from running jobs' expected ends).
  Lower-priority jobs may start out of order only if they cannot delay a
  reservation (finish before it starts, or touch disjoint nodes).
  ``mode="easy"`` reserves for the first blocked job only (SLURM's default
  sched/backfill behaviour); ``mode="conservative"`` reserves for every
  blocked job.
* **QOS limits** — a job whose account already holds its QOS's ``GrpTRES``
  cap is held with reason ``QOSGrpResourceLimit``.
* **Preemption** — when a blocked job's QOS lists preemptable tiers, the
  pass selects the cheapest set of lowest-priority running victims whose
  eviction makes room, and emits them in ``Decision.preemptions``.  The
  engine in ``cluster.py`` requeues (or cancels) the victims and re-runs
  the pass so the preemptor starts on the freed nodes.
* **TPU contiguity** — allocations must tile a rectangle of hosts in the
  pod's host grid (GPUs don't have this constraint; TPU ICI does).

Pure policy: given cluster state, produce decisions.  The event engine in
``cluster.py`` applies them.
"""
from __future__ import annotations

import copy
import itertools
from dataclasses import dataclass, replace
from typing import Callable, Optional

from repro.cluster.job import Job, JobState
from repro.cluster.node import Node, NodeState, Partition
from repro.policy import QOS, add_tres, job_tres, tres_within


class ShadowNodes:
    """Copy-on-write working view of the node inventory for one pass.

    Reads pass through to the base mapping; a node is cloned only when a
    tentative placement actually touches it (``mutate``).  A pass that
    starts k small jobs on a 256-node cluster clones k·nodes-per-job
    nodes instead of all 256 — the dirty set, not the inventory, bounds
    the per-pass copy cost.  Layers compose: projected/preemption shadows
    stack another ShadowNodes on top of the pass's working view.
    """
    __slots__ = ("_base", "_names", "_dirty")

    def __init__(self, base, names=None):
        self._base = base                   # dict[str, Node] or ShadowNodes
        self._names = set(names) if names is not None else None
        self._dirty: dict[str, Node] = {}

    def __getitem__(self, name: str) -> Node:
        node = self._dirty.get(name)
        return node if node is not None else self._base[name]

    def __contains__(self, name: str) -> bool:
        if self._names is not None:
            return name in self._names
        return name in self._dirty or name in self._base

    def mutate(self, name: str) -> Node:
        """The node's private clone, created on first touch."""
        node = self._dirty.get(name)
        if node is None:
            node = self._base[name].clone()
            self._dirty[name] = node
        return node

    @property
    def dirty_count(self) -> int:
        return len(self._dirty)


@dataclass(frozen=True)
class Reservation:
    job_id: int
    start: float
    nodes: tuple[str, ...]


@dataclass(frozen=True)
class Preemption:
    """Evict ``victims`` so ``job_id`` can start."""
    job_id: int
    victims: tuple[int, ...]


@dataclass(frozen=True)
class Decision:
    """One scheduling pass outcome."""
    starts: tuple[tuple[int, tuple[str, ...]], ...]  # (job_id, nodes)
    reservations: tuple[Reservation, ...]
    preemptions: tuple[Preemption, ...] = ()
    holds: tuple[tuple[int, str], ...] = ()          # (job_id, reason)


def _rect_candidates(nodes: list[Node], count: int):
    """All host-grid rectangles of exactly `count` nodes from `nodes`.

    Nodes without coordinates fall back to arbitrary combinations (non-TPU
    partitions).  Yields tuples of node names.
    """
    coords = {n.coord: n for n in nodes if n.coord is not None}
    if not coords or len(coords) < count:
        if len(nodes) >= count:
            yield tuple(n.name for n in nodes[:count])
        return
    rows = sorted({c[0] for c in coords})
    cols = sorted({c[1] for c in coords})
    # factor pairs h x w == count
    for h in range(1, count + 1):
        if count % h:
            continue
        w = count // h
        for r0 in rows:
            for c0 in cols:
                rect = [(r0 + dr, c0 + dc)
                        for dr in range(h) for dc in range(w)]
                if all(rc in coords for rc in rect):
                    yield tuple(coords[rc].name for rc in rect)


def find_allocation(job: Job, nodes: dict[str, Node],
                    partition: Partition) -> Optional[tuple[str, ...]]:
    """Nodes that can run `job` right now, or None."""
    req = job.req
    avail = [
        nodes[nm] for nm in partition.nodes
        if nodes[nm].fits(req.cpus_per_node, req.mem_mb_per_node,
                          req.gres_per_node)
    ]
    if len(avail) < req.nodes:
        return None
    if req.contiguous:
        for cand in _rect_candidates(avail, req.nodes):
            return cand
        return None
    return tuple(n.name for n in avail[:req.nodes])


def capacity_probe(nodes: dict[str, Node], partition: Partition,
                   req) -> int:
    """slurm_now-style idle-capacity probe: the largest node count a job
    shaped like ``req`` (per-node cpus/mem/gres, contiguity) could start
    with RIGHT NOW — no queueing, no preemption, no reservations.

    This is the autoscaler's growth signal ("largest scavenger job that
    starts immediately"): it answers *would one more replica start*,
    without submitting anything.  Contiguous requests go through the
    same mesh-rectangle placement real allocation uses, so a probe
    answer of ``n`` is a guarantee, not an estimate."""
    upper = sum(
        1 for nm in partition.nodes
        if nodes[nm].fits(req.cpus_per_node, req.mem_mb_per_node,
                          req.gres_per_node))
    for n in range(upper, 0, -1):
        shaped = replace(req, nodes=n)
        probe = Job(job_id=-1, name="capacity-probe", user="",
                    partition=partition.name, req=shaped)
        if find_allocation(probe, nodes, partition) is not None:
            return n
    return 0


def _projected_allocation(job: Job, nodes: dict[str, Node],
                          partition: Partition, running: list[Job],
                          now: float) -> Optional[Reservation]:
    """Earliest-start reservation from projected job-end releases."""
    # replay releases in end-time order on a copy-on-write shadow
    shadow = ShadowNodes(nodes, names=partition.nodes)
    events = sorted(
        ((j.start_time + j.runtime(), j.job_id, j) for j in running
         if j.start_time is not None),
        key=lambda t: t[:2])          # job_id tiebreak: Jobs don't order
    events = [(when, j) for when, _, j in events]
    # try now, then after each release
    t = now
    for when, ending in itertools.chain([(now, None)], events):
        if ending is not None:
            for nm in ending.nodes_alloc:
                if nm in shadow:
                    shadow.mutate(nm).release(
                        ending.job_id, ending.req.cpus_per_node,
                        ending.req.mem_mb_per_node, ending.req.gres_per_node)
            t = when
        alloc = find_allocation(job, shadow, partition)
        if alloc is not None:
            return Reservation(job.job_id, t, alloc)
    return None


def _preemption_victims(job: Job, work: dict[str, Node],
                        partition: Partition, running: list[Job],
                        qos_table: dict[str, QOS],
                        rank: Callable[[Job], tuple],
                        ) -> Optional[tuple[int, ...]]:
    """Lowest-priority running jobs whose eviction lets ``job`` start.

    Greedy: evict candidates cheapest-first on a shadow state until the
    allocation fits, then drop any victim whose nodes turned out not to be
    needed.  Returns None when no victim set makes room.
    """
    my_qos = qos_table.get(job.qos)
    if my_qos is None or not my_qos.preempt:
        return None
    part_nodes = set(partition.nodes)
    candidates = [r for r in running
                  if my_qos.can_preempt(r.qos)
                  and any(nm in part_nodes for nm in r.nodes_alloc)]
    if not candidates:
        return None
    candidates.sort(key=rank, reverse=True)       # worst-ranked first
    shadow = ShadowNodes(work, names=partition.nodes)
    evicted: list[Job] = []
    for victim in candidates:
        for nm in victim.nodes_alloc:
            if nm in shadow:
                shadow.mutate(nm).release(
                    victim.job_id, victim.req.cpus_per_node,
                    victim.req.mem_mb_per_node, victim.req.gres_per_node)
        evicted.append(victim)
        alloc = find_allocation(job, shadow, partition)
        if alloc is not None:
            needed = set(alloc)
            kept = tuple(v.job_id for v in evicted
                         if needed & set(v.nodes_alloc))
            return kept or tuple(v.job_id for v in evicted[-1:])
    return None


def _grp_tres_usage(running: list[Job]) -> dict[tuple[str, str], dict]:
    """(qos, account) -> aggregate TRES held by running jobs."""
    usage: dict[tuple[str, str], dict] = {}
    for j in running:
        add_tres(usage.setdefault((j.qos, j.account), {}), job_tres(j.req))
    return usage


def schedule_pass(now: float, pending: list[Job], running: list[Job],
                  nodes: dict[str, Node], partitions: dict[str, Partition],
                  mode: str = "easy",
                  priority_fn: Optional[Callable[[Job], float]] = None,
                  qos_table: Optional[dict[str, QOS]] = None,
                  preemption_enabled: bool = True,
                  tracer=None) -> Decision:
    """One scheduling cycle.  Mutates nothing; returns the decision."""
    assert mode in ("easy", "conservative", "fifo")
    qos_table = qos_table or {}
    sp = tracer.begin("schedule_pass", cat="scheduler",
                      track=("cluster:scheduler", "passes"), ts=now,
                      mode=mode, pending=len(pending),
                      running=len(running)) if tracer is not None else None

    def rank(j: Job) -> tuple:
        """Ascending sort => best job first."""
        tier = partitions[j.partition].priority_tier if j.partition in \
            partitions else 0
        if priority_fn is not None:
            return (-tier, -priority_fn(j), j.submit_time, j.job_id)
        return (-tier,) + j.sort_key()

    queue = sorted((j for j in pending if j.state == JobState.PENDING
                    and j.reason != "Dependency"), key=rank)

    starts: list[tuple[int, tuple[str, ...]]] = []
    reservations: list[Reservation] = []
    preemptions: list[Preemption] = []
    holds: list[tuple[int, str]] = []
    # copy-on-write working view so successive starts see earlier ones
    # without cloning the whole inventory (dirty-set incremental clone)
    work = ShadowNodes(nodes)
    run_proj = list(running)
    grp_usage = _grp_tres_usage(running)

    for job in queue:
        part = partitions[job.partition]
        qos = qos_table.get(job.qos)
        my_tres = job_tres(job.req)
        if qos is not None and qos.grp_tres:
            held = grp_usage.get((job.qos, job.account), {})
            if not tres_within(held, my_tres, qos.grp_tres):
                holds.append((job.job_id, "QOSGrpResourceLimit"))
                continue                 # held: never backfills or preempts
        alloc = find_allocation(job, work, part)
        if alloc is not None:
            # backfill guard: starting now must not delay any reservation
            end = now + job.runtime()
            conflict = any(
                end > r.start and set(alloc) & set(r.nodes)
                for r in reservations)
            if not conflict:
                starts.append((job.job_id, alloc))
                for nm in alloc:
                    work.mutate(nm).allocate(
                        job.job_id, job.req.cpus_per_node,
                        job.req.mem_mb_per_node, job.req.gres_per_node)
                add_tres(grp_usage.setdefault((job.qos, job.account), {}),
                         my_tres)
                # projected running job for later reservations
                proj = copy.copy(job)
                proj.start_time = now
                proj.nodes_alloc = alloc
                run_proj.append(proj)
                continue
        if (preemption_enabled and not preemptions
                and qos is not None and qos.preempt):
            victims = _preemption_victims(job, work, part, running,
                                          qos_table, rank)
            if victims:
                preemptions.append(Preemption(job.job_id, victims))
                continue            # engine applies eviction + new pass
        if mode == "fifo":
            break                       # strict FIFO: head blocks the queue
        if mode == "easy" and reservations:
            continue                    # EASY: only the first blocked job
        res = _projected_allocation(job, work, part, run_proj, now)
        if res is not None:
            reservations.append(res)

    decision = Decision(tuple(starts), tuple(reservations),
                        tuple(preemptions), tuple(holds))
    if sp is not None:
        # virtual-clock spans are zero-length on the timeline; the
        # decision counts ride along as attributes
        tracer.end(sp, ts=now, starts=len(starts),
                   reservations=len(reservations),
                   preemptions=len(preemptions), holds=len(holds))
    return decision
