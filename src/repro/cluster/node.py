"""Software-defined cluster inventory: nodes, GRES, partitions.

Adaptation note (DESIGN.md): the paper's node is a Linux host with 1–8
GPUs (``gres/gpu:N``); ours is a TPU host with 4 chips (``gres/tpu:4``).
Everything else — state machine, CPU/memory accounting, partitions with
priority tiers and time limits — is SLURM semantics kept intact.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Optional


class NodeState(enum.Enum):
    IDLE = "idle"
    MIXED = "mixed"          # partially allocated
    ALLOCATED = "alloc"
    DOWN = "down"
    DRAIN = "drain"          # no new jobs; running jobs finish

    @property
    def schedulable(self) -> bool:
        return self in (NodeState.IDLE, NodeState.MIXED)


@dataclass
class Node:
    """One compute host.  GRES follows SLURM's ``name:count`` model."""
    name: str
    cpus: int = 16
    mem_mb: int = 131_072
    gres: dict = field(default_factory=lambda: {"tpu": 4})
    features: tuple[str, ...] = ()          # e.g. ("v5e", "ici")
    # TPU topology coordinates within the pod mesh (row, col of the 4-chip
    # host in the 16x16 chip grid).  GPUs don't have this constraint; TPUs do
    # — allocations must form contiguous sub-rectangles.
    coord: Optional[tuple[int, int]] = None
    state: NodeState = NodeState.IDLE
    reason: str = ""

    # live accounting
    alloc_cpus: int = 0
    alloc_mem_mb: int = 0
    alloc_gres: dict = field(default_factory=dict)
    running_jobs: set = field(default_factory=set)

    def clone(self) -> "Node":
        """Cheap scheduling-shadow copy: shares the immutable inventory
        (name/cpus/mem/gres/coord/features) and copies only the mutable
        allocation state.  ~10x faster than ``copy.deepcopy`` for the
        per-pass working sets the scheduler builds."""
        c = Node.__new__(Node)
        c.name = self.name
        c.cpus = self.cpus
        c.mem_mb = self.mem_mb
        c.gres = self.gres                  # never mutated after provisioning
        c.features = self.features
        c.coord = self.coord
        c.state = self.state
        c.reason = self.reason
        c.alloc_cpus = self.alloc_cpus
        c.alloc_mem_mb = self.alloc_mem_mb
        c.alloc_gres = dict(self.alloc_gres)
        c.running_jobs = set(self.running_jobs)
        return c

    # ---- capacity queries ----
    def free_cpus(self) -> int:
        return self.cpus - self.alloc_cpus

    def free_mem_mb(self) -> int:
        return self.mem_mb - self.alloc_mem_mb

    def free_gres(self, name: str) -> int:
        return self.gres.get(name, 0) - self.alloc_gres.get(name, 0)

    def fits(self, cpus: int, mem_mb: int, gres: dict) -> bool:
        if not self.state.schedulable:
            return False
        if cpus > self.free_cpus() or mem_mb > self.free_mem_mb():
            return False
        return all(self.free_gres(g) >= n for g, n in gres.items())

    # ---- allocation bookkeeping ----
    def allocate(self, job_id: int, cpus: int, mem_mb: int, gres: dict):
        assert self.fits(cpus, mem_mb, gres), (self.name, job_id)
        self.alloc_cpus += cpus
        self.alloc_mem_mb += mem_mb
        for g, n in gres.items():
            self.alloc_gres[g] = self.alloc_gres.get(g, 0) + n
        self.running_jobs.add(job_id)
        self._refresh_state()

    def release(self, job_id: int, cpus: int, mem_mb: int, gres: dict):
        self.alloc_cpus -= cpus
        self.alloc_mem_mb -= mem_mb
        for g, n in gres.items():
            self.alloc_gres[g] = self.alloc_gres.get(g, 0) - n
        self.running_jobs.discard(job_id)
        self._refresh_state()

    def _refresh_state(self):
        if self.state in (NodeState.DOWN, NodeState.DRAIN):
            return
        if self.alloc_cpus == 0 and not any(self.alloc_gres.values()):
            self.state = NodeState.IDLE
        elif self.free_cpus() == 0 or all(
                self.free_gres(g) == 0 for g in self.gres):
            self.state = NodeState.ALLOCATED
        else:
            self.state = NodeState.MIXED

    def set_state(self, state: NodeState, reason: str = ""):
        self.state = state
        self.reason = reason
        if state not in (NodeState.DOWN, NodeState.DRAIN):
            self._refresh_state()


@dataclass(frozen=True)
class Partition:
    """SLURM partition: a named group of nodes with policy attached."""
    name: str
    nodes: tuple[str, ...]
    max_time_s: int = 24 * 3600
    priority_tier: int = 1          # higher tier preempts queue order
    default: bool = False
