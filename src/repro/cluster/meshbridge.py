"""Allocation -> jax.Mesh bridge: the point where the SLURM layer hands a
chip grid to the JAX layer.

A job allocated N hosts x 4 chips owns a contiguous chip rectangle (the
scheduler enforces host-rect contiguity).  This module maps that rectangle
onto however many JAX devices actually exist in the process:

* real deployment — one process per host, `jax.devices()` = the job's chips;
* this container — CPU devices (1, or 512 under the dry-run XLA flag), and
  the bridge folds the logical (data, model) mesh onto them.

The mesh axes follow DESIGN.md: ``("data", "model")`` within a pod,
``("pod", "data", "model")`` across pods.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh
try:                                    # jax >= 0.5.0 only
    from jax.sharding import AxisType
except ImportError:
    AxisType = None

from repro.cluster.cluster import Cluster
from repro.cluster.job import Job


def allocation_chip_count(cluster: Cluster, job: Job,
                          gres_name: str = "tpu") -> int:
    return sum(cluster.nodes[nm].gres.get(gres_name, 0)
               for nm in job.nodes_alloc)


def factor_mesh(n_chips: int, model_parallel: int) -> tuple[int, int]:
    """(data, model) shape for n_chips total."""
    model = math.gcd(model_parallel, n_chips)
    return n_chips // model, model


def mesh_for_job(cluster: Cluster, job: Job, model_parallel: int = 1,
                 devices=None) -> Mesh:
    """Build the (data, model) mesh for a running job's allocation."""
    assert job.nodes_alloc, f"job {job.job_id} has no allocation"
    n_chips = allocation_chip_count(cluster, job)
    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) < n_chips:
        # container fallback: fold the logical mesh onto available devices
        n_chips = max(1, (len(devices) // 1))
        n_chips = 2 ** int(math.log2(n_chips))
    data, model = factor_mesh(n_chips, model_parallel)
    dev = np.asarray(devices[:data * model]).reshape(data, model)
    if AxisType is None:
        return Mesh(dev, ("data", "model"))
    return Mesh(dev, ("data", "model"),
                axis_types=(AxisType.Auto, AxisType.Auto))
