"""Qwen1.5/2-MoE-A2.7B — 4 shared + 60 routed experts, top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B] 24L d_model=2048 16H (GQA kv=16) d_ff=1408
(per-expert) vocab=151936, MoE 60e top-4 + 4 shared experts every layer.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,                              # FFN is MoE in every layer
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, num_shared=4, d_ff=1408, every=1),
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        head_dim=64, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, d_ff=128, every=1,
                      group_size=64),
    )
