"""repro-100m — the guide's own workload: a ~100M-parameter dense GQA
transformer sized for the end-to-end CPU training example (examples/
train_cluster.py).  Not part of the assigned-architecture pool.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="repro-100m",
    family="dense",
    source="this repo (examples driver)",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab_size=32_000,
    mlp_type="swiglu",
)


def reduced() -> ModelConfig:
    return replace(CONFIG, num_layers=2, d_model=256, num_heads=4,
                   num_kv_heads=2, vocab_size=512, d_ff=1024)
