from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    LONG_CONTEXT_WINDOW,
    InputShape,
    ModelConfig,
    MoEConfig,
    RunConfig,
    SSMConfig,
    default_run_config,
    get_config,
    get_reduced_config,
    shape_for,
)

__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "LONG_CONTEXT_WINDOW", "InputShape",
    "ModelConfig", "MoEConfig", "RunConfig", "SSMConfig",
    "default_run_config", "get_config", "get_reduced_config", "shape_for",
]
