"""StableLM-3B — dense MHA. [hf:stabilityai/stablelm-2-1_6b family]

32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        head_dim=64, d_ff=512, vocab_size=512,
    )
