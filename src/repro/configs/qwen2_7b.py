"""Qwen2-7B — dense, GQA kv=4, QKV bias. [arXiv:2407.10671]

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
    )
