"""MusicGen-Large — decoder-only over EnCodec tokens. [arXiv:2306.05284]

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048 (per codebook).
The EnCodec conv codec frontend is STUBBED per the assignment carve-out:
``input_specs()`` provides precomputed frame embeddings (delay-pattern
codebook embeddings already summed).  Non-gated GELU MLP, sinusoidal
positions (adapted: implemented alongside RoPE, selected by config).
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    pos_embedding="sinusoidal",
    frontend="audio",
    num_codebooks=4,
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        head_dim=64, d_ff=512, vocab_size=512,
    )
