"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Jamba block: 8 layers, 1 attention : 7 mamba; MoE FFN every other layer.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_every=8,                       # 1 attn : 7 mamba
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576, every=2),
    ssm=SSMConfig(state=16, head_dim=64, expand=2, conv_width=4),
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        attn_every=2,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=512, every=2, group_size=64),
        ssm=SSMConfig(state=16, head_dim=32, expand=2, conv_width=4, chunk=32),
    )
