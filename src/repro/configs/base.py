"""Config system: model architecture + input shape + run (parallelism) configs.

Every assigned architecture gets one ``<arch>.py`` in this package exporting
``CONFIG: ModelConfig`` with the exact published dimensions, plus a
``reduced()`` variant used by CPU smoke tests (2 layers, d_model<=512,
<=4 experts — same family, same code paths).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    top_k: int = 0
    num_shared: int = 0           # always-on shared experts (qwen2-moe)
    d_ff: int = 0                 # per-expert hidden dim
    every: int = 1                # MoE FFN every `every` layers (others dense)
    capacity_factor: float = 1.25
    group_size: int = 1024        # GShard dispatch group size (tokens)
    router_z_coef: float = 1e-3   # router z-loss
    balance_coef: float = 1e-2    # load-balance aux loss


@dataclass(frozen=True)
class SSMConfig:
    state: int = 128              # N — SSD state size
    head_dim: int = 64            # P — channels per SSD head
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    source: str                   # citation from the assignment table
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int                     # dense FFN hidden dim (0 if pure-MoE FFN)
    vocab_size: int
    head_dim: int = 128
    # attention details
    rope_theta: float = 1e4
    qkv_bias: bool = False
    pos_embedding: str = "rope"   # rope | sinusoidal
    sliding_window: Optional[int] = None  # None = full causal
    # FFN
    mlp_type: str = "swiglu"      # swiglu | gelu | relu2
    # mixer schedule (hybrid): 1 attention layer per `attn_every` layers,
    # the rest SSM.  attn_every=1 => all attention; 0 => attention-free.
    attn_every: int = 1
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # modality frontend stub: none | vision | audio
    frontend: str = "none"
    num_codebooks: int = 1        # audio (EnCodec streams)
    # numerics
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"       # compute dtype
    param_dtype: str = "float32"  # storage dtype
    tie_embeddings: bool = False

    # ---- derived ----
    def layer_kinds(self) -> list[str]:
        """Mixer kind per layer: 'attn' or 'ssm'."""
        if self.attn_every == 0:
            return ["ssm"] * self.num_layers
        kinds = []
        for i in range(self.num_layers):
            kinds.append("attn" if i % self.attn_every == 0 else "ssm")
        return kinds

    def ffn_kinds(self) -> list[str]:
        """FFN kind per layer: 'dense' or 'moe'."""
        if self.moe is None:
            return ["dense"] * self.num_layers
        return [
            "moe" if (i % self.moe.every == self.moe.every - 1) else "dense"
            for i in range(self.num_layers)
        ]

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return replace(self, sliding_window=window)

    def param_count(self) -> int:
        """Total parameter count (exact, mirrors the spec tree)."""
        from repro.models.spec import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.spec import count_params
        return count_params(self, active_only=True)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Sliding-window size used for the long_500k variant of full-attention archs.
LONG_CONTEXT_WINDOW = 8_192


@dataclass(frozen=True)
class RunConfig:
    """Parallelism + execution knobs for one (arch x shape x mesh) run."""
    strategy: str = "fsdp_tp"     # dp | tp | fsdp | fsdp_tp | pp
    zero_stage: int = 3           # 1 | 2 | 3 (ZeRO partitioning depth)
    microbatches: int = 1         # gradient-accumulation microbatches
    remat: str = "layer"          # none | layer | full
    opt_state_dtype: str = "float32"
    use_pallas: bool = False      # Pallas kernels (TPU / interpret only)
    seq_shard_decode: bool = False  # shard decode KV cache along sequence
    # Beyond-paper (§Perf): when attention heads don't divide the model
    # axis, shard the SEQUENCE dim of activations over `model` instead of
    # replicating attention compute (context/sequence parallelism).  KV is
    # small under GQA, so the per-layer K/V all-gather is cheap against a
    # model_axis-fold compute replication.
    seq_parallel: bool = False
    # Beyond-paper (§Perf): cast f32 master params to bf16 BEFORE the
    # ZeRO-3 all-gather (halves FSDP gather bytes; grads still f32 at the
    # optimizer).
    gather_bf16: bool = False
    # Beyond-paper (§Perf): with TP-inside-expert (experts % model != 0),
    # don't pin the expert output to full d_model — let the w2 partial
    # sums flow through the (linear) combine einsum so the all-reduce
    # lands on the (G, gs, d) tokens instead of the ~5x larger
    # (G, E, C, d) capacity tensor.
    moe_defer_combine: bool = False
    # Beyond-paper (§Perf): cross-data gradient reductions in bf16 (the
    # local f32 accumulator is unchanged) — halves the per-microbatch
    # weight-grad all-reduce, the dominant collective on MoE trains.
    grad_reduce_bf16: bool = False
    # Unroll layer-group and microbatch loops into straight-line HLO.
    # Production keeps scans (flat compile time); the dry-run cost probes
    # unroll because XLA's cost_analysis counts a while body ONCE — see
    # launch/dryrun.py probe machinery.
    unroll: bool = False


ARCH_IDS = [
    "jamba-1.5-large-398b",
    "starcoder2-3b",
    "pixtral-12b",
    "qwen2-moe-a2.7b",
    "musicgen-large",
    "qwen2-7b",
    "stablelm-3b",
    "mamba2-780m",
    "dbrx-132b",
    "minitron-4b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def get_reduced_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.reduced()


def default_run_config(cfg: ModelConfig, shape: InputShape,
                       batch_divisor: int = 32) -> RunConfig:
    """Sensible production defaults per (arch, shape).

    ``batch_divisor`` = product of batch-carrying mesh axes (pod*data); the
    per-microbatch batch must stay divisible by it so the batch dim shards
    cleanly at every microbatch step.
    """
    micro = 1
    if shape.kind == "train":
        # keep per-device live activations ~ few GB: scale microbatches with
        # d_model * layers (see DESIGN.md memory napkin math).
        cost = cfg.d_model * cfg.num_layers
        if cost >= 400_000:
            micro = 16
        elif cost >= 150_000:
            micro = 8
        elif cost >= 64_000:
            micro = 4
        else:
            micro = 2
        micro = max(1, min(micro, shape.global_batch // batch_divisor))
    opt_dtype = "bfloat16" if cfg.param_count() > 100e9 else "float32"
    return RunConfig(microbatches=micro, opt_state_dtype=opt_dtype)


def shape_for(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt an arch config to an input shape (long-context window)."""
    if shape.name == "long_500k" and cfg.attn_every != 0:
        # sub-quadratic requirement: dense/hybrid archs use sliding window.
        if cfg.sliding_window is None or cfg.sliding_window > LONG_CONTEXT_WINDOW:
            return cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg
