"""Pixtral-12B — VLM: pixtral-ViT frontend (STUB) + mistral-nemo decoder.

[hf:mistralai/Pixtral-12B-2409] 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, head_dim=128 (explicit, != d_model/H — Nemo convention).
The vision encoder + projector are stubbed per the assignment carve-out:
``input_specs()`` provides precomputed patch embeddings of shape
(batch, num_patches, d_model) consumed as a prefix.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    frontend="vision",
    rope_theta=1e6,
)

# patch-embedding prefix length provided by the stub frontend
NUM_PATCHES = 256


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
    )
