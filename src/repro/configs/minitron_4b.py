"""Minitron-4B — pruned Nemotron. [arXiv:2407.14679]

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.  Squared-ReLU MLP
(Nemotron convention), huge vocabulary (sharded on `model`).
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    source="arXiv:2407.14679",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    mlp_type="relu2",
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
    )
