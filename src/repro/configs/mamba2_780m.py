"""Mamba2-780M — attention-free SSD (state-space duality). [arXiv:2405.21060]

48L d_model=1536, d_inner=3072, ssm_state=128, headdim=64 (48 SSD heads),
vocab=50280.  ``long_500k`` runs natively (O(1) recurrent decode state).
"""
from dataclasses import replace

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                          # mamba blocks have no separate FFN
    vocab_size=50280,
    attn_every=0,                    # attention-free
    ssm=SSMConfig(state=128, head_dim=64, expand=2, conv_width=4),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, vocab_size=512,
        ssm=SSMConfig(state=32, head_dim=32, expand=2, conv_width=4, chunk=32),
    )
