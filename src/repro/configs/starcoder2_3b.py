"""StarCoder2-3B — dense, GQA kv=2, RoPE. [arXiv:2402.19173]

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.  Non-gated GELU MLP.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    mlp_type="gelu",
    qkv_bias=True,
    rope_theta=1e5,
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
    )
