"""DBRX-132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert) vocab=100352.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,                              # FFN is MoE in every layer
    vocab_size=100352,
    rope_theta=5e5,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff=10752, every=1),
)


def reduced() -> ModelConfig:
    return replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=256, every=1, group_size=64),
    )
