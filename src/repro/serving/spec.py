"""Draft sources for speculative decoding.

The engine's speculative step needs, per live slot, up to ``k`` proposed
next tokens.  Two sources implement one small interface:

  * :class:`NgramDraftSource` — prompt-lookup drafts: match the slot's
    current suffix against (a) its *own* context (prompt + tokens emitted
    so far) and (b) a cross-request :class:`NgramIndex` fed with finished
    requests' sequences (the radix prefix index tells us *pages* repeat;
    this tells us *continuations* repeat).  Free — no extra model, no
    extra dispatches — and very effective on repeat-heavy workloads.
  * :class:`ModelDraftSource` — a tiny autoregressive draft model (a
    shrunk config from :func:`draft_config`) with its own dense KV cache,
    advanced with the same ``decode_n`` scan as the target.

Acceptance lives here too: :func:`greedy_accept` (longest agreeing run —
output bit-identical to non-speculative greedy decode) and
:func:`rejection_sample` (accept token *i* with probability
``min(1, p_target(d_i)/q_draft(d_i))``, resample from the normalized
residual on first rejection — exactly distribution-preserving; with the
point-mass drafts produced here ``q(d_i) = 1`` so the accept probability
is simply ``p_target(d_i)``).

The interface (duck-typed; the engine calls only these):

  begin(slot, ctx)        slot admitted/resumed with token context ``ctx``
                          (prompt + any tokens generated so far, including
                          the last sampled token)
  draft(slot, k)          -> np.int32 array of up to ``k`` proposals
                          (may be shorter, may be empty — the engine
                          falls back to plain decode for empty drafts)
  advance(slot, emitted)  tokens actually emitted this round (accepted
                          run + correction/bonus), in order
  release(slot)           slot vacated (finish, eviction, requeue)
  observe(tokens)         a finished request's full sequence, for
                          cross-request indices
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "greedy_accept",
    "rejection_sample",
    "NgramIndex",
    "NgramDraftSource",
    "ModelDraftSource",
    "draft_config",
]


# ------------------------------------------------------------ acceptance ----

def greedy_accept(targets, drafts):
    """Longest agreeing run under greedy decoding.

    ``targets`` is the target model's greedy pick at each verify row
    (length ``k+1``: row ``j`` predicts the token after draft ``j-1``);
    ``drafts`` the ``k`` proposals.  Returns the emitted tokens:
    accepted drafts plus the target's own next token (the correction
    where the first disagreement happened, or the bonus token when every
    draft agreed) — always at least one token, and bit-identical to
    running the target one token at a time.
    """
    targets = np.asarray(targets)
    drafts = np.asarray(drafts)
    m = 0
    while m < len(drafts) and int(targets[m]) == int(drafts[m]):
        m += 1
    return targets[: m + 1].astype(np.int32)


def rejection_sample(rng, probs, drafts):
    """Distribution-preserving acceptance under temperature sampling.

    ``probs`` is the target model's per-row probability vector (already
    temperature-scaled softmax, shape ``(k+1, V)`` float); ``drafts``
    the ``k`` point-mass proposals.  Token ``j`` is accepted with
    probability ``p[j][d_j]`` (the ``min(1, p/q)`` rule with ``q`` a
    point mass); on the first rejection we resample from the residual
    ``p[j]`` with ``d_j`` removed and renormalized, and stop.  If every
    draft is accepted, a bonus token is drawn from the final row.  The
    marginal distribution of each emitted token is exactly the target
    model's — speculation changes speed, not outputs.
    """
    probs = np.asarray(probs, dtype=np.float64)
    emitted = []
    for j, d in enumerate(np.asarray(drafts)):
        d = int(d)
        if rng.random() < probs[j, d]:
            emitted.append(d)
            continue
        residual = probs[j].copy()
        residual[d] = 0.0
        z = residual.sum()
        if z <= 0.0:                       # degenerate row: p was a point
            residual = np.full_like(residual, 1.0 / len(residual))
        else:
            residual /= z
        emitted.append(int(rng.choice(len(residual), p=residual)))
        return np.asarray(emitted, np.int32)
    row = probs[len(drafts)]
    row = row / row.sum()
    emitted.append(int(rng.choice(len(row), p=row)))
    return np.asarray(emitted, np.int32)


# --------------------------------------------------------- n-gram source ----

class NgramIndex:
    """Cross-request suffix -> continuation map, fed at request finish.

    Last-writer-wins per suffix tuple, capacity-bounded with oldest-entry
    eviction (dict insertion order doubles as the LRU list — a refreshed
    key is deleted and re-inserted so it moves to the back).
    """

    def __init__(self, orders=(3, 2), max_continuation: int = 16,
                 capacity: int = 4096):
        self.orders = tuple(sorted(orders, reverse=True))
        self.max_continuation = max_continuation
        self.capacity = capacity
        self._map: dict = {}

    def __len__(self):
        return len(self._map)

    def observe(self, tokens) -> None:
        toks = np.asarray(tokens, np.int32)
        for o in self.orders:
            for i in range(len(toks) - o):
                gram = tuple(int(t) for t in toks[i:i + o])
                cont = toks[i + o:i + o + self.max_continuation].copy()
                if len(cont) == 0:
                    continue
                self._map.pop((o, gram), None)
                self._map[(o, gram)] = cont
        while len(self._map) > self.capacity:
            self._map.pop(next(iter(self._map)))

    def lookup(self, suffix):
        """Longest-order match of ``suffix`` (a token sequence); returns
        the stored continuation (np.int32 array) or None."""
        suffix = [int(t) for t in suffix]
        for o in self.orders:
            if len(suffix) < o:
                continue
            hit = self._map.get((o, tuple(suffix[-o:])))
            if hit is not None:
                return hit
        return None


class _SlotNgrams:
    """Per-slot own-context n-gram maps, built incrementally.

    Values are ``(latest, prev)`` end positions (index just past the
    gram).  The gram formed by the context's own tail always matches
    itself at ``latest == len(ctx)`` — a useless self-match — so lookups
    fall back to ``prev`` in that case.
    """

    def __init__(self, orders, ctx):
        self.orders = orders
        self.ctx = [int(t) for t in np.asarray(ctx).ravel()]
        self.maps = {o: {} for o in orders}
        for t in range(len(self.ctx)):
            self._index_at(t)

    def _index_at(self, t):
        for o in self.orders:
            if t + 1 < o:
                continue
            gram = tuple(self.ctx[t + 1 - o:t + 1])
            m = self.maps[o]
            old = m.get(gram)
            m[gram] = (t + 1, old[0] if old else None)

    def append(self, tokens):
        for t in np.asarray(tokens).ravel():
            self.ctx.append(int(t))
            self._index_at(len(self.ctx) - 1)

    def match(self, k):
        n = len(self.ctx)
        for o in self.orders:
            if n < o:
                continue
            hit = self.maps[o].get(tuple(self.ctx[-o:]))
            if hit is None:
                continue
            latest, prev = hit
            j = prev if latest >= n else latest
            if j is None:
                continue
            cont = self.ctx[j:j + k]
            if cont:
                return np.asarray(cont, np.int32)
        return None


class NgramDraftSource:
    """Prompt-lookup drafting: own context first, shared index second."""

    kind = "ngram"

    def __init__(self, orders=(3, 2), index: NgramIndex | None = None):
        self.orders = tuple(sorted(orders, reverse=True))
        self.index = index if index is not None else NgramIndex(self.orders)
        self._slots: dict[int, _SlotNgrams] = {}

    def begin(self, slot, ctx):
        self._slots[slot] = _SlotNgrams(self.orders, ctx)

    def release(self, slot):
        self._slots.pop(slot, None)

    def advance(self, slot, emitted):
        st = self._slots.get(slot)
        if st is not None:
            st.append(emitted)

    def observe(self, tokens):
        self.index.observe(tokens)

    def draft(self, slot, k):
        st = self._slots.get(slot)
        if st is None:
            return np.zeros((0,), np.int32)
        cont = st.match(k)
        if cont is None:
            hit = self.index.lookup(st.ctx)
            cont = None if hit is None else hit[:k]
        if cont is None:
            return np.zeros((0,), np.int32)
        return np.asarray(cont[:k], np.int32)


# ---------------------------------------------------- draft-model source ----

def draft_config(cfg):
    """A tiny dense config sharing the target's vocabulary/tokenization —
    1 layer, 128-wide — cheap enough that k draft steps cost a fraction
    of one target step."""
    return dataclasses.replace(
        cfg,
        name=f"{cfg.name}-draft",
        family="dense",
        num_layers=1,
        d_model=128,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        sliding_window=None,
        attn_every=1,
        moe=None,
        ssm=None,
        frontend="none",
    )


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class ModelDraftSource:
    """A small autoregressive draft model with its own dense KV cache.

    The cache is ``(num_slots, cache_len)`` dense; per slot we track the
    position of the last emitted token, whose KV line is written by the
    *next* draft dispatch (same convention as the engine's decode loop).
    Drafting runs the shared ``decode_n`` scan with every other slot
    masked done — frozen slots re-feed their last (token, pos)
    deterministically, so their rows stay bit-stable.

    One wrinkle: when a round is fully accepted (all ``k`` drafts plus
    the bonus token), the line for draft ``k-1`` was never written by
    the k-step scan (it only *fed* drafts ``0..k-2``).  We record a
    per-slot ``pending`` token and catch up with a single extra
    decode_step before the next draft; rounds with any rejection need no
    catch-up because the next scan overwrites the dead lines before
    reading them.
    """

    kind = "model"

    def __init__(self, cfg, num_slots, cache_len, seed=0, params=None,
                 run=None):
        import jax
        import jax.numpy as jnp
        from ..configs import RunConfig
        from ..models import init_params, init_cache
        from ..models.model import decode_step, prefill

        self.cfg = cfg
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.run = run if run is not None else RunConfig(remat="none")
        self.params = (params if params is not None
                       else init_params(cfg, seed))
        self.cache = init_cache(cfg, num_slots, cache_len)
        self.pos = np.zeros(num_slots, np.int32)
        self.last = np.zeros(num_slots, np.int32)
        self.live = np.zeros(num_slots, bool)
        self.pending = {}            # slot -> token needing a catch-up step

        self._jnp, self._jax = jnp, jax
        self._prefill = jax.jit(
            lambda p, t: prefill(p, {"tokens": t}, cfg, self.run),
            static_argnums=())
        self._insert = jax.jit(self._insert_impl, static_argnums=(2,),
                               donate_argnums=(0,))
        self._catchup = jax.jit(
            lambda p, c, t, q: decode_step(p, c, t, q, cfg, self.run)[1],
            donate_argnums=(1,))
        self._decode_cache = {}      # k -> jitted draft scan
        self._key = jax.random.PRNGKey(seed)

    # cache row insertion: one prefilled row -> slot's row in the pool
    def _insert_impl(self, big, one, slot):
        jnp = self._jnp

        def put(big_leaf, one_leaf):
            row = one_leaf[:, 0]
            S = row.shape[1]
            if S < self.cache_len:
                pad = [(0, 0)] * row.ndim
                pad[1] = (0, self.cache_len - S)
                row = jnp.pad(row, pad)
            else:
                row = row[:, :self.cache_len]
            return big_leaf.at[:, slot].set(row.astype(big_leaf.dtype))

        return {"layers": [
            {kk: put(big["layers"][li][kk], one["layers"][li][kk])
             for kk in big["layers"][li]}
            for li in range(len(big["layers"]))]}

    def _draft_fn(self, k):
        fn = self._decode_cache.get(k)
        if fn is None:
            jnp = self._jnp
            from ..models.model import decode_n

            def run(p, c, t, q, d, key):
                toks, c, *_ = decode_n(
                    p, c, t, q,
                    jnp.full((self.num_slots,), 1 << 20, jnp.int32), d,
                    jnp.full((self.num_slots,), -1, jnp.int32),
                    jnp.zeros((self.num_slots,), jnp.float32), key,
                    self.cfg, self.run, k, self.cache_len)
                return toks, c

            fn = self._jax.jit(run, donate_argnums=(1,))
            self._decode_cache[k] = fn
        return fn

    # ------------------------------------------------------------ hooks ----
    def begin(self, slot, ctx):
        jnp = self._jnp
        ctx = np.asarray(ctx, np.int32).ravel()
        assert len(ctx) >= 1
        self.pending.pop(slot, None)
        if len(ctx) > 1:
            # a context longer than the draft cache keeps only its tail,
            # re-based at position 0 — the draft's positions are private
            prior = ctx[:-1][-(self.cache_len - 2):]
            b = _bucket(len(prior))
            padded = np.zeros((1, b), np.int32)
            padded[0, :len(prior)] = prior
            # pad lines past the real suffix are masked by pos until each
            # is overwritten in place by a later draft step
            _, one = self._prefill(self.params, jnp.asarray(padded))
            self.cache = self._insert(self.cache, one, int(slot))
            self.pos[slot] = len(prior)
        else:
            self.pos[slot] = 0
        self.last[slot] = ctx[-1]
        self.live[slot] = True

    def release(self, slot):
        self.live[slot] = False
        self.pending.pop(slot, None)

    def advance(self, slot, emitted):
        emitted = np.asarray(emitted, np.int32).ravel()
        if not self.live[slot] or len(emitted) == 0:
            return
        new_pos = int(self.pos[slot]) + len(emitted)
        if new_pos >= self.cache_len - 1:
            # out of draft-cache room: stop drafting for this slot (the
            # engine falls back to plain decode on empty drafts)
            self.live[slot] = False
            return
        self.pos[slot] = new_pos
        self.last[slot] = emitted[-1]

    def observe(self, tokens):
        pass

    def set_pending(self, slot, token):
        """The round was fully accepted: draft ``k-1``'s KV line was
        never written — feed it once before the next draft."""
        self.pending[slot] = int(token)

    def draft(self, slot, k):
        jnp = self._jnp
        if (not self.live[slot] or k <= 0
                or int(self.pos[slot]) + k + 2 >= self.cache_len):
            return np.zeros((0,), np.int32)
        tok = self.pending.pop(slot, None)
        if tok is not None:
            # other rows re-feed (last, pos) — the value their own next
            # draft would write there anyway, so they stay consistent
            t = np.zeros((self.num_slots, 1), np.int32)
            q = self.pos.copy()
            t[:, 0] = self.last
            t[slot, 0] = tok
            q[slot] = self.pos[slot] - 1
            self.cache = self._catchup(self.params, self.cache,
                                       jnp.asarray(t), jnp.asarray(q))
        done = np.ones(self.num_slots, bool)
        done[slot] = False
        self._key, sub = self._jax.random.split(self._key)
        toks, self.cache = self._draft_fn(k)(
            self.params, self.cache, jnp.asarray(self.last),
            jnp.asarray(self.pos), jnp.asarray(done), sub)
        return np.asarray(toks)[slot].astype(np.int32)
