"""Serve-step builder: the decode analogue of training.train_step.

``decode_32k`` / ``long_500k`` dry-run shapes lower exactly this function —
ONE new token against a seq_len KV cache.  Shardings follow
core.sharding.cache_shardings (batch over data axes, heads over model;
at global_batch=1 the state shards over `model` only).

``make_fused_serve_step`` is the device-resident fast-path twin: the same
shardings around ``models.model.decode_n`` (N tokens per dispatch, fused
sampling + stop masking), so the fused signature the serving engine runs
can be lowered/cost-analyzed by the dry-run machinery too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import InputShape, ModelConfig, RunConfig
from repro.core import sharding as shd
from repro.core.actshard import activation_sharding
from repro.models import abstract_params, init_cache
from repro.models.model import decode_n, decode_step


def make_serve_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                    batch: int, cache_len: int):
    """Returns jitted f(params, cache, token, pos) -> (logits, cache)."""
    p_sh = shd.param_shardings(cfg, mesh, run)
    cache_abs = init_cache(cfg, batch, cache_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    act_rules = shd.make_activation_rules(cfg, mesh, run)

    def step(params, cache, token, pos):
        with activation_sharding(act_rules):
            return decode_step(params, cache, token, pos, cfg, run)

    return jax.jit(
        step,
        in_shardings=(p_sh, c_sh, None, None),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )


def serve_step_lowering_args(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                             shape: InputShape):
    """Abstract (params, cache, token, pos) for ``.lower()``."""
    B = shape.global_batch
    ap = abstract_params(cfg)
    cache_abs = init_cache(cfg, B, shape.seq_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    cache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_abs, c_sh)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return ap, cache, token, pos


def make_fused_serve_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                          batch: int, cache_len: int, num_tokens: int = 8):
    """Returns jitted fused-chunk step — the decode_n signature the engine
    dispatches: f(params, cache, token, pos, remaining, done, eos, temps,
    key) -> (tokens, cache, token, pos, remaining, done, key)."""
    p_sh = shd.param_shardings(cfg, mesh, run)
    cache_abs = init_cache(cfg, batch, cache_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    act_rules = shd.make_activation_rules(cfg, mesh, run)

    def step(params, cache, token, pos, remaining, done, eos, temps, key):
        with activation_sharding(act_rules):
            return decode_n(params, cache, token, pos, remaining, done,
                            eos, temps, key, cfg, run, num_tokens,
                            cache_len)

    return jax.jit(
        step,
        in_shardings=(p_sh, c_sh) + (None,) * 7,
        out_shardings=(None, c_sh) + (None,) * 5,
        donate_argnums=(1,),
    )


def fused_serve_step_lowering_args(cfg: ModelConfig, run: RunConfig,
                                   mesh: Mesh, shape: InputShape):
    """Abstract args matching ``make_fused_serve_step`` for ``.lower()``."""
    B = shape.global_batch
    ap = abstract_params(cfg)
    cache_abs = init_cache(cfg, B, shape.seq_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    cache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_abs, c_sh)
    vec = lambda dt: jax.ShapeDtypeStruct((B,), dt)  # noqa: E731
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return (ap, cache, vec(jnp.int32), vec(jnp.int32), vec(jnp.int32),
            vec(jnp.bool_), vec(jnp.int32), vec(jnp.float32), key)
