"""Serve-step builder: the decode analogue of training.train_step.

``decode_32k`` / ``long_500k`` dry-run shapes lower exactly this function —
ONE new token against a seq_len KV cache.  Shardings follow
core.sharding.cache_shardings (batch over data axes, heads over model;
at global_batch=1 the state shards over `model` only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import InputShape, ModelConfig, RunConfig
from repro.core import sharding as shd
from repro.core.actshard import activation_sharding
from repro.models import abstract_params, init_cache
from repro.models.model import decode_step


def make_serve_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                    batch: int, cache_len: int):
    """Returns jitted f(params, cache, token, pos) -> (logits, cache)."""
    p_sh = shd.param_shardings(cfg, mesh, run)
    cache_abs = init_cache(cfg, batch, cache_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    act_rules = shd.make_activation_rules(cfg, mesh, run)

    def step(params, cache, token, pos):
        with activation_sharding(act_rules):
            return decode_step(params, cache, token, pos, cfg, run)

    return jax.jit(
        step,
        in_shardings=(p_sh, c_sh, None, None),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )


def serve_step_lowering_args(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                             shape: InputShape):
    """Abstract (params, cache, token, pos) for ``.lower()``."""
    B = shape.global_batch
    ap = abstract_params(cfg)
    cache_abs = init_cache(cfg, B, shape.seq_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    cache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_abs, c_sh)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return ap, cache, token, pos
