"""Serve-step builder: the decode analogue of training.train_step.

``decode_32k`` / ``long_500k`` dry-run shapes lower exactly this function —
ONE new token against a seq_len KV cache.  Shardings follow
core.sharding.cache_shardings (batch over data axes, heads over model;
at global_batch=1 the state shards over `model` only).

``make_fused_serve_step`` is the device-resident fast-path twin: the same
shardings around ``models.model.decode_n`` (N tokens per dispatch, fused
sampling + stop masking), so the fused signature the serving engine runs
can be lowered/cost-analyzed by the dry-run machinery too.

``make_chunked_serve_step`` is the continuous-batching twin: ONE fused
dispatch running a prefill chunk (``models.model.prefill_chunk``) plus a
1-token ``decode_n`` over every lane against the shared paged pool —
exactly what the engine's token-budgeted serve step dispatches when a
partial prefill and live decode lanes coexist.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import InputShape, ModelConfig, RunConfig
from repro.core import sharding as shd
from repro.core.actshard import activation_sharding
from repro.models import abstract_params, init_cache
from repro.models.model import decode_n, decode_step, prefill_chunk
from repro.models.paging import PagedKVConfig


def make_serve_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                    batch: int, cache_len: int):
    """Returns jitted f(params, cache, token, pos) -> (logits, cache)."""
    p_sh = shd.param_shardings(cfg, mesh, run)
    cache_abs = init_cache(cfg, batch, cache_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    act_rules = shd.make_activation_rules(cfg, mesh, run)

    def step(params, cache, token, pos):
        with activation_sharding(act_rules):
            return decode_step(params, cache, token, pos, cfg, run)

    return jax.jit(
        step,
        in_shardings=(p_sh, c_sh, None, None),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )


def serve_step_lowering_args(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                             shape: InputShape):
    """Abstract (params, cache, token, pos) for ``.lower()``."""
    B = shape.global_batch
    ap = abstract_params(cfg)
    cache_abs = init_cache(cfg, B, shape.seq_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    cache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_abs, c_sh)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return ap, cache, token, pos


def make_fused_serve_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                          batch: int, cache_len: int, num_tokens: int = 8):
    """Returns jitted fused-chunk step — the decode_n signature the engine
    dispatches: f(params, cache, token, pos, remaining, done, eos, temps,
    key) -> (tokens, cache, token, pos, remaining, done, key)."""
    p_sh = shd.param_shardings(cfg, mesh, run)
    cache_abs = init_cache(cfg, batch, cache_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    act_rules = shd.make_activation_rules(cfg, mesh, run)

    def step(params, cache, token, pos, remaining, done, eos, temps, key):
        with activation_sharding(act_rules):
            return decode_n(params, cache, token, pos, remaining, done,
                            eos, temps, key, cfg, run, num_tokens,
                            cache_len)

    return jax.jit(
        step,
        in_shardings=(p_sh, c_sh) + (None,) * 7,
        out_shardings=(None, c_sh) + (None,) * 5,
        donate_argnums=(1,),
    )


def fused_serve_step_lowering_args(cfg: ModelConfig, run: RunConfig,
                                   mesh: Mesh, shape: InputShape):
    """Abstract args matching ``make_fused_serve_step`` for ``.lower()``."""
    B = shape.global_batch
    ap = abstract_params(cfg)
    cache_abs = init_cache(cfg, B, shape.seq_len, abstract=True)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs)
    cache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_abs, c_sh)
    vec = lambda dt: jax.ShapeDtypeStruct((B,), dt)  # noqa: E731
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return (ap, cache, vec(jnp.int32), vec(jnp.int32), vec(jnp.int32),
            vec(jnp.bool_), vec(jnp.int32), vec(jnp.float32), key)


def _chunked_paging(cache_len: int, batch: int,
                    page_size: int) -> PagedKVConfig:
    """The pool layout the budgeted engine defaults to: one dense HBM
    budget's worth of pages (batch * cache_len lines) plus the null page."""
    return PagedKVConfig.for_budget(batch * cache_len, page_size, cache_len)


def make_chunked_serve_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                            batch: int, cache_len: int,
                            page_size: int = 16, num_tokens: int = 8):
    """Returns the jitted token-budgeted mixed step the budgeted engine
    dispatches: f(params, cache, token, pos, remaining, done, eos, temps,
    key, page_table, limit, c_tokens, c_row, c_start, c_last, c_pages,
    c_offs) -> (tokens, cache, token, pos, remaining, done, key,
    c_logits) — a prefill chunk (length = c_tokens' trailing dim, one
    program per chunk bucket; compute + per-line scatter) fused with a
    ``num_tokens``-token decode over every lane, both against the shared
    paged pool."""
    from repro.serving.engine import DecodeEngine
    paging = _chunked_paging(cache_len, batch, page_size)
    p_sh = shd.param_shardings(cfg, mesh, run)
    cache_abs = init_cache(cfg, batch, cache_len, abstract=True,
                           paging=paging)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs, paging=True)
    act_rules = shd.make_activation_rules(cfg, mesh, run)

    def step(params, cache, token, pos, remaining, done, eos, temps, key,
             page_table, limit, c_tokens, c_row, c_start, c_last,
             c_pages, c_offs):
        with activation_sharding(act_rules):
            c_logits, c_slices = prefill_chunk(
                params, {"tokens": c_tokens}, cache, c_row, c_start, cfg,
                run, last_pos=c_last)
            cache = DecodeEngine._scatter_chunk(
                cache, c_slices, c_pages, c_offs)
            out = decode_n(params, cache, token, pos, remaining, done,
                           eos, temps, key, cfg, run, num_tokens,
                           cache_len, page_table=page_table, limit=limit)
            return out + (c_logits,)

    return jax.jit(
        step,
        in_shardings=(p_sh, c_sh) + (None,) * 15,
        out_shardings=(None, c_sh) + (None,) * 6,
        donate_argnums=(1,),
    )


def chunked_serve_step_lowering_args(cfg: ModelConfig, run: RunConfig,
                                     mesh: Mesh, shape: InputShape,
                                     chunk: int = 64, page_size: int = 16):
    """Abstract args matching ``make_chunked_serve_step`` for ``.lower()``."""
    B = shape.global_batch
    paging = _chunked_paging(shape.seq_len, B, page_size)
    ap = abstract_params(cfg)
    cache_abs = init_cache(cfg, B, shape.seq_len, abstract=True,
                           paging=paging)
    c_sh = shd.cache_shardings(cfg, mesh, run, cache_abs, paging=True)
    cache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_abs, c_sh)
    vec = lambda dt: jax.ShapeDtypeStruct((B,), dt)  # noqa: E731
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    table = jax.ShapeDtypeStruct((B, paging.pages_per_seq), jnp.int32)
    c_tokens = jax.ShapeDtypeStruct((1, chunk), jnp.int32)
    c_row = jax.ShapeDtypeStruct((1, paging.pages_per_seq), jnp.int32)
    c_line = jax.ShapeDtypeStruct((chunk,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return (ap, cache, vec(jnp.int32), vec(jnp.int32), vec(jnp.int32),
            vec(jnp.bool_), vec(jnp.int32), vec(jnp.float32), key,
            table, vec(jnp.int32), c_tokens, c_row, scalar, scalar,
            c_line, c_line)
