"""Prefix-affinity router over N decode-engine replicas.

One serving engine is one node's worth of HBM; the cluster has many.
This module is the front door of the elastic tier: a :class:`Router`
that owns N :class:`~repro.serving.engine.DecodeEngine` replicas and
decides, per request, which replica's queue it joins.

* **Global admission, local execution** — every replica gets its own
  ``AdmissionController`` (slots and pages are physical, per-engine),
  but all of them bill the *same* :class:`~repro.policy.FairShareTree`
  and, by default, the same :class:`~repro.policy.GrpTresLedger`.  A
  tenant burning tokens on replica 0 loses priority on replica 1 too,
  and a GrpTRES slot cap is a cluster-wide cap, not per-replica × N.
* **Prefix affinity** — the radix prefix cache (``serving/prefix.py``)
  indexes prompts by complete ``page_size``-token blocks, so the
  request's *first complete prompt page* is exactly the key under which
  its system prompt would be cached.  The router consistent-hashes that
  key (:class:`HashRing`, SHA-1, ~64 virtual nodes per replica) so all
  requests sharing a system prompt land on the replica that already
  holds those pages.  Consistent hashing makes replica churn cheap:
  removing a replica remaps only *its* keys (property-tested).
* **Load shed** — affinity must not defeat batching: when the affine
  replica's queue depth exceeds the least-loaded replica's by more than
  ``spill_factor × num_slots``, the request spills to the least-loaded
  replica (counted in ``route_spills_total``; a cold prefill beats
  waiting out a convoy).
* **Drain** — :meth:`remove_replica` evicts every in-flight request via
  the engine's preemption path (partial output retained), pops the
  queues, and re-routes everything through the surviving ring.  Greedy
  decode is batch-independent, so a drained request's final output is
  bit-identical to an undisturbed run — the autoscaler leans on this.

The router never touches the device: it is host-side placement over
engines that each own their compiled programs, KV pool, and radix index.
"""
from __future__ import annotations

import bisect
import hashlib
import itertools
import time
from dataclasses import dataclass, field

from repro.monitoring.metrics import (
    METRIC_ROUTE_AFFINITY_HITS, METRIC_ROUTE_SPILLS,
    METRIC_SERVE_REPLICA_KV_PAGES, METRIC_SERVE_REPLICA_LOAD,
    MetricsRegistry,
)
from repro.policy import FairShareTree, GrpTresLedger, default_qos_table
from repro.serving.admission import AdmissionController

#: affinity key length when a replica engine has no paged pool to take a
#: page size from (first complete "page" of the prompt is still a stable
#: shared-system-prompt key)
DEFAULT_KEY_TOKENS = 16


def affinity_key(prompt, page_size: int) -> bytes:
    """The routing key: the request's first complete prompt page — the
    same token block the radix index would cache it under — or the whole
    prompt when it is shorter than one page."""
    head = [int(t) for t in prompt[:page_size]]
    return ",".join(str(t) for t in head).encode()


class HashRing:
    """Deterministic consistent-hash ring (SHA-1; ``hash()`` is salted
    per-process and would break cross-run routing stability).

    Each replica owns ``vnodes`` points on a 64-bit ring; a key maps to
    the first point clockwise.  With ~64 virtual nodes per replica the
    per-replica key share stays within 2x of uniform, and removing a
    replica hands *only its own* arcs to the survivors.
    """

    def __init__(self, vnodes: int = 64):
        assert vnodes >= 1
        self.vnodes = vnodes
        self._ring: list[tuple[int, int]] = []   # sorted (point, rid)
        self._points: dict[int, list[int]] = {}  # rid -> its points

    @staticmethod
    def _digest(data: bytes) -> int:
        return int.from_bytes(hashlib.sha1(data).digest()[:8], "big")

    def add(self, rid: int):
        assert rid not in self._points
        points = [self._digest(f"replica-{rid}-vnode-{v}".encode())
                  for v in range(self.vnodes)]
        self._points[rid] = points
        for p in points:
            bisect.insort(self._ring, (p, rid))

    def remove(self, rid: int):
        for p in self._points.pop(rid):
            self._ring.remove((p, rid))

    def lookup(self, key: bytes) -> int:
        assert self._ring, "hash ring is empty"
        h = self._digest(key)
        i = bisect.bisect_right(self._ring, (h, -1))
        if i == len(self._ring):
            i = 0
        return self._ring[i][1]

    @property
    def replicas(self) -> list[int]:
        return sorted(self._points)

    def __len__(self) -> int:
        return len(self._points)


@dataclass
class Replica:
    """One engine plus its per-replica admission controller."""
    rid: int
    engine: object
    admission: AdmissionController
    busy_s: float = field(default=0.0)  # seconds spent inside step()


class Router:
    """Prefix-affinity front door over replica decode engines.

    ``make_engine(admission)`` is the replica factory: it must build a
    fresh ``DecodeEngine`` wired to the given admission controller (the
    router constructs one per replica against the shared ledger).
    ``policy`` is ``"affinity"`` (consistent-hash + spill), ``"rr"``
    (round-robin), or ``"least"`` (least-loaded).  ``grp_scope`` decides
    whether GrpTRES caps bind cluster-wide (``"global"``, one shared
    :class:`GrpTresLedger`) or per replica (``"replica"``, PR-4
    behaviour times N).
    """

    POLICIES = ("affinity", "rr", "least")

    def __init__(self, make_engine, replicas: int = 0,
                 policy: str = "affinity", spill_factor: float = 2.0,
                 tree: FairShareTree = None, qos_table: dict = None,
                 weights=None, metrics: MetricsRegistry = None,
                 grp_scope: str = "global", vnodes: int = 64):
        assert policy in self.POLICIES, policy
        assert grp_scope in ("global", "replica"), grp_scope
        self.make_engine = make_engine
        self.policy = policy
        self.spill_factor = spill_factor
        self.tree = tree if tree is not None else FairShareTree()
        self.qos_table = (qos_table if qos_table is not None
                          else default_qos_table())
        self.weights = weights
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.grp_ledger = GrpTresLedger() if grp_scope == "global" else None
        self.ring = HashRing(vnodes)
        self.replicas: dict[int, Replica] = {}
        self._next_rid = itertools.count()
        self._rr = itertools.count()
        self.stats = {"routed": 0, "affinity_hits": 0, "spills": 0,
                      "drains": 0, "resubmitted": 0}
        for _ in range(replicas):
            self.add_replica()

    # ------------------------------------------------------------ fleet ----
    def add_replica(self) -> int:
        """Bring up one replica against the shared ledger; returns its id."""
        rid = next(self._next_rid)
        admission = AdmissionController(
            tree=self.tree, qos_table=self.qos_table, weights=self.weights,
            grp_ledger=self.grp_ledger)
        engine = self.make_engine(admission)
        assert engine.admission is admission, \
            "make_engine must wire the provided admission controller"
        self.replicas[rid] = Replica(rid, engine, admission)
        self.ring.add(rid)
        return rid

    def remove_replica(self, rid: int) -> int:
        """Drain ``rid``: evict its in-flight requests (partial output
        retained), pop its queues, and re-route everything through the
        surviving replicas.  Returns the number of requests moved."""
        assert len(self.replicas) > 1, "cannot drain the last replica"
        rep = self.replicas.pop(rid)
        self.ring.remove(rid)
        drained = rep.engine.drain()
        self.stats["drains"] += 1
        for req in drained:
            self.submit(req)
            self.stats["resubmitted"] += 1
        return len(drained)

    def load(self, rid: int) -> int:
        """Queue depth of one replica: slot holders plus queued — the
        spill signal and the autoscaler's emptiest-replica criterion."""
        eng = self.replicas[rid].engine
        return eng.active() + eng.pending()

    @property
    def page_size(self) -> int:
        for rep in self.replicas.values():
            paging = getattr(rep.engine, "paging", None)
            if paging is not None:
                return paging.page_size
        return DEFAULT_KEY_TOKENS

    def add_tenant(self, name: str, shares: int = 1):
        """Pre-register a tenant's shares on the shared tree (replicas'
        controllers pick existing accounts up on first submit)."""
        if name not in self.tree.accounts:
            self.tree.add_account(name, shares=shares)

    # ---------------------------------------------------------- routing ----
    def route(self, req) -> int:
        """Pick a replica id for ``req`` without submitting it."""
        rids = sorted(self.replicas)
        assert rids, "router has no replicas"
        if len(rids) == 1:
            return rids[0]
        if self.policy == "rr":
            return rids[next(self._rr) % len(rids)]
        loads = {r: self.load(r) for r in rids}
        least = min(rids, key=lambda r: (loads[r], r))
        if self.policy == "least":
            return least
        rid = self.ring.lookup(affinity_key(req.prompt, self.page_size))
        bound = self.spill_factor * self.replicas[rid].engine.num_slots
        if loads[rid] - loads[least] > bound:
            self.stats["spills"] += 1
            self.metrics.counter(
                METRIC_ROUTE_SPILLS,
                "affinity routes shed to the least-loaded replica").inc()
            return least
        self.stats["affinity_hits"] += 1
        self.metrics.counter(
            METRIC_ROUTE_AFFINITY_HITS,
            "requests routed to their prefix-affine replica").inc()
        return rid

    def submit(self, req) -> int:
        """Route ``req`` and enqueue it on the chosen replica."""
        rid = self.route(req)
        self.stats["routed"] += 1
        self.replicas[rid].engine.submit(req)
        return rid

    # --------------------------------------------------------- stepping ----
    def step(self) -> int:
        """Step every replica once; returns total tokens emitted.

        Per-replica compute time is accumulated in ``Replica.busy_s``
        (wall seconds inside each engine's ``step()``).  Replicas share
        nothing, so a real deployment's wall clock is the *busiest*
        replica's compute time — ``max(busy_s)`` is the router-balance
        throughput denominator the bench gates."""
        total = 0
        for rid in sorted(self.replicas):
            rep = self.replicas[rid]
            t0 = time.perf_counter()
            total += rep.engine.step()
            rep.busy_s += time.perf_counter() - t0
        self._update_gauges()
        return total

    def run_to_completion(self, max_steps: int = 10_000) -> int:
        total = 0
        for _ in range(max_steps):
            made = self.step()
            total += made
            if made == 0 and not any(
                    self.load(r) for r in self.replicas):
                break
        return total

    def busy_seconds(self) -> dict[int, float]:
        return {rid: rep.busy_s for rid, rep in self.replicas.items()}

    def _update_gauges(self):
        load_g = self.metrics.gauge(
            METRIC_SERVE_REPLICA_LOAD,
            "per-replica queue depth (slot holders + queued)")
        pages_g = self.metrics.gauge(
            METRIC_SERVE_REPLICA_KV_PAGES,
            "per-replica KV pages with >= 1 holder")
        for rid in sorted(self.replicas):
            load_g.set(float(self.load(rid)), replica=str(rid))
            eng = self.replicas[rid].engine
            view = getattr(eng, "pool_view", None)
            if getattr(eng, "paging", None) is not None and view is not None:
                pages_g.set(float(max(view.in_use_vector())),
                            replica=str(rid))
