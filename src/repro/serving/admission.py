"""Multi-tenant admission for the decode engine: the serving half of the
shared tenancy core.

The batch scheduler (``repro.cluster``) and this controller consult the
*same* ``repro.policy`` machinery — one account tree, one decayed TRES
ledger, one QOS catalogue — so a single ``sshare`` call reports a tenant's
batch jobs *and* served tokens against one set of shares.

Per-tenant queues replace the engine's single deque.  *Within* a tenant
queue requests are ordered by ``(QOS priority desc, arrival seq)`` — a
high-QOS request never waits behind a same-tenant scavenger one (the
cross-tenant analogue has always held via preemption).  When a slot
frees, the next request comes from the tenant maximizing the same
multifactor composition the scheduler uses::

    W_fs * 2^(-usage/shares) + W_qos * qos_priority_norm

with FIFO arrival order breaking ties.  Serving consumption charges the
ledger in serving TRES units: generated tokens and KV-cache residency
(cache lines held per decode step), discounted by the QOS
``usage_factor`` exactly like batch scavenger cycles.  The fused decode
engine charges once per chunk through :meth:`charge_bulk`, which groups
by (tenant, QOS) so ledger writes stay O(tenants) per chunk no matter
the slot count.

With ``wall_clock_decay=True`` the shared ledger decays on
``time.monotonic()`` at every pick/charge — for long-lived pure-serving
deployments where no cluster event loop drives ``decay_to`` (otherwise
an old hog would never be forgiven).  Leave it off when the ledger is
shared with a simulated cluster clock.

QOS rules carry over unchanged:

* ``grp_tres`` — a tenant's concurrent decode slots are capped via the
  ``slots`` TRES key (``QOS(grp_tres={"slots": 2})``): the GrpTRES hold
  that keeps one tenant from monopolizing the batch;
* ``preempt`` — a queued high-QOS request that finds no free slot may
  evict one running preemptable (e.g. scavenger) slot; the victim
  requeues at the head of its tenant queue with its partial output
  retained and resumes from where it stopped.
"""
from __future__ import annotations

import bisect
import itertools
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.policy import (
    FairShareTree, PriorityWeights, QOS, default_qos_table, tres_within,
)

#: Serving TRES billing weights, merged into the shared ledger's
#: TRESBillingWeights on attach (setdefault — an operator override wins).
#: One generated token bills like one accelerator-second; KV residency is
#: a light rent so long-context requests pay for the memory they pin.
SERVING_TRES_WEIGHTS = {
    "tokens": 1.0,            # one generated token
    "gres/kv_token": 0.001,   # one KV-cache line resident for one step
}
# "gres/kv_page" (one KV page resident for one step) is deliberately NOT
# defaulted here: its fair rate is page_size * kv_token, so the paged
# engine setdefaults it from its own page size at attach — an operator
# value set beforehand always wins, and engines sharing one ledger
# should share one page size (or set the weight explicitly).

#: TRES key for concurrent decode slots (GrpTRES caps, e.g. {"slots": 2}).
TRES_SLOTS = "slots"

#: TRES key for concurrently-held KV pages (paged engine GrpTRES caps,
#: e.g. ``{"kv_pages": 8}`` — a direct lid on a tenant's HBM residency).
TRES_KV_PAGES = "kv_pages"


@dataclass
class Tenant:
    """One serving tenant: an account in the shared tree + a queue kept
    sorted by (QOS priority desc, arrival seq)."""
    name: str
    shares: int = 1
    queue: list = field(default_factory=list)
    # decode slots currently held, keyed by QOS — GrpTRES caps are
    # per-(account, QOS), matching the batch scheduler's accounting
    slots_by_qos: dict = field(default_factory=dict)
    # KV pages currently held, keyed by QOS (paged engine only)
    pages_by_qos: dict = field(default_factory=dict)

    @property
    def slots_held(self) -> int:
        return sum(self.slots_by_qos.values())

    @property
    def pages_held(self) -> int:
        return sum(self.pages_by_qos.values())


class AdmissionController:
    """Per-tenant queues + fair-share pick + QOS caps/preemption.

    All bookkeeping is host-side Python over O(tenants) dicts — nothing
    here touches the jitted decode path.
    """

    def __init__(self, tree: Optional[FairShareTree] = None,
                 qos_table: Optional[dict[str, QOS]] = None,
                 weights: Optional[PriorityWeights] = None,
                 wall_clock_decay: bool = False,
                 clock=time.monotonic, tracer=None, grp_ledger=None):
        self.tree = tree if tree is not None else FairShareTree()
        for key, w in SERVING_TRES_WEIGHTS.items():
            self.tree.tres_weights.setdefault(key, w)
        if wall_clock_decay:
            self.tree.enable_wallclock_decay(clock)
        self.qos_table = dict(qos_table) if qos_table is not None \
            else default_qos_table()
        self.weights = weights or PriorityWeights()
        self.tenants: dict[str, Tenant] = {}
        self._seq = itertools.count()      # global FIFO arrival order
        #: optional repro.monitoring.Tracer — QUEUED spans, queue-wait
        #: SLO series, and pick-reason attributes hang off it
        self.tracer = tracer
        #: optional shared repro.policy.GrpTresLedger — when set, GrpTRES
        #: caps bind on the account's holdings across EVERY controller
        #: writing through the same ledger (the router's N replicas),
        #: not just this one's
        self.grp_ledger = grp_ledger
        #: optional predicate(req) -> bool: "would this request's prompt
        #: hit the radix prefix index right now?"  The engine wires it
        #: when the prefix cache is on; it breaks exact fair-share
        #: priority ties toward requests that reuse cached pages (their
        #: prefill is nearly free), falling back to FIFO within the tie.
        self.radix_probe = None
        #: admission cycle statistics, the `sdiag` admission section
        self.stats = {"cycles": 0, "picks": 0, "preempt_picks": 0,
                      "requeues": 0}

    # ----------------------------------------------------------- tenants ----
    def add_tenant(self, name: str, shares: int = 1) -> Tenant:
        """Register a tenant (idempotent).  Reuses an existing account in
        a shared tree — so a batch account and a serving tenant with the
        same name are literally the same ledger row.  For a pre-existing
        account the ledger's shares are authoritative (priorities come
        from ``tree.norm_shares``): the ``shares`` argument is ignored
        and the tenant reports the tree's value."""
        t = self.tenants.get(name)
        if t is not None:
            return t
        if name not in self.tree.accounts:
            self.tree.add_account(name, shares=shares)
        else:
            shares = self.tree.accounts[name].shares
        t = Tenant(name, shares=shares)
        self.tenants[name] = t
        return t

    # ------------------------------------------------------------ queues ----
    def _order_key(self, req):
        """In-queue ordering: highest QOS first, then arrival order."""
        qos = self.qos_table.get(req.qos)
        return (-(qos.priority if qos else 0), req._seq)

    def account_for(self, req) -> str:
        """The ledger account a request bills: its ``tenant/user`` leaf
        association when the request carries a user, else the tenant
        itself.  Leaf charges propagate up the subtree, so the tenant's
        standing still reflects all of its users."""
        user = getattr(req, "user", "")
        return f"{req.tenant}/{user}" if user else req.tenant

    def submit(self, req):
        """Enqueue a request on its tenant's queue — (QOS priority,
        arrival) ordered — auto-registering an unknown tenant with 1
        share, like the scheduler's lenient auto-association.  A request
        with a ``user`` additionally auto-registers its ``tenant/user``
        leaf association (idempotent), so per-user fair-share needs no
        pre-provisioning."""
        t = self.add_tenant(req.tenant)
        user = getattr(req, "user", "")
        if user:
            self.tree.add_user_association(user, req.tenant)
        req._seq = next(self._seq)
        bisect.insort(t.queue, req, key=self._order_key)
        self._trace_enqueue(req)

    def requeue(self, req):
        """A preempted request goes back into its tenant's queue with
        partial output retained.  Its original arrival seq makes it first
        in line within its QOS class when capacity returns (a later,
        higher-QOS arrival may still outrank it — by design)."""
        bisect.insort(self.tenants[req.tenant].queue, req,
                      key=self._order_key)
        self.stats["requeues"] += 1
        self._trace_enqueue(req, resumed=True)

    # ----------------------------------------------------------- tracing ----
    def _trace_enqueue(self, req, resumed: bool = False):
        """Open a QUEUED span for a (re)enqueued request: closed by the
        pick that admits it, its duration IS the queue wait."""
        tr = self.tracer
        if tr is None:
            return
        trace = getattr(req, "_trace", None)
        if trace is None:
            trace = req._trace = {}
        root = trace.get("root")
        track = root.track if root is not None else (
            f"serving:{req.tenant}", f"req {getattr(req, 'rid', '?')}")
        trace["queued"] = tr.begin("QUEUED", cat="queue", track=track,
                                   parent=root, resumed=resumed,
                                   qos=req.qos)

    def _trace_pick(self, req, reason: str):
        """Close the QUEUED span with the pick reason and feed the
        queue-wait SLO series (admit timestamp stamps the request — the
        engine's TTFT measurement starts here)."""
        self.stats["picks"] += 1
        if reason == "preemption":
            self.stats["preempt_picks"] += 1
        tr = self.tracer
        if tr is None:
            return
        now = tr.clock()
        req._t_admit = now
        trace = getattr(req, "_trace", None)
        queued = trace.pop("queued", None) if trace else None
        if queued is not None:
            wait = now - queued.start
            tr.end(queued, ts=now, pick_reason=reason,
                   fairshare=round(
                       self.tree.fair_share_factor(req.tenant), 4))
        else:
            wait = 0.0
        tr.slo.queue_wait(wait, req.tenant, req.qos)

    def pending(self) -> int:
        return sum(len(t.queue) for t in self.tenants.values())

    def queued(self, tenant: str) -> int:
        t = self.tenants.get(tenant)
        return len(t.queue) if t else 0

    # -------------------------------------------------------------- pick ----
    def _qos_factor(self, qos_name: str) -> float:
        qos = self.qos_table.get(qos_name)
        max_qos = max((q.priority for q in self.qos_table.values()),
                      default=1) or 1
        return qos.priority / max_qos if qos else 0.0

    def _priority(self, tenant: Tenant) -> float:
        """The serving multifactor: fair-share + QOS, same weights and the
        same ``2^(-usage/shares)`` factor the batch scheduler uses.  The
        fair-share factor is the head request's LEAF association — its
        ``tenant/user`` sub-account when it has one — so two users of
        the same tenant fair-share against each other, not just against
        other tenants."""
        head = tenant.queue[0]
        return (self.weights.fairshare
                * self.tree.fair_share_factor(self.account_for(head))
                + self.weights.qos * self._qos_factor(head.qos))

    def _over_cap(self, tenant: Tenant, req) -> bool:
        qos = self.qos_table.get(req.qos)
        if qos is None or not qos.grp_tres:
            return False
        if self.grp_ledger is not None:
            # global scope: the account's holdings summed across every
            # replica controller sharing this ledger
            total = self.grp_ledger.held(req.tenant, req.qos)
            held = {TRES_SLOTS: total.get(TRES_SLOTS, 0.0),
                    TRES_KV_PAGES: total.get(TRES_KV_PAGES, 0.0)}
        else:
            held = {TRES_SLOTS: float(tenant.slots_by_qos.get(req.qos, 0)),
                    TRES_KV_PAGES: float(tenant.pages_by_qos.get(
                        req.qos, 0))}
        # _est_pages: the paged engine stamps its page estimate on submit;
        # dense mode leaves it 0 so only the slot cap binds.  Under TP the
        # estimate may arrive as a per-shard vector (one logical page =
        # one page slice per shard); the cap binds on the tightest shard
        ask = {TRES_SLOTS: 1.0,
               TRES_KV_PAGES: float(np.max(getattr(req, "_est_pages", 0)))}
        return not tres_within(held, ask, qos.grp_tres)

    def _best_tenant(self, eligible=None) -> Optional[Tenant]:
        self.tree.tick()                   # wall-clock decay, if enabled
        best, best_key = None, None
        for t in self.tenants.values():
            if not t.queue or self._over_cap(t, t.queue[0]):
                continue
            if eligible is not None and not eligible(t.queue[0]):
                continue
            key = (self._priority(t), self._radix_bit(t.queue[0]),
                   -t.queue[0]._seq)
            if best is None or key > best_key:
                best, best_key = t, key
        return best

    def _radix_bit(self, req) -> int:
        """Tie-break between tenants whose multifactor priorities are
        exactly equal: prefer the head whose prompt hits the radix
        prefix index (its prefill is mostly cached — admitting it first
        is nearly free and keeps the shared pages hot).  Probe unset
        (no prefix cache) degrades to the pure FIFO tie-break."""
        if self.radix_probe is None:
            return 0
        return 1 if self.radix_probe(req) else 0

    def next_request(self, eligible=None):
        """Pop the next request to admit, or None (all queues empty or
        capped).  The caller owns the slot; the tenant's GrpTRES slot
        hold is taken here and returned by :meth:`release`.

        ``eligible`` (optional predicate over the head request) lets the
        engine veto picks it cannot place right now — the paged engine
        passes "does the prefill fit the free page pool", so a big
        blocked request does not starve admissible small ones.
        """
        self.stats["cycles"] += 1
        t = self._best_tenant(eligible=eligible)
        if t is None:
            return None
        req = t.queue.pop(0)
        t.slots_by_qos[req.qos] = t.slots_by_qos.get(req.qos, 0) + 1
        self._ledger_adjust(req, slots=1.0)
        self._trace_pick(req, "fairshare")
        return req

    def release(self, req):
        """Return the slot hold (request finished or was evicted)."""
        t = self.tenants.get(req.tenant)
        if t is not None:
            t.slots_by_qos[req.qos] = max(
                t.slots_by_qos.get(req.qos, 0) - 1, 0)
            self._ledger_adjust(req, slots=-1.0)

    def _ledger_adjust(self, req, slots: float = 0.0, pages: float = 0.0):
        """Mirror a holdings change into the shared GrpTRES ledger (when
        global scope is on) so sibling controllers see it."""
        if self.grp_ledger is None:
            return
        self.grp_ledger.adjust(req.tenant, req.qos,
                               {TRES_SLOTS: slots, TRES_KV_PAGES: pages})

    def adjust_pages(self, req, delta: int):
        """Track a tenant's reserved KV pages for the ``kv_pages``
        GrpTRES cap.  The classic paged engine reserves a request's
        WORST-CASE footprint (``_est_pages``) for its whole slot
        residency and returns it on finish/evict — decode-time growth is
        pre-paid, so a tenant can never grow past its cap.  The budgeted
        engine (``max_batch_tokens``) instead moves the hold
        chunk-by-chunk as a partial prefill's pages actually materialize
        (TRUE holdings, returned in full on promotion-exit, preemption,
        or starvation), so mid-prefill requests occupy exactly what they
        use.

        ``delta`` may be a per-shard vector (TP engines): the ledger
        tracks the tightest shard, since that is the shard the GrpTRES
        cap protects."""
        t = self.tenants.get(req.tenant)
        if t is not None:
            t.pages_by_qos[req.qos] = max(
                t.pages_by_qos.get(req.qos, 0) + int(np.max(delta)), 0)
            self._ledger_adjust(req, pages=float(int(np.max(delta))))

    # -------------------------------------------------------- preemption ----
    def pick_victim(self, candidates: list):
        """The ONE eviction-victim rule, shared by QOS preemption and the
        paged engine's pool-exhaustion reclaim: lowest QOS priority
        first, ties toward the worst fair-share standing, then the most
        recent admission.  Callers pass only candidates the preemptor's
        QOS may evict."""
        def vkey(r):
            vq = self.qos_table.get(r.qos)
            return (vq.priority if vq else 0,
                    self.tree.fair_share_factor(r.tenant), -r._seq)
        return min(candidates, key=vkey)

    def next_preempting(self, running: list):
        """Pop the best queued request whose QOS may evict one of
        ``running``, and pick its victim: ``(request, victim)`` or None.

        Atomic pop-and-pick so the engine admits exactly the blocked
        request the eviction was justified by (the requeued victim lands
        at the head of its tenant queue and must not race it back into
        the freed slot).  Considered tenants are those whose *head* can
        preempt something running — a blocked high request preempts even
        when a non-preempting tenant outranks it for the next free slot.
        The victim is the lowest-QOS running request, breaking ties
        toward the tenant with the worst fair-share standing, then the
        most recent admission.
        """
        running_qos = {r.qos for r in running}

        def can_preempt_now(req) -> bool:
            qos = self.qos_table.get(req.qos)
            return qos is not None and any(
                qos.can_preempt(v) for v in running_qos)

        self.stats["cycles"] += 1
        t = self._best_tenant(eligible=can_preempt_now)
        if t is None:
            return None
        head = t.queue[0]
        qos = self.qos_table[head.qos]
        victim = self.pick_victim(
            [r for r in running if qos.can_preempt(r.qos)])
        t.queue.pop(0)
        t.slots_by_qos[head.qos] = t.slots_by_qos.get(head.qos, 0) + 1
        self._ledger_adjust(head, slots=1.0)
        self._trace_pick(head, "preemption")
        return head, victim

    # ---------------------------------------------------------- charging ----
    def charge(self, req, tokens: int = 0, kv_tokens: int = 0,
               kv_pages: float = 0) -> float:
        """Charge generated tokens and/or KV-cache residency to the
        request's tenant in the shared ledger (QOS usage_factor applied,
        so scavenger tokens are discounted like scavenger job-seconds).
        Dense engines bill residency in ``kv_tokens`` (lines x steps);
        the paged engine bills ``kv_pages`` (pages x steps) — actual HBM
        held, so a short request stops paying for cache it never pinned.
        ``kv_pages`` may be fractional: a prefix-cache page shared by N
        live requests bills ``1/N`` to each holder, so the pool's true
        residency is charged exactly once per step across all sharers.

        No decay advance unless ``wall_clock_decay`` was enabled: the
        ledger's clock is driven by whoever owns it (the cluster's event
        loop, ``tree.decay_to`` directly, or the wall clock when opted
        in).
        """
        self.tree.tick()
        qos = self.qos_table.get(req.qos)
        return self.tree.charge_tres(
            self.account_for(req),
            {"tokens": float(tokens), "gres/kv_token": float(kv_tokens),
             "gres/kv_page": float(kv_pages)},
            usage_factor=qos.usage_factor if qos else 1.0)

    def charge_bulk(self, charges) -> float:
        """Charge a chunk's worth of consumption in one pass: ``charges``
        is an iterable of ``(req, tokens, kv_tokens)`` or
        ``(req, tokens, kv_tokens, kv_pages)``.  Grouped by (tenant, QOS)
        before hitting the ledger, so the fused decode engine pays
        O(tenants) ledger writes per chunk regardless of slot count or
        chunk length.  Returns the total charged amount."""
        self.tree.tick()
        grouped: dict[tuple, list[float]] = {}
        for entry in charges:
            req, tokens, kv_tokens = entry[0], entry[1], entry[2]
            kv_pages = entry[3] if len(entry) > 3 else 0
            acc = grouped.setdefault((self.account_for(req), req.qos),
                                     [0.0, 0.0, 0.0])
            acc[0] += tokens
            acc[1] += kv_tokens
            acc[2] += kv_pages
        total = 0.0
        for (account, qos_name), (tokens, kv_tokens, kv_pages) in \
                grouped.items():
            qos = self.qos_table.get(qos_name)
            total += self.tree.charge_tres(
                account,
                {"tokens": tokens, "gres/kv_token": kv_tokens,
                 "gres/kv_page": kv_pages},
                usage_factor=qos.usage_factor if qos else 1.0)
        return total
