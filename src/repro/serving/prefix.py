"""Radix-style prefix cache over the paged KV pool (SGLang-inspired).

In multi-tenant serving most requests share long prompt prefixes — chat
templates, few-shot headers, system prompts — and without reuse the
engine re-runs prefill and allocates fresh pages for every one of them.
The page table (``models.paging``) makes sharing natural: KV lines for a
token block at a fixed position range are *identical* across requests
whose prompts agree up to that block, so the same physical page can back
all of them read-only.

This module is the host-side index that makes the match:

* **radix trie at page granularity** — each node is one *complete* page
  of ``page_size`` prompt tokens, keyed by the token tuple and rooted at
  position 0, so node depth implies absolute position range (RoPE bakes
  positions into the cached K lines — a block is only reusable at the
  depth it was computed);
* **longest-prefix match** — :meth:`PrefixCache.match` walks the trie
  and returns the chain of cached nodes covering the request's prompt,
  capped at ``(len(tokens) - 1) // page_size`` pages: the final token is
  always prefilled so there are logits to sample the first output from;
* **copy-on-write fork** — a request maps the matched pages read-only
  (one allocator reference each, via :meth:`acquire`) and allocates
  private pages from the first divergent page onward; decode writes only
  land at positions past the shared region, so the "copy" never actually
  happens — divergence just stops the sharing;
* **refcount lifetime** — the index itself holds one reference on every
  cached page (taken at :meth:`insert`), so pages survive their
  producing request.  :meth:`evict` is the capacity-pressure valve: LRU
  leaves whose pages have no holder but the index are released back to
  the free pool (the engine tries this before its scavenger-preemption
  reclaim path fires).

Nothing here touches the device: the engine scatters/gathers through
page tables; this class only decides which physical pages mean what.
Tensor parallelism keeps it that way — a sharded pool stores ``1/tp``
of every page's KV heads per device, but page *ids* remain one logical
space, so matching, refcounts, COW forks, and eviction run unchanged
whatever the mesh looks like (``serving/tp.py``).
"""
from __future__ import annotations

import itertools

from repro.models.paging import NULL_PAGE, PageAllocator


class RadixNode:
    """One cached page: ``page_size`` prompt tokens at depth-implied
    positions, backed by physical ``page``.  ``generated`` marks a page
    whose tokens include model *output* (indexed at request finish
    rather than admission) — lifetime and sharing are identical, the
    flag only feeds the prompt/generated hit split."""
    __slots__ = ("block", "page", "parent", "children", "last_used",
                 "generated")

    def __init__(self, block: tuple, page: int, parent,
                 generated: bool = False):
        self.block = block
        self.page = page
        self.parent = parent
        self.children: dict = {}
        self.last_used = 0
        self.generated = generated


class PrefixCache:
    """Host-side radix index mapping prompt token blocks to pool pages."""

    def __init__(self, allocator: PageAllocator, page_size: int):
        assert page_size >= 1
        self.allocator = allocator
        self.page_size = page_size
        self.root = RadixNode((), NULL_PAGE, None)
        self.nodes = 0                  # cached pages currently indexed
        self.prompt_hits = 0            # acquired pages by provenance
        self.generated_hits = 0
        self._clock = itertools.count(1)

    # ------------------------------------------------------------- match ----
    def _blocks(self, tokens, n: int) -> list:
        ps = self.page_size
        return [tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])
                for j in range(n)]

    def match(self, tokens) -> list:
        """Longest cached chain of complete-page blocks covering a strict
        prefix of ``tokens`` (read-only: no refs taken, no LRU bump).
        At most ``(len - 1) // page_size`` pages match, so at least the
        last token always prefills."""
        limit = max(len(tokens) - 1, 0) // self.page_size
        node, out = self.root, []
        for blk in self._blocks(tokens, limit):
            node = node.children.get(blk)
            if node is None:
                break
            out.append(node)
        return out

    def acquire(self, nodes) -> list:
        """Pin a matched chain for an admitted request: one allocator
        reference per page (released with the request's other pages via
        ``allocator.free``) and an LRU recency bump."""
        now = next(self._clock)
        pages = []
        for n in nodes:
            n.last_used = now
            pages.append(n.page)
            if n.generated:
                self.generated_hits += 1
            else:
                self.prompt_hits += 1
        self.allocator.ref(pages)
        return pages

    # ------------------------------------------------------------ insert ----
    def insert(self, tokens, pages, generated_from=None) -> int:
        """Register the complete-page blocks of ``tokens`` (physical
        ``pages``, logical order).  Blocks already indexed — a request's
        matched chain, or a concurrent twin's insert — are kept as-is
        (first wins); each newly indexed page takes an index-owned
        allocator reference so it outlives the request.  A node whose
        block extends past token index ``generated_from`` (the prompt
        length, when inserting a finished request's full sequence) is
        flagged ``generated``.  Returns the number of nodes added."""
        n_total = min(len(tokens) // self.page_size, len(pages))
        node, added = self.root, 0
        now = next(self._clock)
        for j, blk in enumerate(self._blocks(tokens, n_total)):
            child = node.children.get(blk)
            if child is None:
                if pages[j] == NULL_PAGE:
                    break
                gen = (generated_from is not None
                       and (j + 1) * self.page_size > generated_from)
                child = RadixNode(blk, pages[j], node, generated=gen)
                node.children[blk] = child
                self.allocator.ref([pages[j]])
                self.nodes += 1
                added += 1
            child.last_used = now
            node = child
        return added

    # ---------------------------------------------------------- eviction ----
    def _pinned(self, node: RadixNode) -> bool:
        """A page some active request still maps (refcount beyond the
        index's own reference)."""
        return self.allocator.refcount(node.page) > 1

    def evictable_pages(self) -> int:
        """Pages the index could return to the pool right now: the nodes
        of maximal subtrees where nothing is pinned (leaf-first cascading
        reaches exactly those)."""
        def walk(node):
            count, clean = 0, True
            for c in node.children.values():
                c_count, c_clean = walk(c)
                count += c_count
                clean &= c_clean
            if node is self.root:
                return count, False
            if clean and not self._pinned(node):
                return count + 1, True
            return count, False
        return walk(self.root)[0]

    def evict(self, need: int) -> int:
        """LRU-evict unpinned cached prefixes until ``need`` pages are
        back in the free pool (or nothing evictable remains).  Only
        leaves are dropped — an interior node with a live descendant
        stays, or the descendant's path would dangle — and a freed
        parent becomes the next round's leaf.  Returns pages freed."""
        freed = 0
        while freed < need:
            victim = None
            stack = [self.root]
            while stack:
                n = stack.pop()
                stack.extend(n.children.values())
                if n is self.root or n.children or self._pinned(n):
                    continue
                if victim is None or n.last_used < victim.last_used:
                    victim = n
            if victim is None:
                break
            self.allocator.free([victim.page])
            del victim.parent.children[victim.block]
            self.nodes -= 1
            freed += 1
        return freed
