from repro.serving.admission import (
    AdmissionController, SERVING_TRES_WEIGHTS, Tenant,
)
from repro.serving.engine import DecodeEngine, Request
from repro.serving.prefix import PrefixCache, RadixNode
from repro.serving.serve_step import (
    chunked_serve_step_lowering_args, fused_serve_step_lowering_args,
    make_chunked_serve_step, make_fused_serve_step, make_serve_step,
    serve_step_lowering_args,
)

__all__ = ["AdmissionController", "DecodeEngine", "PrefixCache",
           "RadixNode", "Request", "SERVING_TRES_WEIGHTS", "Tenant",
           "chunked_serve_step_lowering_args",
           "fused_serve_step_lowering_args", "make_chunked_serve_step",
           "make_fused_serve_step", "make_serve_step",
           "serve_step_lowering_args"]
