from repro.serving.engine import DecodeEngine, Request
from repro.serving.serve_step import make_serve_step, serve_step_lowering_args

__all__ = ["DecodeEngine", "Request", "make_serve_step",
           "serve_step_lowering_args"]
