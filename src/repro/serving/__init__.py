from repro.serving.admission import (
    AdmissionController, SERVING_TRES_WEIGHTS, Tenant,
)
from repro.serving.autoscale import Autoscaler
from repro.serving.engine import DecodeEngine, Request
from repro.serving.prefix import PrefixCache, RadixNode
from repro.serving.router import HashRing, Router, affinity_key
from repro.serving.serve_step import (
    chunked_serve_step_lowering_args, fused_serve_step_lowering_args,
    make_chunked_serve_step, make_fused_serve_step, make_serve_step,
    serve_step_lowering_args,
)
from repro.serving.spec import (
    ModelDraftSource, NgramDraftSource, NgramIndex, draft_config,
    greedy_accept, rejection_sample,
)
from repro.serving.tp import TPPlan, plan_tp

__all__ = ["AdmissionController", "Autoscaler", "DecodeEngine", "HashRing",
           "ModelDraftSource", "NgramDraftSource", "NgramIndex",
           "PrefixCache", "RadixNode", "Request", "Router",
           "SERVING_TRES_WEIGHTS", "Tenant", "affinity_key",
           "chunked_serve_step_lowering_args", "draft_config",
           "fused_serve_step_lowering_args", "greedy_accept",
           "make_chunked_serve_step", "make_fused_serve_step",
           "make_serve_step", "plan_tp", "rejection_sample",
           "serve_step_lowering_args", "TPPlan"]
