from repro.serving.admission import (
    AdmissionController, SERVING_TRES_WEIGHTS, Tenant,
)
from repro.serving.engine import DecodeEngine, Request
from repro.serving.serve_step import make_serve_step, serve_step_lowering_args

__all__ = ["AdmissionController", "DecodeEngine", "Request",
           "SERVING_TRES_WEIGHTS", "Tenant", "make_serve_step",
           "serve_step_lowering_args"]
