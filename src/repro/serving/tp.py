"""Serving tensor parallelism: the mesh plan behind a TP DecodeEngine.

The training side already maps logical parameter axes to mesh axes
(``core/sharding.py``); this module is the *serving* counterpart, with
two differences that keep decode fast and bit-identical:

* **Sharding is explicit, not GSPMD.**  The decode hot paths run Pallas
  kernels that the partitioner cannot split, so every jitted engine
  program is wrapped in ``shard_map`` over the mesh's ``model`` axis:
  attention shards along KV-head groups (the GQA flash-decode kernel's
  grid ``(B, K, nk)`` simply sees ``K/tp`` heads per shard and runs
  unchanged), the MLP shards ``d_ff``, and each layer pays exactly one
  ``psum`` at the attention output projection and one at the MLP
  down-projection (``core.actshard.maybe_psum``).  Embedding, LM head
  and norms stay replicated — every shard computes FULL logits, so
  on-device sampling/argmax needs no collective and greedy decode is
  token-for-token identical to TP=1.

* **Reductions run in float32.**  The partial contraction at each psum
  point keeps its f32 accumulator through the reduction
  (``core.actshard.tp_will_reduce``) and rounds once afterwards, so f32
  models decode token-for-token identically at any TP degree.  bf16
  models keep ~1-ulp logit noise from the reassociated sum — standard
  for TP serving — which can flip an argmax whose top-2 logits collide
  in bf16; strict cross-TP reproducibility asks for ``dtype="float32"``.

* **Divisibility falls back, never crashes.**  A head/ffn count that
  does not divide the mesh axis leaves that block replicated (its psum
  point disabled — a reduction over replicas would multiply by ``tp``)
  and records a human-readable notice.  SSM state and MoE experts are
  not sharded by the serving path yet and fall back the same way.

The paged KV pool shards with attention: each device holds the
``(G, num_pages, page_size, K/tp, Dh)`` slice of every page, page *ids*
stay a single host-side space (one logical page = one id = ``tp``
device-local slices), so the allocator, prefix-cache refcounts and COW
forks remain shard-agnostic host logic.  Admission sees the pool
through :class:`repro.models.paging.ShardedAllocatorView`'s per-shard
budget vectors.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.actshard import tp_reduce_scope
from repro.core.sharding import serving_param_pspec
from repro.models.model import cache_kv_head_dim
from repro.models.spec import ParamSpec, layer_schedule, model_spec

#: mesh axis serving TP shards over
TP_AXIS = "model"


@dataclass
class TPPlan:
    """Resolved tensor-parallel plan for one engine instance."""
    mesh: Optional[Mesh]
    tp: int = 1
    axis: str = TP_AXIS
    shard_attn: bool = False
    shard_mlp: bool = False
    #: divisibility/compat fallbacks, human-readable (sdiag, tests)
    notices: list = field(default_factory=list)

    @property
    def active(self) -> bool:
        """True when any block is actually sharded — otherwise the
        engine skips shard_map entirely and runs exactly like TP=1."""
        return (self.mesh is not None and self.tp > 1
                and (self.shard_attn or self.shard_mlp))

    def devices(self) -> list:
        if self.mesh is None:
            return []
        return list(self.mesh.devices.flat)

    def psums_per_token(self, cfg: ModelConfig) -> dict:
        """Cross-shard reductions ONE decode step pays, by kind — the
        sdiag "psum count per dispatch" line (a ``decode_n`` chunk of N
        tokens pays N times this)."""
        sched = layer_schedule(cfg)
        attn = sum(1 for mixer, _ in sched if mixer == "attn")
        mlp = sum(1 for _, ffn in sched if ffn == "dense")
        return {"attn_out": attn if self.shard_attn else 0,
                "mlp_out": mlp if self.shard_mlp else 0}

    def describe(self, cfg: ModelConfig) -> str:
        if self.mesh is None or self.tp <= 1:
            return "tp=1 (single shard)"
        parts = []
        if self.shard_attn:
            parts.append(f"attn(heads {cfg.num_heads}->"
                         f"{cfg.num_heads // self.tp}/shard, kv "
                         f"{cfg.num_kv_heads}->"
                         f"{cfg.num_kv_heads // self.tp}/shard)")
        if self.shard_mlp:
            parts.append(f"mlp(ffn {cfg.d_ff}->"
                         f"{cfg.d_ff // self.tp}/shard)")
        if not parts:
            parts.append("replicated (no shardable dims)")
        return f"tp={self.tp} " + ", ".join(parts)


def plan_tp(cfg: ModelConfig, mesh: Optional[Mesh]) -> TPPlan:
    """Resolve which blocks shard over the mesh's ``model`` axis.

    The divisibility policy mirrors ``core/sharding.py``: a dimension
    shards only when the axis size divides it; otherwise that block
    replicates, with a notice instead of a crash.
    """
    if mesh is None:
        return TPPlan(mesh=None)
    tp = int(mesh.shape[TP_AXIS]) if TP_AXIS in mesh.axis_names else 1
    plan = TPPlan(mesh=mesh, tp=tp)
    if tp <= 1:
        return plan
    if cfg.ssm is not None:
        plan.notices.append(
            f"cfg.ssm set: SSM state is not head-sharded yet — "
            f"attention/SSM blocks replicate across tp={tp}")
    elif cfg.num_kv_heads % tp or cfg.num_heads % tp:
        plan.notices.append(
            f"kv_heads={cfg.num_kv_heads}, heads={cfg.num_heads} not "
            f"divisible by tp={tp}: attention replicates (GQA KV-head "
            f"groups must split evenly across shards)")
    else:
        plan.shard_attn = True
    if cfg.moe is not None:
        plan.notices.append(
            f"cfg.moe set: experts are not sharded by serving TP yet — "
            f"MoE blocks replicate across tp={tp}")
    elif cfg.d_ff % tp:
        plan.notices.append(
            f"d_ff={cfg.d_ff} not divisible by tp={tp}: MLP replicates")
    else:
        plan.shard_mlp = True
    if not (plan.shard_attn or plan.shard_mlp):
        plan.notices.append(
            f"nothing shardable: running replicated on 1 of {tp} shards")
    return plan


# -------------------------------------------------------- partition specs ----

def _shard_axes(plan: TPPlan) -> tuple:
    axes = ()
    if plan.shard_attn:
        axes += ("heads", "kv_heads")
    if plan.shard_mlp:
        axes += ("ffn",)
    return axes


def param_pspecs(cfg: ModelConfig, plan: TPPlan):
    """PartitionSpec pytree matching the parameter pytree (shard_map
    ``in_specs``)."""
    axes = _shard_axes(plan)

    def build(tree):
        if isinstance(tree, ParamSpec):
            return serving_param_pspec(tree, plan.tp, axes, axis=plan.axis)
        if isinstance(tree, dict):
            return {k: build(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [build(v) for v in tree]
        raise TypeError(type(tree))

    return build(model_spec(cfg))


def cache_pspec(plan: TPPlan, cfg: Optional[ModelConfig] = None) -> P:
    """PartitionSpec for ONE KV-cache leaf.

    Every engine-level cache layout — paged pool ``(G, pages, ps, K,
    Dh)``, dense rows ``(G, B, slots, K, Dh)``, one-request prefill
    output and chunk slices ``(G, B, S, K, Dh)`` — carries ``kv_heads``
    at the same dim of a 5-D leaf
    (:func:`repro.models.model.cache_kv_head_dim`), so a single spec
    covers all of them.  Used as a pytree *prefix* over the whole
    ``{"layers": [{"k","v"}]}`` cache (SSM leaves never co-exist with
    ``shard_attn``)."""
    if not plan.shard_attn:
        return P()
    kv_dim = 3 if cfg is None else cache_kv_head_dim(cfg)
    spec = [None] * 5
    spec[kv_dim] = plan.axis
    return P(*spec)


def param_shardings(cfg: ModelConfig, plan: TPPlan):
    """NamedSharding pytree for placing the params on the mesh."""
    mesh = plan.mesh
    axes = _shard_axes(plan)

    def build(tree):
        if isinstance(tree, ParamSpec):
            return NamedSharding(mesh, serving_param_pspec(
                tree, plan.tp, axes, axis=plan.axis))
        if isinstance(tree, dict):
            return {k: build(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [build(v) for v in tree]
        raise TypeError(type(tree))

    return build(model_spec(cfg))


def cache_shardings(cache, plan: TPPlan,
                    cfg: Optional[ModelConfig] = None):
    """NamedSharding pytree for placing the engine cache on the mesh."""
    spec = cache_pspec(plan, cfg)

    def leaf(x):
        return NamedSharding(plan.mesh, spec if x.ndim == 5 else P())

    return jax.tree.map(leaf, cache)


# ------------------------------------------------------- shard_map wrapper ----

def wrap(plan: TPPlan, fn, in_specs: Sequence, out_specs,
         donate: tuple = ()):
    """jit(shard_map(fn)) over the plan's mesh.

    ``in_specs``/``out_specs`` are per-argument PartitionSpecs (pytree
    prefixes — a bare ``P()`` replicates a whole params/cache subtree).
    The body installs :func:`repro.core.actshard.tp_reduce_scope` so the
    model's ``maybe_psum`` points emit cross-shard reductions exactly
    where the plan sharded; ``check_rep=False`` because the Pallas
    decode kernels define no replication rules — the ``P()`` out_specs
    are correct by construction (full logits per shard after the psums).

    ``jit`` wraps *outside* so donation and the engine's
    ``_cache_size()`` compile counters keep working unchanged.
    """
    if not plan.active:
        if donate:
            return jax.jit(fn, donate_argnums=donate)
        return jax.jit(fn)

    @functools.wraps(fn)
    def body(*args):
        with tp_reduce_scope(plan.axis, plan.shard_attn, plan.shard_mlp):
            return fn(*args)

    mapped = shard_map(body, mesh=plan.mesh, in_specs=tuple(in_specs),
                       out_specs=out_specs, check_rep=False)
    if donate:
        return jax.jit(mapped, donate_argnums=donate)
    return jax.jit(mapped)
