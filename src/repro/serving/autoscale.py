"""Elastic replica autoscaling: serving scavenges the cluster's idle nodes.

The burst-parallel idea (PAPERS.md) applied to serving: decode-engine
replicas run as *scavenger-QOS placeholder jobs* inside the SLURM
simulation, so the cluster's own policy machinery — fair-share billing,
GrpTRES caps, QOS preemption — governs how much of the cluster serving
may hold at any moment.

* **Growth** — each :meth:`Autoscaler.tick` asks the scheduler's
  ``slurm_now``-style probe (:meth:`Cluster.capacity_now`, "largest
  scavenger job that starts immediately") whether one more
  replica-shaped job would start *right now*.  While it would and the
  fleet is under ``max_replicas``, the autoscaler submits the placeholder
  job, verifies it started, and brings up a router replica against the
  shared ledger.  No capacity, no growth — serving never queues batch
  work out.
* **Drain** — batch pressure takes nodes back through the path that
  already exists: a normal/high-QOS job preempts the scavenger
  placeholder (requeue mode), the tick notices the job lost RUNNING, and
  the router drains that replica — in-flight requests are evicted with
  partial output retained and resume on a surviving replica,
  bit-identical (greedy decode is batch-independent).  When pending
  batch work *cannot* preempt (scavenger-vs-scavenger), the tick drains
  the emptiest replica proactively and cancels its job so the batch work
  starts on the freed nodes.
* **Floor** — ``min_replicas`` replicas keep serving even when their
  placeholder job is knocked out (the job waits requeued; interactive
  traffic must not go to zero because the cluster is busy).

The placeholder job's ``script`` is None: the decode engine lives in the
serving process, the job just owns the nodes.  ``Job.kind`` marks it so
squeue/sdiag and the pressure check can tell replicas from real batch
work.
"""
from __future__ import annotations

from typing import Optional

from repro.cluster.job import JOB_KIND_SERVE_REPLICA, JobState, \
    ResourceRequest


class Autoscaler:
    """Grows the router's replica fleet into idle nodes; shrinks it when
    the cluster takes them back.

    ``req`` is the per-replica node shape (default: one whole node's
    gres); each replica is one ``kind="serve_replica"`` scavenger job.
    Call :meth:`tick` from the serving loop — it is cheap (one capacity
    probe plus dict scans) and idempotent when nothing changed.
    """

    def __init__(self, router, cluster, req: Optional[ResourceRequest] = None,
                 min_replicas: int = 1, max_replicas: int = 4,
                 partition: Optional[str] = None, user: str = "serving",
                 account: Optional[str] = None, qos: str = "scavenger",
                 time_limit_s: int = 3600):
        assert 1 <= min_replicas <= max_replicas
        self.router = router
        self.cluster = cluster
        self.req = req if req is not None else ResourceRequest(
            nodes=1, gres_per_node={"tpu": 4}, time_limit_s=time_limit_s)
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.partition = partition
        self.user = user
        self.account = account
        self.qos = qos
        #: replica id -> its placeholder job id
        self.jobs: dict[int, int] = {}
        self.stats = {"ticks": 0, "scale_ups": 0, "drains": 0,
                      "requeued_requests": 0, "last_probe": 0}

    # ------------------------------------------------------------- ticks ----
    def tick(self):
        """One control-loop pass: reap lost jobs, yield to batch
        pressure, then grow into whatever is idle."""
        self.stats["ticks"] += 1
        self._reap_lost_jobs()
        self._yield_to_batch()
        self._scale_up()

    def _batch_pressure(self) -> bool:
        return any(j.kind != JOB_KIND_SERVE_REPLICA
                   for j in self.cluster._pending())

    def _reap_lost_jobs(self):
        """Replicas whose placeholder job is no longer RUNNING (QOS
        preemption requeued it, wall limit ended it, someone cancelled
        it) lose their nodes: drain them through the router — except the
        ``min_replicas`` floor, which keeps serving on a waiting job."""
        for rid, jid in sorted(self.jobs.items()):
            job = self.cluster.jobs[jid]
            if job.state == JobState.RUNNING:
                continue
            if len(self.router.replicas) <= self.min_replicas:
                continue
            if not job.state.finished:
                self.cluster.cancel(jid)
            del self.jobs[rid]
            self._drain(rid)

    def _yield_to_batch(self):
        """Pending non-replica work with no idle capacity to start on:
        give back the emptiest replica's nodes (the cluster's own QOS
        preemption handles preempting-QOS batch work before this runs —
        this path is for peers that cannot evict us)."""
        while (len(self.router.replicas) > self.min_replicas
               and self._batch_pressure()
               and self._probe() < self.req.nodes):
            managed = [r for r in self.jobs if r in self.router.replicas]
            if not managed:
                break
            rid = min(managed, key=lambda r: (self.router.load(r), r))
            jid = self.jobs.pop(rid)
            self.cluster.cancel(jid)
            self._drain(rid)

    def _scale_up(self):
        while (len(self.router.replicas) < self.max_replicas
               and self._probe() >= self.req.nodes):
            jid = self.cluster.submit(
                f"serve-replica-{len(self.jobs)}", self.req, user=self.user,
                partition=self.partition, account=self.account, qos=self.qos,
                run_time_s=float(self.req.time_limit_s),
                kind=JOB_KIND_SERVE_REPLICA)[0]
            if self.cluster.jobs[jid].state != JobState.RUNNING:
                # the probe said yes but scheduling said no (e.g. a
                # GrpTRES hold on the scavenger account) — back out
                self.cluster.cancel(jid)
                break
            rid = self.router.add_replica()
            self.jobs[rid] = jid
            self.stats["scale_ups"] += 1

    # ----------------------------------------------------------- helpers ----
    def _probe(self) -> int:
        n = self.cluster.capacity_now(self.req, self.partition)
        self.stats["last_probe"] = n
        return n

    def _drain(self, rid: int):
        moved = self.router.remove_replica(rid)
        self.stats["drains"] += 1
        self.stats["requeued_requests"] += moved
