"""Batched decode engine: slot-based continuous batching over a shared KV
cache (the TensorRT-role module from DESIGN.md's assumption log).

A fixed number of *slots* share one batched cache pytree.  Requests queue;
when a slot frees, the next request is prefilled (its cache slice written
into the batch cache at the slot index) and joins the batched one-token
decode loop.  Finished sequences (EOS or max_new_tokens) free their slot
immediately — the engine never waits for the whole batch, which is the
throughput property continuous batching exists for.

Per-slot position bookkeeping lives host-side; the batched decode step is a
single jitted call per token across all active slots.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import init_cache, init_params, prefill
from repro.models.model import decode_step
from repro.monitoring import MetricsRegistry


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0           # 0 => greedy
    # filled by the engine
    output: list = field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 cache_len: int = 1024, run: Optional[RunConfig] = None,
                 metrics: Optional[MetricsRegistry] = None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.run = run or RunConfig(remat="none")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.metrics = metrics or MetricsRegistry()
        self.cache = init_cache(cfg, num_slots, cache_len)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.pos = np.zeros(num_slots, np.int64)       # next position per slot
        self.last_tok = np.zeros(num_slots, np.int32)
        self.remaining = np.zeros(num_slots, np.int64)
        self._key = jax.random.PRNGKey(seed)
        self._step = self._build_step()

    # ------------------------------------------------------------ jitted ----
    def _build_step(self):
        cfg, run = self.cfg, self.run

        @jax.jit
        def step(params, cache, token, pos):
            # per-slot positions: (B,) — decode_step handles scalar or vector
            logits, cache = decode_step(params, cache, token, pos, cfg, run)
            return logits[:, 0], cache

        return step

    # ------------------------------------------------------------ public ----
    def submit(self, req: Request):
        assert len(req.prompt) < self.cache_len, "prompt exceeds cache"
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slots) if r is None]

    def _admit(self):
        """Prefill queued requests into free slots."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            with_timer = self.metrics.histogram(
                "serve_prefill_seconds", "prefill latency")
            import time
            t0 = time.perf_counter()
            logits, cache1 = prefill(
                self.params, {"tokens": prompt}, self.cfg, self.run,
                cache_len=self.cache_len)
            with_timer.observe(time.perf_counter() - t0)
            # write this request's cache slice into the batch cache
            def put(batch_leaf, one_leaf):
                return jax.lax.dynamic_update_slice_in_dim(
                    batch_leaf, one_leaf.astype(batch_leaf.dtype), slot,
                    axis=1)
            self.cache = jax.tree.map(put, self.cache, cache1)
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
            self.slots[slot] = req
            self.pos[slot] = len(req.prompt)
            self.last_tok[slot] = tok
            self.remaining[slot] = req.max_new_tokens - 1
            self.metrics.counter("serve_requests_admitted").inc()
            self._maybe_finish(slot)

    def _maybe_finish(self, slot: int):
        req = self.slots[slot]
        if req is None:
            return
        if (req.eos_id is not None and req.output
                and req.output[-1] == req.eos_id) or self.remaining[slot] <= 0 \
                or self.pos[slot] >= self.cache_len - 1:
            req.done = True
            self.slots[slot] = None
            self.metrics.counter("serve_requests_completed").inc()

    def _sample(self, logits: jax.Array) -> np.ndarray:
        """Per-slot sampling.  logits: (num_slots, V)."""
        temps = np.array([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.num_slots)], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        if (temps <= 0).all():
            return greedy.astype(np.int32)
        self._key, sub = jax.random.split(self._key)
        t = jnp.maximum(jnp.asarray(temps), 1e-4)[:, None]
        sampled = np.asarray(
            jax.random.categorical(sub, logits / t, axis=-1))
        return np.where(temps > 0, sampled, greedy).astype(np.int32)

    def step(self) -> int:
        """Admit + one batched decode token.  Returns #active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        token = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos.astype(np.int32))
        import time
        t0 = time.perf_counter()
        logits, self.cache = self._step(self.params, self.cache, token, pos)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        nxt = self._sample(logits)
        for i in active:
            req = self.slots[i]
            req.output.append(int(nxt[i]))
            self.pos[i] += 1
            self.last_tok[i] = nxt[i]
            self.remaining[i] -= 1
            self._maybe_finish(i)
        self.metrics.counter("serve_tokens_generated").inc(len(active))
        return len([r for r in self.slots if r is not None]) + len(self.queue)

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
