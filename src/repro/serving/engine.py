"""Batched decode engine: slot-based continuous batching over a shared KV
cache (the TensorRT-role module from DESIGN.md's assumption log).

A fixed number of *slots* share one batched cache pytree.  Requests queue
behind a multi-tenant :class:`~repro.serving.admission.AdmissionController`;
when a slot frees, the next request is chosen by the same
``2^(-usage/shares)`` fair-share priority the batch scheduler uses, then
prefilled (its cache slice written into the batch cache at the slot index)
and joins the batched one-token decode loop.  Finished sequences (EOS or
max_new_tokens) free their slot immediately — the engine never waits for
the whole batch, which is the throughput property continuous batching
exists for.

Multi-tenancy rides entirely on the host side: admission picks, GrpTRES
slot caps, QOS preemption (a blocked high request evicts one scavenger
slot; the victim requeues with its partial output retained and resumes
exactly where it stopped), and per-token ledger charges are all O(tenants)
Python per step — the batched decode step stays a single jitted call per
token across all active slots.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import init_cache, init_params, prefill
from repro.models.model import decode_step
from repro.monitoring import MetricsRegistry
from repro.monitoring.metrics import (
    METRIC_SERVE_PREEMPTIONS, METRIC_SERVE_TENANT_ADMITTED,
    METRIC_SERVE_TENANT_TOKENS,
)
from repro.serving.admission import AdmissionController


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0           # 0 => greedy
    tenant: str = "default"            # account in the shared ledger
    qos: str = "normal"                # service tier (see repro.policy.qos)
    # filled by the engine
    output: list = field(default_factory=list)
    done: bool = False
    preemptions: int = 0               # times evicted mid-decode
    _seq: int = field(default=0, repr=False)   # admission arrival order


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 cache_len: int = 1024, run: Optional[RunConfig] = None,
                 metrics: Optional[MetricsRegistry] = None, seed: int = 0,
                 admission: Optional[AdmissionController] = None):
        self.cfg = cfg
        self.params = params
        self.run = run or RunConfig(remat="none")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.metrics = metrics or MetricsRegistry()
        self.admission = admission if admission is not None \
            else AdmissionController()
        self.cache = init_cache(cfg, num_slots, cache_len)
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.pos = np.zeros(num_slots, np.int64)       # next position per slot
        self.last_tok = np.zeros(num_slots, np.int32)
        self.remaining = np.zeros(num_slots, np.int64)
        self._key = jax.random.PRNGKey(seed)
        self._step = self._build_step()

    # ------------------------------------------------------------ jitted ----
    def _build_step(self):
        cfg, run = self.cfg, self.run

        @jax.jit
        def step(params, cache, token, pos):
            # per-slot positions: (B,) — decode_step handles scalar or vector
            logits, cache = decode_step(params, cache, token, pos, cfg, run)
            return logits[:, 0], cache

        return step

    # ------------------------------------------------------------ public ----
    def submit(self, req: Request):
        # generation past the cache boundary truncates in _maybe_finish,
        # which also guarantees a preemption victim's resume prefill
        # (prompt + partial output) still fits the cache
        assert len(req.prompt) < self.cache_len, "prompt exceeds cache"
        self.admission.submit(req)

    def pending(self) -> int:
        return self.admission.pending()

    @property
    def queue(self) -> list:
        """Flattened view of all tenant queues (compat/diagnostics)."""
        return [r for t in self.admission.tenants.values() for r in t.queue]

    def _free_slots(self):
        return [i for i, r in enumerate(self.slots) if r is None]

    def _admit(self):
        """Fill free slots from the admission controller; then let blocked
        high-QOS requests preempt one preemptable slot each."""
        for slot in self._free_slots():
            req = self.admission.next_request()
            if req is None:
                return
            self._prefill_into(slot, req)
        # QOS preemption: each blocked preempting request evicts exactly
        # one victim slot (bounded per pass against cyclic QOS tables)
        for _ in range(self.num_slots):
            running = [r for r in self.slots if r is not None]
            pick = self.admission.next_preempting(running)
            if pick is None:
                return
            req, victim = pick
            slot = self._evict(victim)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        """Prefill a request into a free slot.  A preempted request
        resumes: its prompt *and* retained partial output are prefilled,
        so decode continues from exactly where the eviction stopped."""
        if req.output:
            toks = np.concatenate(
                [req.prompt, np.asarray(req.output[:-1], np.int32)])
        else:
            toks = req.prompt
        prompt = jnp.asarray(toks, jnp.int32)[None]
        with_timer = self.metrics.histogram(
            "serve_prefill_seconds", "prefill latency")
        t0 = time.perf_counter()
        try:
            logits, cache1 = prefill(
                self.params, {"tokens": prompt}, self.cfg, self.run,
                cache_len=self.cache_len)
        finally:
            with_timer.observe(time.perf_counter() - t0)
        # write this request's cache slice into the batch cache
        def put(batch_leaf, one_leaf):
            return jax.lax.dynamic_update_slice_in_dim(
                batch_leaf, one_leaf.astype(batch_leaf.dtype), slot,
                axis=1)
        self.cache = jax.tree.map(put, self.cache, cache1)
        if req.output:
            tok = int(req.output[-1])      # resume: last token re-decodes
        else:
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
        self.slots[slot] = req
        self.pos[slot] = len(toks)
        self.last_tok[slot] = tok
        self.remaining[slot] = req.max_new_tokens - len(req.output)
        # the prefilled KV lines are residency the tenant pays for
        self.admission.charge(req, kv_tokens=len(toks))
        self.metrics.counter("serve_requests_admitted").inc()
        self.metrics.counter(
            METRIC_SERVE_TENANT_ADMITTED,
            "admissions per tenant").inc(tenant=req.tenant)
        self._maybe_finish(slot)

    def _evict(self, victim: Request) -> int:
        """Evict a running request from its slot; it requeues at the head
        of its tenant queue with partial output retained.  Returns the
        freed slot index."""
        slot = self.slots.index(victim)
        self.slots[slot] = None
        victim.preemptions += 1
        self.admission.release(victim)
        self.admission.requeue(victim)
        self.metrics.counter(
            METRIC_SERVE_PREEMPTIONS, "evicted decode slots").inc()
        return slot

    def _maybe_finish(self, slot: int):
        req = self.slots[slot]
        if req is None:
            return
        if (req.eos_id is not None and req.output
                and req.output[-1] == req.eos_id) or self.remaining[slot] <= 0 \
                or self.pos[slot] >= self.cache_len - 1:
            req.done = True
            self.slots[slot] = None
            self.admission.release(req)
            self.metrics.counter("serve_requests_completed").inc()

    def _sample(self, logits: jax.Array) -> np.ndarray:
        """Per-slot sampling.  logits: (num_slots, V)."""
        temps = np.array([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.num_slots)], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        if (temps <= 0).all():
            return greedy.astype(np.int32)
        self._key, sub = jax.random.split(self._key)
        t = jnp.maximum(jnp.asarray(temps), 1e-4)[:, None]
        sampled = np.asarray(
            jax.random.categorical(sub, logits / t, axis=-1))
        return np.where(temps > 0, sampled, greedy).astype(np.int32)

    def step(self) -> int:
        """Admit + one batched decode token.  Returns #active + #queued."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return self.admission.pending()
        token = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos.astype(np.int32))
        t0 = time.perf_counter()
        logits, self.cache = self._step(self.params, self.cache, token, pos)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        nxt = self._sample(logits)
        tenant_tokens: dict[str, int] = {}
        for i in active:
            req = self.slots[i]
            req.output.append(int(nxt[i]))
            self.pos[i] += 1
            self.last_tok[i] = nxt[i]
            self.remaining[i] -= 1
            # one generated token + rent on the KV lines this slot holds
            self.admission.charge(req, tokens=1, kv_tokens=int(self.pos[i]))
            tenant_tokens[req.tenant] = tenant_tokens.get(req.tenant, 0) + 1
            self._maybe_finish(i)
        self.metrics.counter("serve_tokens_generated").inc(len(active))
        tok_counter = self.metrics.counter(
            METRIC_SERVE_TENANT_TOKENS, "generated tokens per tenant")
        for tenant, n in tenant_tokens.items():
            tok_counter.inc(n, tenant=tenant)
        return (len([r for r in self.slots if r is not None])
                + self.admission.pending())

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if self.step() == 0:
                break
