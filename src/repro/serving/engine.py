"""Batched decode engine: slot-based continuous batching over a shared KV
cache (the TensorRT-role module from DESIGN.md's assumption log).

A fixed number of *slots* share one batched cache pytree.  Requests queue
behind a multi-tenant :class:`~repro.serving.admission.AdmissionController`;
when a slot frees, the next request is chosen by the same
``2^(-usage/shares)`` fair-share priority the batch scheduler uses, then
prefilled (its cache slice written into the batch cache at the slot index)
and joins the batched decode loop.  Finished sequences (EOS or
max_new_tokens) free their slot immediately — the engine never waits for
the whole batch, which is the throughput property continuous batching
exists for.

The decode hot loop is **device-resident** (the fast path): sampling and
stop handling run inside the jitted step (``models.model.decode_n``), and
one dispatch generates ``decode_chunk`` tokens per slot via ``lax.scan``.
The host syncs ``tokens/pos/remaining/done`` once per chunk, then does
admission / ledger / metrics work exactly as before — so QOS preemption
and fair-share picks happen at chunk boundaries.  ``fused=False`` keeps
the original one-token host loop (reference + benchmark baseline).

Prefill is **bucketed** when ``prefill_buckets`` is set (full-attention,
non-sliding-window configs): prompts pad to the next bucket length so the
jitted prefill compiles once per bucket instead of once per distinct
prompt length, and the cache slice lands in the batch cache through one
pre-jitted donated ``dynamic_update_slice`` insert.

Multi-tenancy rides entirely on the host side: admission picks, GrpTRES
slot caps, QOS preemption (a blocked high request evicts one scavenger
slot; the victim requeues with its partial output retained and resumes
exactly where it stopped), and per-chunk batched ledger charges are all
O(tenants) Python per chunk.

**Paged KV cache** (``kv_page_size > 0``, opt-in): instead of pinning
``cache_len`` dense lines per slot, all slots share one device page pool
(``models.paging``).  A request holds exactly ``ceil(tokens/page_size)``
pages, grows one page at a time at decode-time page boundaries (the host
pre-allocates each chunk's worth before dispatch), and frees everything
back to the pool on finish/evict — so the same HBM budget serves far
more concurrent short requests.  Admission turns page-budget-aware: a
request is only picked when its prefill fits the free pool, GrpTRES can
cap ``kv_pages`` per tenant, and the ledger bills ``kv_pages`` residency
(true HBM held) instead of dense ``kv_tokens``.  Pool exhaustion at
growth time triggers the same one-victim scavenger eviction QOS
preemption uses; if nothing is evictable the starved slot truncates at
its allocation boundary instead of corrupting neighbours.  Greedy fused
decode is bit-identical to the dense cache (the gathered logical view
feeds the exact same masked attention math).

**Prefix cache** (``prefix_cache=True``, needs paging): a radix index
(``serving.prefix``) maps complete prompt-token pages to physical pages.
On admission the engine looks up the longest cached prefix, maps those
pages READ-ONLY into the new request's page table (copy-on-write: refs,
not copies — decode writes only ever land past the shared region on
privately-owned pages) and prefills **only the suffix**
(``models.model.prefill_suffix``, riding the same buckets).  Finished
requests donate their complete prompt pages to the index; under
capacity pressure the index LRU-evicts unpinned prefixes back to the
free pool *before* the scavenger victim path fires.  Shared pages bill
``gres/kv_page`` residency once, amortized across current holders, so
``sshare --tres`` keeps reporting true HBM use, and greedy decode stays
bit-identical to the no-reuse path.

**Continuous batching with chunked prefill** (``max_batch_tokens``,
needs paging + fused): instead of running a whole prompt's prefill as
one blocking dispatch at admission — head-of-line blocking every
decoding slot for the duration — each engine iteration runs ONE fused
step over a token budget that mixes decode lanes (1 token each while
prefills are pending) and prefill *chunks* from a partial-prefill
queue.  Chunked prefill is suffix prefill applied repeatedly
(``models.model.prefill_chunk``): a partially-prefilled request holds
exactly ``ceil(pos_filled/page)`` pages, its next chunk attends the
already-written lines through its page table (line-granular masking, so
chunk boundaries need not be page-aligned), and the chunk's KV lines
scatter mid-page into its pages.  Chunks pad to power-of-two buckets
(compiles O(buckets)); the queue packs shortest-remaining-first within
QOS rank so short interactive prompts cannot queue behind long batch
ones.  Admission/billing integrate at chunk granularity:
``adjust_pages`` grows a partial's GrpTRES holdings chunk-by-chunk
(true holdings, not the worst-case reservation the classic paged path
takes), a mid-prefill request is preemptible at chunk boundaries via
the existing requeue path, and PREFILL trace spans carry
``chunks``/``pos_filled`` attrs.  Greedy output is bit-identical to
whole-prompt prefill; ``serve_stats`` counters feed ``sdiag``'s
serve-step utilization section.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PSpec

from repro.configs.base import ModelConfig, RunConfig
from repro.models import init_cache, prefill
from repro.models.model import (
    decode_n, decode_step, prefill_chunk, prefill_suffix, verify_tokens,
)
from repro.models.paging import (
    NULL_PAGE, PageAllocator, PagedKVConfig, ShardedAllocatorView,
    TwoLevelPageTable, pages_for,
)
from repro.monitoring import MetricsRegistry, Tracer
from repro.monitoring.metrics import (
    METRIC_SERVE_KV_PAGES_IN_USE, METRIC_SERVE_PREEMPTIONS,
    METRIC_SERVE_PREFIX_EVICTIONS, METRIC_SERVE_PREFIX_HITS,
    METRIC_SERVE_PREFIX_MISSES, METRIC_SERVE_PREFIX_REUSED_TOKENS,
    METRIC_SERVE_TENANT_ADMITTED, METRIC_SERVE_TENANT_TOKENS,
    METRIC_SPEC_ACCEPT_RATE, METRIC_SPEC_ACCEPTED, METRIC_SPEC_PROPOSED,
)
from repro.serving import tp as tp_mod
from repro.serving.admission import (
    SERVING_TRES_WEIGHTS, AdmissionController,
)
from repro.serving.prefix import PrefixCache
from repro.serving.spec import (
    ModelDraftSource, NgramDraftSource, draft_config, rejection_sample,
)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0           # 0 => greedy
    tenant: str = "default"            # account in the shared ledger
    qos: str = "normal"                # service tier (see repro.policy.qos)
    user: str = ""                     # optional tenant/user leaf association
    # filled by the engine
    output: list = field(default_factory=list)
    done: bool = False
    preemptions: int = 0               # times evicted mid-decode
    _seq: int = field(default=0, repr=False)   # admission arrival order
    _slot: int = field(default=-1, repr=False)  # current decode slot (-1 = none)
    _est_pages: int = field(default=0, repr=False)  # paged: worst-case pages
    # budgeted mode bills TRUE holdings, grown chunk-by-chunk, instead of
    # the classic worst-case reservation
    _held_pages: int = field(default=0, repr=False)
    # lifecycle tracing (populated only when the engine has a tracer)
    _trace: dict = field(default_factory=dict, repr=False)  # open spans
    _t_submit: Optional[float] = field(default=None, repr=False)
    _t_admit: Optional[float] = field(default=None, repr=False)
    _t_last: Optional[float] = field(default=None, repr=False)  # last token


@dataclass
class _PartialPrefill:
    """An admitted request whose prompt is not fully prefilled yet.  It
    owns a decode slot (so eviction/requeue ride the existing paths) but
    its decode lane stays frozen — the page-table row the decode dispatch
    sees is all-NULL until promotion — while ``pos_filled`` advances one
    chunk at a time across engine iterations."""
    req: Request
    toks: np.ndarray                    # full resume/prefill token sequence
    slot: int
    pos_filled: int = 0                 # prompt lines already in the pool
    pages: list = field(default_factory=list)   # pages covering pos_filled
    n_shared: int = 0                   # leading prefix-cache pages
    chunks: int = 0                     # chunks dispatched so far
    span: object = None                 # open PREFILL trace span


@dataclass
class _ChunkPlan:
    """One planned prefill chunk: bucketed tokens plus the per-line page
    scatter targets, ready for dispatch (fused with decode or standalone)."""
    part: _PartialPrefill
    bucket: int                         # padded chunk length (power of two)
    real: int                           # real tokens in the chunk
    start: int                          # == part.pos_filled at plan time
    tokens: np.ndarray                  # (bucket,) int32, zero-padded
    row: np.ndarray                     # (pages_per_seq,) page-table row
    pages: np.ndarray                   # (bucket,) per-line target page
    offs: np.ndarray                    # (bucket,) per-line offset in page


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 cache_len: int = 1024, run: Optional[RunConfig] = None,
                 metrics: Optional[MetricsRegistry] = None, seed: int = 0,
                 admission: Optional[AdmissionController] = None,
                 decode_chunk: int = 1, fused: bool = True,
                 prefill_buckets: Union[None, str, Sequence[int]] = None,
                 kv_page_size: int = 0,
                 kv_pages: Optional[int] = None,
                 prefix_cache: bool = False,
                 max_batch_tokens: Optional[int] = None,
                 tracer: Optional[Tracer] = None,
                 speculate: int = 0,
                 spec_source: str = "ngram",
                 draft_model: Optional[ModelConfig] = None,
                 index_generated: Optional[bool] = None,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.run = run or RunConfig(remat="none")
        # ---- tensor parallelism (mesh=None -> single-shard, zero cost) ----
        # resolved up front: the paged pool view and every jitted builder
        # below depend on the plan
        self.tp = tp_mod.plan_tp(cfg, mesh)
        for note in self.tp.notices:
            print(f"[serve] tp: {note}")
        self._pp = tp_mod.param_pspecs(cfg, self.tp) if self.tp.active \
            else None
        self._cc = tp_mod.cache_pspec(self.tp, cfg) if self.tp.active \
            else None
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.metrics = metrics or MetricsRegistry()
        self.admission = admission if admission is not None \
            else AdmissionController()
        # request-lifecycle tracer (opt-in; None = zero overhead).  The
        # admission controller writes QUEUED spans / queue-wait series
        # into the same tracer unless it already has its own.
        self.tracer = tracer
        if tracer is not None and self.admission.tracer is None:
            self.admission.tracer = tracer
        self.decode_chunk = max(1, int(decode_chunk))
        self.fused = fused
        self.paging = self._resolve_paging(kv_page_size, kv_pages)
        if self.paging is not None:
            # one page bills like the lines it holds, keeping fair-share
            # comparable across page sizes and with dense engines on the
            # same ledger; setdefault so an operator-set weight wins
            w = self.admission.tree.tres_weights
            w.setdefault("gres/kv_page", self.paging.page_size *
                         w.get("gres/kv_token",
                               SERVING_TRES_WEIGHTS["gres/kv_token"]))
            self.allocator = PageAllocator(self.paging.num_pages)
            # one logical page id = one page slice per shard (TP shards
            # the pool along kv heads); admission consumes the per-shard
            # budget vectors, not the scalar
            self.pool_view = ShardedAllocatorView(
                self.allocator,
                shards=(self.tp.tp if self.tp.active and self.tp.shard_attn
                        else 1))
            # two-level (directory, leaf) page map: host memory scales
            # with pages actually mapped, not slots * pages_per_seq
            self._ptab = TwoLevelPageTable(num_slots,
                                           self.paging.pages_per_seq)
            #: dispatch-width bucket for the classic paged mode (grows
            #: monotonically in powers of two, so the decode programs
            #: recompile O(log pages_per_seq) times)
            self._table_width = 1
            self._slot_pages: list[list[int]] = [[] for _ in
                                                 range(num_slots)]
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            if self.paging is None:
                raise ValueError(
                    "prefix_cache=True needs the paged KV cache: pass "
                    "kv_page_size > 0 (CLI: --prefix-cache implies "
                    "--kv-paging)")
            self.prefix = PrefixCache(self.allocator, self.paging.page_size)
            # active-request holders per physical page, for amortized
            # residency billing (a page shared by h requests bills 1/h
            # to each, so the ledger charges true HBM once)
            self._page_holders: dict[int, int] = {}
            # radix tie-break: when fair-share priorities tie exactly,
            # admission prefers the head whose prompt hits the index
            # (its prefill is mostly cached).  A controller the caller
            # pre-wired keeps its own probe.
            if self.admission.radix_probe is None:
                self.admission.radix_probe = (
                    lambda r: bool(self.prefix.match(
                        self._resume_tokens(r))))
        self.cache = init_cache(cfg, num_slots, cache_len,
                                paging=self.paging)
        if self.tp.active:
            # place params and the KV pool on the mesh: attention weights
            # and cache split along (kv) heads, MLP along d_ff, everything
            # else (embed/lm_head/norms) replicated so every shard holds
            # full logits and sampling needs no collective
            self.params = jax.device_put(
                self.params, tp_mod.param_shardings(cfg, self.tp))
            self.cache = jax.device_put(
                self.cache, tp_mod.cache_shardings(self.cache, self.tp,
                                                   cfg))
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.pos = np.zeros(num_slots, np.int64)       # next position per slot
        self.last_tok = np.zeros(num_slots, np.int32)
        self.remaining = np.zeros(num_slots, np.int64)
        self._key = jax.random.PRNGKey(seed)
        self._buckets = self._resolve_buckets(prefill_buckets)
        self._step = self._build_step()
        self._decode_n = self._build_decode_n()
        self._insert = self._build_insert()
        self._prefill_fn = self._build_prefill()
        self._suffix_prefill_fn = (self._build_suffix_prefill()
                                   if self.prefix is not None else None)
        # ---- continuous batching (token-budgeted serve step) ----
        # always defined (empty in classic mode) so the shared eviction /
        # step paths need no mode guards
        self._partials: list[_PartialPrefill] = []
        self._prefill_slots: dict[int, _PartialPrefill] = {}
        #: per-iteration counters behind sdiag's serve-step utilization
        self.serve_stats = {"iterations": 0, "decode_tokens": 0,
                            "prefill_tokens": 0, "prefill_chunks": 0}
        self.max_batch_tokens: Optional[int] = None
        self._mixed_step = None
        if max_batch_tokens is not None:
            if self.paging is None:
                raise ValueError(
                    "max_batch_tokens: continuous batching with chunked "
                    "prefill needs the paged KV cache — a partial prefill "
                    "holds ceil(pos_filled/page) pages, which the dense "
                    "per-slot layout cannot express.  Pass kv_page_size "
                    "> 0 (CLI: --max-batch-tokens implies --kv-paging)")
            if not fused:
                raise ValueError(
                    "max_batch_tokens: the token-budgeted serve step "
                    "fuses decode and prefill chunks into one dispatch, "
                    "which needs fused=True (the host per-token loop has "
                    "no budgeted equivalent)")
            self.max_batch_tokens = int(max_batch_tokens)
            assert self.max_batch_tokens >= 1, max_batch_tokens
            b, buckets = 1, []
            while b <= self.max_batch_tokens:
                buckets.append(b)
                b *= 2
            # ascending: _plan_chunk picks the smallest bucket covering
            # the remaining prompt that still fits the budget
            self.chunk_buckets = tuple(buckets)
            # mixed iterations decode 1 token/lane; reuse the chunked
            # program when decode_chunk is already 1
            self._decode_n1 = (self._decode_n if self.decode_chunk == 1
                               else self._build_decode_n(1))
            self._chunk_fn = self._build_chunk_prefill()
            self._mixed_step = self._build_mixed_step()
        # ---- speculative decoding (draft-and-verify in the chunk) ----
        self.speculate = int(speculate)
        self.spec = None
        self._verify_fn = None
        self._mixed_verify = None
        #: per-round speculation counters behind sdiag's speculation
        #: section (rounds = verify dispatches; accepted counts draft
        #: tokens the target agreed with, excluding correction/bonus)
        self.spec_stats = {"rounds": 0, "proposed": 0, "accepted": 0,
                           "emitted": 0, "proposed_by": {}}
        self._spec_rng = np.random.default_rng(seed)
        if self.speculate:
            if self.paging is None:
                raise ValueError(
                    "speculate: draft-and-verify writes each proposal's "
                    "KV line through per-line (page, offset) scatter "
                    "targets and relies on rejected lines dying on the "
                    "null page — pass kv_page_size > 0 (CLI: --speculate "
                    "implies --kv-paging)")
            if not fused:
                raise ValueError(
                    "speculate: verification is one batched dispatch "
                    "over the fused decode lanes, which needs fused=True")
            if spec_source == "model":
                dcfg = draft_model if draft_model is not None \
                    else draft_config(cfg)
                self.spec = ModelDraftSource(dcfg, num_slots, cache_len,
                                             seed=seed, run=self.run)
            elif spec_source == "ngram":
                self.spec = NgramDraftSource()
            else:
                raise ValueError(f"unknown spec_source {spec_source!r} "
                                 "(expected 'ngram' or 'model')")
            self._verify_fn = self._build_verify()
            if self.max_batch_tokens is not None:
                self._mixed_verify = self._build_mixed_verify()
        #: index finished requests' complete generated-token pages into
        #: the radix trie (cross-request reuse of generated tokens).
        #: Defaults on exactly when speculation can mine them, so
        #: non-speculative engines keep pool accounting bit-identical.
        if index_generated is None:
            index_generated = bool(self.speculate) \
                and self.prefix is not None
        self.index_generated = bool(index_generated)

    def _resolve_paging(self, kv_page_size: int,
                        kv_pages: Optional[int]) -> Optional[PagedKVConfig]:
        """Paged layout, or None (dense default).  Paging needs a cache
        without position-dependent physical layout: full attention (no
        SSM state to page) and no sliding-window ring.  ``kv_pages``
        overrides the pool size; the default matches the dense HBM
        budget (num_slots * cache_len lines) plus the null page, so
        dense and paged engines are HBM-comparable out of the box."""
        if not kv_page_size:
            return None
        # name the offending config field: "full-attention only" alone
        # sends operators hunting through the whole ModelConfig
        if self.cfg.ssm is not None:
            raise ValueError(
                "kv_page_size: paged KV cache needs a full-attention "
                f"config, but cfg.ssm={self.cfg.ssm!r} — SSM recurrent "
                "state is not line-addressable, so it cannot be paged")
        if self.cfg.attn_every != 1:
            raise ValueError(
                "kv_page_size: paged KV cache needs a full-attention "
                f"config, but cfg.attn_every={self.cfg.attn_every} "
                "interleaves non-attention sublayers whose state has no "
                "page layout")
        if self.cfg.sliding_window is not None:
            raise ValueError(
                "kv_page_size: paged KV cache does not support "
                f"cfg.sliding_window={self.cfg.sliding_window} — the "
                "windowed ring cache's wrapped slot layout has no "
                "page-table equivalent yet")
        assert self.cache_len % kv_page_size == 0, \
            (self.cache_len, kv_page_size)
        if kv_pages is not None:
            assert kv_pages >= 2, "pool needs the null page + 1 usable page"
            return PagedKVConfig(page_size=kv_page_size, num_pages=kv_pages,
                                 pages_per_seq=self.cache_len // kv_page_size)
        return PagedKVConfig.for_budget(self.num_slots * self.cache_len,
                                        kv_page_size, self.cache_len)

    # -------------------------------------------------------- page table ----
    @property
    def page_tables(self) -> np.ndarray:
        """Dense (num_slots, pages_per_seq) logical->physical view of the
        two-level table (tests/diagnostics; dispatches use the bucketed
        :meth:`_dispatch_table`)."""
        return self._ptab.dense()

    def _dispatch_table(self) -> np.ndarray:
        """The page table a decode/verify dispatch sees.  Budgeted mode
        pins the full ``pages_per_seq`` width — its compile-count
        invariant (``chunk_compilations() <= 2 * buckets``) admits no
        per-width retraces.  Classic paged mode buckets the width to a
        monotonically-growing power of two covering every live mapping,
        so short requests dispatch small gathers and the programs
        recompile O(log pages_per_seq) times over the engine's life."""
        if self.max_batch_tokens is not None:
            return self._ptab.dense()
        w = max(self._ptab.max_width(), 1)
        while self._table_width < w:
            self._table_width *= 2
        self._table_width = min(self._table_width,
                                self.paging.pages_per_seq)
        return self._ptab.dense(self._table_width)

    # ------------------------------------------------------------ jitted ----
    def _tp_wrap(self, fn, in_kinds: str, out_kinds: str,
                 donate: tuple = ()):
        """``tp.wrap`` with per-argument specs named by kind: ``p`` the
        params pytree, ``c`` a cache pytree (prefix spec — every 5-D
        leaf carries kv_heads at dim 3), ``r`` replicated.  Inactive
        plans compile to a plain ``jax.jit`` with identical semantics."""
        if not self.tp.active:
            return tp_mod.wrap(self.tp, fn, (), (), donate)
        m = {"p": self._pp, "c": self._cc, "r": PSpec()}
        ins = tuple(m[k] for k in in_kinds)
        outs = (m[out_kinds] if len(out_kinds) == 1
                else tuple(m[k] for k in out_kinds))
        return tp_mod.wrap(self.tp, fn, ins, outs, donate)

    def _build_step(self):
        cfg, run = self.cfg, self.run

        if self.paging is not None:
            def step_paged(params, cache, token, pos, page_table):
                logits, cache = decode_step(params, cache, token, pos, cfg,
                                            run, page_table=page_table)
                return logits[:, 0], cache

            return self._tp_wrap(step_paged, "pcrrr", "rc")

        def step(params, cache, token, pos):
            # per-slot positions: (B,) — decode_step handles scalar or vector
            logits, cache = decode_step(params, cache, token, pos, cfg, run)
            return logits[:, 0], cache

        return self._tp_wrap(step, "pcrr", "rc")

    def _build_decode_n(self, chunk: Optional[int] = None):
        cfg, run = self.cfg, self.run
        cache_len = self.cache_len
        chunk = self.decode_chunk if chunk is None else chunk

        if self.paging is not None:
            def step_n_paged(params, cache, token, pos, remaining, done,
                             eos, temps, key, page_table, limit):
                return decode_n(params, cache, token, pos, remaining, done,
                                eos, temps, key, cfg, run, chunk, cache_len,
                                page_table=page_table, limit=limit)

            return self._tp_wrap(step_n_paged, "pc" + "r" * 9, "rcrrrrr",
                                 donate=(1,))

        def step_n(params, cache, token, pos, remaining, done, eos, temps,
                   key):
            return decode_n(params, cache, token, pos, remaining, done, eos,
                            temps, key, cfg, run, chunk, cache_len)

        return self._tp_wrap(step_n, "pc" + "r" * 7, "rcrrrrr",
                             donate=(1,))

    def _build_insert(self):
        if self.paging is not None:
            ps = self.paging.page_size

            def insert_paged(pool_cache, one_cache, page_ids):
                # scatter the request's prefilled lines into its pages;
                # pad-tail pages ride on the null page (id 0), whose
                # garbage is never read unmasked
                def put(pool_leaf, one_leaf):
                    g, _, length = one_leaf.shape[:3]
                    n = page_ids.shape[0]
                    lines = one_leaf[:, 0]
                    if n * ps > length:
                        lines = jnp.pad(
                            lines, ((0, 0), (0, n * ps - length),
                                    (0, 0), (0, 0)))
                    pages = lines.reshape(g, n, ps, *lines.shape[2:])
                    return pool_leaf.at[:, page_ids].set(
                        pages.astype(pool_leaf.dtype))
                return jax.tree.map(put, pool_cache, one_cache)

            return self._tp_wrap(insert_paged, "ccr", "c", donate=(0,))

        def insert(batch_cache, one_cache, slot):
            def put(batch_leaf, one_leaf):
                return jax.lax.dynamic_update_slice_in_dim(
                    batch_leaf, one_leaf.astype(batch_leaf.dtype), slot,
                    axis=1)
            return jax.tree.map(put, batch_cache, one_cache)

        return self._tp_wrap(insert, "ccr", "c", donate=(0,))

    def _build_prefill(self):
        cfg, run, cache_len = self.cfg, self.run, self.cache_len
        # paged mode: prefill only materializes the prompt's own lines
        # (cache_len=None -> S slots); the page scatter does the placement
        paged = self.paging is not None

        if getattr(self, "_front_pad", False):
            # SSM/hybrid bucketed prefill: real tokens sit at a traced
            # chunk-aligned front offset, so one program per bucket
            # serves every prompt length (front_pad/num_real are traced)
            def prefill_front_fn(params, tokens, front_pad, num_real,
                                 last_pos):
                return prefill(params, {"tokens": tokens}, cfg, run,
                               cache_len=cache_len, last_pos=last_pos,
                               front_pad=front_pad, num_real=num_real)

            return self._tp_wrap(prefill_front_fn, "prrrr", "rc")

        def prefill_fn(params, tokens, last_pos):
            return prefill(params, {"tokens": tokens}, cfg, run,
                           cache_len=None if paged else cache_len,
                           last_pos=last_pos)

        return self._tp_wrap(prefill_fn, "prr", "rc")

    def _build_suffix_prefill(self):
        """Jitted suffix prefill for prefix-cache hits: compiles once per
        (bucketed) suffix length; ``start`` and the page table are traced
        so any prefix depth reuses the same program."""
        cfg, run = self.cfg, self.run

        def suffix_fn(params, cache, tokens, page_table, start, last_pos):
            return prefill_suffix(params, {"tokens": tokens}, cache,
                                  page_table, start, cfg, run,
                                  last_pos=last_pos)

        return self._tp_wrap(suffix_fn, "pcrrrr", "rc")

    @staticmethod
    def _scatter_chunk(cache, slices, pages, offs):
        """Write a chunk's KV lines into the pool at per-line
        (page, offset) targets.  Unlike the whole-page admission insert,
        a chunk may start and end mid-page, so the write is
        line-granular; pad lines target the null page (harmless
        duplicate writes).  Traced inside the chunk/mixed programs."""
        def put(pool_leaf, one_leaf):
            lines = one_leaf[:, 0].astype(pool_leaf.dtype)   # (G,C,K,Dh)
            return pool_leaf.at[:, pages, offs].set(lines)
        return jax.tree.map(put, cache, slices)

    def _build_chunk_prefill(self):
        """Jitted standalone prefill chunk (budgeted mode): compute the
        chunk against the pool, scatter its lines back, return (logits,
        cache) — ONE dispatch per chunk.  Compiles once per chunk bucket:
        ``start``/``last_pos`` and the page-table row (always
        ``pages_per_seq`` wide) are traced, so every chunk of every
        request at every depth reuses the same O(buckets) programs."""
        cfg, run = self.cfg, self.run

        def chunk_fn(params, cache, tokens, page_table, start, last_pos,
                     pages, offs):
            logits, slices = prefill_chunk(
                params, {"tokens": tokens}, cache, page_table, start, cfg,
                run, last_pos=last_pos)
            return logits, DecodeEngine._scatter_chunk(
                cache, slices, pages, offs)

        return self._tp_wrap(chunk_fn, "pc" + "r" * 6, "rc", donate=(1,))

    def _build_mixed_step(self):
        """THE budgeted serve step: one dispatch running a prefill chunk
        (compute + line scatter) and a full ``decode_chunk``-token decode
        over every live lane — streaming a prefill must not drop decode
        lanes to 1 token/dispatch.  The chunk reads the pre-decode,
        pre-scatter pool state and its pages are disjoint from the
        lanes' write targets, so fusing changes no math — greedy output
        stays bit-identical to running the dispatches back-to-back."""
        cfg, run, cache_len = self.cfg, self.run, self.cache_len
        num_tokens = self.decode_chunk

        def mixed(params, cache, token, pos, remaining, done, eos, temps,
                  key, page_table, limit, c_tokens, c_row, c_start,
                  c_last, c_pages, c_offs):
            c_logits, c_slices = prefill_chunk(
                params, {"tokens": c_tokens}, cache, c_row, c_start, cfg,
                run, last_pos=c_last)
            cache = DecodeEngine._scatter_chunk(
                cache, c_slices, c_pages, c_offs)
            out = decode_n(params, cache, token, pos, remaining, done,
                           eos, temps, key, cfg, run, num_tokens,
                           cache_len, page_table=page_table, limit=limit)
            return out + (c_logits,)

        return self._tp_wrap(mixed, "pc" + "r" * 15, "rcrrrrrr",
                             donate=(1,))

    def _build_verify(self):
        """Jitted speculative verification: score ``last_tok`` plus up to
        ``speculate`` drafts per lane in ONE dispatch.  Row ``j``'s
        logits are bitwise-identical to a sequential ``decode_step`` at
        position ``pos0+j``, so the device argmax returned here IS the
        greedy token stream — accepting the longest agreeing run keeps
        greedy output bit-identical to non-speculative decode.  Raw
        logits ride along for temperature-mode rejection sampling."""
        cfg, run = self.cfg, self.run

        def verify(params, cache, tokens, pos0, pages, offs, page_table):
            logits, cache = verify_tokens(params, cache, tokens, pos0,
                                          pages, offs, page_table, cfg,
                                          run)
            return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                    logits, cache)

        return self._tp_wrap(verify, "pc" + "r" * 5, "rrc", donate=(1,))

    def _build_mixed_verify(self):
        """Budgeted serve step with speculation: one dispatch running a
        prefill chunk (compute + line scatter) and the speculative verify
        over every live lane — same fusion (and same disjoint-pages
        argument) as ``_build_mixed_step``, with verify in the decode
        role."""
        cfg, run = self.cfg, self.run

        def mixed(params, cache, tokens, pos0, pages, offs, page_table,
                  c_tokens, c_row, c_start, c_last, c_pages, c_offs):
            c_logits, c_slices = prefill_chunk(
                params, {"tokens": c_tokens}, cache, c_row, c_start, cfg,
                run, last_pos=c_last)
            cache = DecodeEngine._scatter_chunk(
                cache, c_slices, c_pages, c_offs)
            logits, cache = verify_tokens(params, cache, tokens, pos0,
                                          pages, offs, page_table, cfg,
                                          run)
            return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                    logits, cache, c_logits)

        return self._tp_wrap(mixed, "pc" + "r" * 11, "rrcr", donate=(1,))

    def _resolve_buckets(self, spec):
        """Power-of-two prompt-length buckets, or None (exact-length
        prefill).  Full-attention configs pad the prompt TAIL (causal
        masking keeps pads out of real state).  SSM/hybrid configs pad
        the FRONT instead (``models.model.prefill`` front-pad mode): the
        pad lands at a chunk-aligned offset whose masked positions are
        the SSD scan's identity, so the recurrent state stays
        bit-identical to the exact path.  Still refused — silently
        degrading to exact prefill — for sliding-window ring caches (the
        wrapped slot layout has no pad region), sinusoidal embeddings
        (added before the front shift is known), and Pallas prefill (the
        fused kernels take no validity mask)."""
        self._front_pad = False
        if not spec:
            return None
        if self.cfg.sliding_window is not None:
            return None
        attn_only = self.cfg.attn_every == 1 and self.cfg.ssm is None
        if not attn_only:
            if self.cfg.pos_embedding == "sinusoidal" or self.run.use_pallas:
                return None
            self._front_pad = True
        if spec == "auto":
            out, b = [], 32
            while b < self.cache_len:
                out.append(b)
                b *= 2
            out.append(self.cache_len)
            return tuple(out)
        out = tuple(sorted({int(b) for b in spec}))
        assert out and 0 < out[0] and out[-1] <= self.cache_len, out
        if out[-1] < self.cache_len:       # any resume prompt must fit
            out = out + (self.cache_len,)
        return out

    @property
    def prefill_buckets(self):
        return self._buckets

    def prefill_compilations(self) -> int:
        """Distinct prefill programs compiled so far — one per bucket on
        the bucketed path.  The exact-length path runs the eager
        (unjitted) prefill and never touches this cache, so it reports
        0 there."""
        return int(self._prefill_fn._cache_size())

    def chunk_compilations(self) -> int:
        """Distinct chunked-prefill programs compiled so far (budgeted
        mode): one per chunk bucket for the standalone dispatch plus one
        per bucket for the fused decode+chunk step — O(buckets), never
        O(prompt lengths x depths)."""
        if self.max_batch_tokens is None:
            return 0
        return (int(self._chunk_fn._cache_size())
                + int(self._mixed_step._cache_size()))

    def tp_stats(self) -> dict:
        """Tensor-parallel shard stats behind ``sdiag``'s TP section:
        the resolved plan, per-device page-pool occupancy, and the
        cross-shard reduction count one decode token pays."""
        plan = self.tp
        out = {"tp": plan.tp,
               "active": plan.active,
               "plan": plan.describe(self.cfg),
               "devices": [str(d) for d in plan.devices()],
               "notices": list(plan.notices),
               "psums_per_token": plan.psums_per_token(self.cfg)}
        if self.paging is not None:
            out["kv_pages_in_use"] = [
                int(n) for n in self.pool_view.in_use_vector()]
            out["kv_pages_total"] = self.paging.usable_pages
        return out

    def _update_pool_gauges(self):
        """Per-device ``serve_kv_pages_in_use`` gauges (one series per
        shard; single-shard engines report the default device)."""
        if self.paging is None:
            return
        g = self.metrics.gauge(
            METRIC_SERVE_KV_PAGES_IN_USE,
            "KV pages with >= 1 holder, per device")
        devs = self.tp.devices() or jax.devices()[:1]
        for k, n in enumerate(self.pool_view.in_use_vector()):
            dev = devs[k] if k < len(devs) else f"shard{k}"
            g.set(int(n), device=str(dev))

    # ----------------------------------------------------------- tracing ----
    def _trace_root(self, req: Request):
        trace = getattr(req, "_trace", None)
        return trace.get("root") if trace else None

    def _trace_decode_end(self, req: Request, reason: Optional[str] = None):
        """Close the request's current DECODE span (finish, preemption,
        or page starvation); ``reason`` also lands as a root-span event,
        so PREEMPT/STARVED transitions are visible on the timeline."""
        tr = self.tracer
        if tr is None:
            return
        trace = getattr(req, "_trace", None)
        if not trace:
            return
        dec = trace.pop("decode", None)
        if dec is not None:
            tr.end(dec, tokens=len(req.output),
                   **({"stop": reason} if reason else {}))
        root = trace.get("root")
        if root is not None and reason:
            tr.event(reason, root)

    # ------------------------------------------------------------ public ----
    def submit(self, req: Request):
        # generation past the cache boundary truncates in _maybe_finish,
        # which also guarantees a preemption victim's resume prefill
        # (prompt + partial output) still fits the cache
        assert len(req.prompt) < self.cache_len, "prompt exceeds cache"
        tr = self.tracer
        if tr is not None:
            req._t_submit = tr.clock()
            root = tr.begin(
                f"request {req.rid}", cat="request",
                track=(f"serving:{req.tenant}", f"req {req.rid}"),
                rid=req.rid, tenant=req.tenant, qos=req.qos,
                prompt_tokens=len(req.prompt),
                max_new_tokens=req.max_new_tokens)
            req._trace = {"root": root}
            tr.event("SUBMIT", root)
        if self.paging is not None:
            # worst-case page footprint, for GrpTRES kv_pages caps
            req._est_pages = pages_for(
                min(len(req.prompt) + req.max_new_tokens + 1,
                    self.cache_len), self.paging.page_size)
            # a footprint the pool can never hold would queue forever
            # (page-budget admission keeps vetoing it): refuse loudly
            assert req._est_pages <= self.paging.usable_pages, \
                (f"request {req.rid}: needs {req._est_pages} pages, pool "
                 f"has {self.paging.usable_pages}")
        self.admission.submit(req)

    def active(self) -> int:
        """Requests currently holding a decode slot."""
        return sum(r is not None for r in self.slots)

    def _capacity(self, slot: int) -> int:
        """KV lines slot may write before growing (paged) / cache_len."""
        if self.paging is None:
            return self.cache_len
        return len(self._slot_pages[slot]) * self.paging.page_size

    def _resume_tokens(self, req) -> np.ndarray:
        """The token sequence a (possibly resumed) request prefills:
        prompt plus retained partial output, minus the last token (which
        re-decodes)."""
        if req.output:
            return np.concatenate(
                [req.prompt, np.asarray(req.output[:-1], np.int32)])
        return np.asarray(req.prompt, np.int32)

    def _fits_pages(self, req) -> bool:
        """Page-budget admission predicate: the resume/prefill pages must
        fit the free pool right now (decode growth is handled later).
        With the prefix cache, cached prefix pages cost nothing and
        LRU-evictable cached pages count as free — so a request whose
        prompt is mostly cached admits into a pool that looks full."""
        toks = self._resume_tokens(req)
        need = pages_for(len(toks), self.paging.page_size)
        # per-shard budget vector: a logical page is grantable only when
        # EVERY shard can hold its slice, so admission gates on the min
        budget = self.pool_view.min_available()
        if self.prefix is not None and need > budget:
            # matched pages cost nothing, and evictable cached pages
            # count as free — EXCLUDING the match itself: placement pins
            # it before evicting, so a page cannot serve as both shared
            # mapping and eviction fodder (counting it twice would admit
            # requests that then bounce off allocation forever)
            matched = len(self.prefix.match(toks))
            need -= matched
            budget += max(self.prefix.evictable_pages() - matched, 0)
        return need <= budget

    def pending(self) -> int:
        return self.admission.pending()

    def load(self) -> int:
        """Queue depth: slot holders plus queued requests — the router's
        spill signal and the autoscaler's emptiest-replica criterion."""
        return self.active() + self.pending()

    def radix_occupancy(self) -> dict:
        """Prefix-index occupancy for the router/sdiag surface: cached
        pages currently indexed and how many of them are evictable."""
        if self.prefix is None:
            return {"nodes": 0, "evictable_pages": 0}
        return {"nodes": self.prefix.nodes,
                "evictable_pages": self.prefix.evictable_pages()}

    def drain(self) -> list:
        """Evict everything and hand it back: each in-flight request
        leaves through the preemption path (pages released, slot hold
        returned, partial output retained — mid-prefill partials included,
        since partials hold their slot too), then every queued request is
        popped.  Returns all of them in arrival order, ready to resubmit
        elsewhere; greedy decode is batch-independent, so a drained
        request finishes bit-identical on whichever replica resumes it."""
        for req in [r for r in self.slots if r is not None]:
            self._evict(req)
        drained = []
        for t in self.admission.tenants.values():
            drained.extend(t.queue)
            t.queue.clear()
        drained.sort(key=lambda r: r._seq)
        return drained

    @property
    def queue(self) -> list:
        """Flattened view of all tenant queues (compat/diagnostics)."""
        return [r for t in self.admission.tenants.values() for r in t.queue]

    def _free_slots(self):
        return [i for i, r in enumerate(self.slots) if r is None]

    def _admit(self):
        """Fill free slots from the admission controller; then let blocked
        high-QOS requests preempt one preemptable slot each.  In paged
        mode the pick is additionally gated on the prefill fitting the
        free page pool (page-budget admission)."""
        eligible = self._fits_pages if self.paging is not None else None
        for slot in self._free_slots():
            req = self.admission.next_request(eligible=eligible)
            if req is None:
                return
            self._place(slot, req)
        # QOS preemption: each blocked preempting request evicts exactly
        # one victim slot (bounded per pass against cyclic QOS tables)
        for _ in range(self.num_slots):
            running = [r for r in self.slots if r is not None]
            pick = self.admission.next_preempting(running)
            if pick is None:
                return
            req, victim = pick
            slot = self._evict(victim)
            self._place(slot, req)

    def _place(self, slot: int, req: Request):
        """Route an admitted request: budgeted mode enqueues a partial
        prefill (chunked across iterations), classic mode prefills the
        whole prompt in one blocking dispatch."""
        if self.max_batch_tokens is not None:
            self._start_prefill(slot, req)
        else:
            self._prefill_into(slot, req)

    def _alloc_or_evict(self, need: int):
        """Allocate ``need`` pages, LRU-evicting unpinned cached prefixes
        to make room when the free pool is short (the capacity-pressure
        valve that fires BEFORE scavenger preemption)."""
        got = self.allocator.alloc(need)
        if got is None and self.prefix is not None:
            freed = self.prefix.evict(need - self.pool_view.min_available())
            if freed:
                self.metrics.counter(
                    METRIC_SERVE_PREFIX_EVICTIONS,
                    "cached prefix pages LRU-evicted").inc(freed)
                got = self.allocator.alloc(need)
        return got

    def _prefill_into(self, slot: int, req: Request):
        """Prefill a request into a free slot.  A preempted request
        resumes: its prompt *and* retained partial output are prefilled,
        so decode continues from exactly where the eviction stopped.

        Paged mode allocates exactly ``ceil(len(toks)/page_size)`` pages
        first (the bucketed pad tail allocates and charges NOTHING — it
        scatters onto the null page) and bails back to the queue if the
        pool cannot hold the prefill.  With the prefix cache, the longest
        cached prefix maps read-only (one allocator ref per page), only
        the suffix allocates/prefills, and the request's complete prompt
        pages join the radix index afterwards."""
        toks = self._resume_tokens(req)
        tr = self.tracer
        root = self._trace_root(req)
        resume = bool(req.output)
        psp = None
        if tr is not None:
            if root is not None:
                tr.event("ADMIT", root, slot=slot)
            psp = tr.begin("PREFILL", cat="prefill", parent=root,
                           tokens=len(toks), resume=resume)
        pages = shared = None
        if self.paging is not None:
            ps = self.paging.page_size
            if self.prefix is not None:
                # acquire BEFORE the private alloc: matched pages are
                # unpinned until then, and the eviction below must not
                # free what we are about to map
                shared = self.prefix.acquire(self.prefix.match(toks))
            n_shared = len(shared) if shared else 0
            priv = self._alloc_or_evict(
                pages_for(len(toks), ps) - n_shared)
            if priv is None and shared:
                # the shortfall may only be coverable by the matched
                # pages themselves: abandon the reuse (unpin, making the
                # match eviction fodder) and retry as a plain prefill —
                # correctness beats sharing
                self.allocator.free(shared)
                shared, n_shared = [], 0
                priv = self._alloc_or_evict(pages_for(len(toks), ps))
            if priv is None:
                # preemption admitted past the page gate but the pool
                # still can't hold the prefill: back to the queue
                if shared:
                    self.allocator.free(shared)      # unpin the match
                if psp is not None:
                    tr.end(psp, aborted=True)
                self.admission.release(req)
                self.admission.requeue(req)
                return
            pages = (shared or []) + priv
            if self.prefix is not None:
                # count hits/misses only for PLACED admissions, so a
                # requeue bounce cannot inflate the reuse figures
                if shared:
                    self.metrics.counter(
                        METRIC_SERVE_PREFIX_HITS,
                        "admissions reusing cached prefix pages").inc()
                    self.metrics.counter(
                        METRIC_SERVE_PREFIX_REUSED_TOKENS,
                        "prompt tokens served from cached pages").inc(
                        n_shared * ps)
                else:
                    self.metrics.counter(
                        METRIC_SERVE_PREFIX_MISSES,
                        "admissions with no cached prefix").inc()
        with self.metrics.timer("serve_prefill_seconds", "prefill latency"):
            if shared:
                # prefix hit: prefill ONLY the suffix, attending to the
                # shared pages through a prefix-only page-table row.  The
                # row width buckets to the next power of two >= the match
                # depth, so the gather/attention cost scales with the
                # ACTUAL prefix, not cache_len (compiles once per
                # (suffix bucket, prefix bucket) pair)
                start = n_shared * self.paging.page_size
                suffix = toks[start:]
                P = len(suffix)
                L = P if self._buckets is None else next(
                    b for b in self._buckets if b >= P)
                padded = np.zeros(L, np.int32)
                padded[:P] = suffix
                pb = 1
                while pb < n_shared:
                    pb *= 2
                row = np.full(pb, NULL_PAGE, np.int32)
                row[:n_shared] = shared
                logits, cache1 = self._suffix_prefill_fn(
                    self.params, self.cache, jnp.asarray(padded)[None],
                    jnp.asarray(row)[None], jnp.asarray(start, jnp.int32),
                    jnp.asarray(P - 1, jnp.int32))
            elif self._buckets is not None:
                P = len(toks)
                L = next(b for b in self._buckets if b >= P)
                padded = np.zeros(L, np.int32)
                if self._front_pad:
                    # SSM/hybrid: pad the FRONT, at a chunk-aligned
                    # offset so the real tokens' SSD chunk boundaries
                    # match the unpadded run bit-for-bit
                    Q = self.cfg.ssm.chunk if self.cfg.ssm else 1
                    f = ((L - P) // Q) * Q
                    padded[f:f + P] = toks
                    logits, cache1 = self._prefill_fn(
                        self.params, jnp.asarray(padded)[None],
                        jnp.asarray(f, jnp.int32),
                        jnp.asarray(P, jnp.int32),
                        jnp.asarray(f + P - 1, jnp.int32))
                else:
                    padded[:P] = toks
                    logits, cache1 = self._prefill_fn(
                        self.params, jnp.asarray(padded)[None],
                        jnp.asarray(P - 1, jnp.int32))
            else:
                L = len(toks)
                prompt = jnp.asarray(toks, jnp.int32)[None]
                logits, cache1 = prefill(
                    self.params, {"tokens": prompt}, self.cfg, self.run,
                    cache_len=None if self.paging is not None
                    else self.cache_len)
            # sync inside the timed region: dispatch is async, and the
            # very next consumer (argmax below) would absorb the device
            # wait — serve_prefill_seconds must report real latency
            jax.block_until_ready(logits)
        if self.paging is not None:
            # scatter the prefilled lines into the privately-owned pages
            # (suffix-only on a prefix hit — shared pages are READ-ONLY
            # and never written); the bucketed pad tail's pages are the
            # null page
            ps = self.paging.page_size
            page_ids = np.full(pages_for(L, ps), NULL_PAGE, np.int32)
            page_ids[:len(priv)] = priv
            self.cache = self._insert(self.cache, cache1,
                                      jnp.asarray(page_ids))
            self._ptab.clear(slot)
            self._ptab.set_range(slot, 0, pages)
            self._slot_pages[slot] = pages
            if self.prefix is not None:
                # donate the complete prompt pages to the radix index
                # (the index takes its own refs) and register this
                # request as a holder of everything it maps
                self.prefix.insert(toks, pages)
                for p in pages:
                    self._page_holders[p] = \
                        self._page_holders.get(p, 0) + 1
            # GrpTRES holds the request's WORST-CASE footprint for its
            # whole residency (SLURM-style reservation): decode growth
            # then cannot push a tenant past its kv_pages cap
            self.admission.adjust_pages(req, req._est_pages)
        else:
            # write this request's cache slice into the batch cache through
            # the pre-jitted donated insert (one compile, zero retraces)
            self.cache = self._insert(self.cache, cache1, slot)
        if req.output:
            tok = int(req.output[-1])      # resume: last token re-decodes
        else:
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
        self.slots[slot] = req
        req._slot = slot
        self.pos[slot] = len(toks)
        self.last_tok[slot] = tok
        self.remaining[slot] = req.max_new_tokens - len(req.output)
        if self.spec is not None:
            # full context incl. the pending last token (resume-safe:
            # toks is prompt+output[:-1], tok the re-decoding last)
            self.spec.begin(slot, np.append(toks, np.int32(tok)))
        # the prefilled KV residency the tenant pays for: dense lines, or
        # (paged) the pages actually pinned — amortized across holders
        # when the prefix cache shares them
        if self.paging is not None:
            self.admission.charge(req, kv_pages=self._billed_pages(slot))
        else:
            self.admission.charge(req, kv_tokens=len(toks))
        self.metrics.counter("serve_requests_admitted").inc()
        self.metrics.counter(
            METRIC_SERVE_TENANT_ADMITTED,
            "admissions per tenant").inc(tenant=req.tenant)
        if tr is not None:
            now = tr.clock()
            attrs = {"bucket": int(L)}
            if self.paging is not None:
                n_sh = len(shared) if shared else 0
                attrs.update(prefix_pages=n_sh,
                             pages_allocated=len(priv))
            tr.end(psp, ts=now, **attrs)
            if resume:
                if root is not None:
                    tr.event("RESUME", root, slot=slot)
            else:
                # the first output token comes from the prefill argmax,
                # so TTFT = admit -> end of the prefill sync (resumes
                # already produced their first token pre-eviction)
                if root is not None:
                    tr.event("first_token", root)
                if req._t_admit is not None:
                    tr.slo.ttft(now - req._t_admit, req.tenant, req.qos)
            req._t_last = now
            req._trace["decode"] = tr.begin(
                "DECODE", cat="decode", parent=root, slot=slot)
        self._maybe_finish(slot)

    def _hold_pages(self, req: Request, delta: int):
        """Budgeted mode: move the request's GrpTRES kv_pages hold by
        ``delta`` (chunk-by-chunk TRUE holdings, not the classic
        worst-case reservation).  No-op in classic mode, where
        ``_prefill_into`` reserves ``_est_pages`` up front."""
        if self.max_batch_tokens is None or delta == 0:
            return
        self.admission.adjust_pages(req, delta)
        req._held_pages += delta

    def _billed_pages(self, slot: int) -> float:
        """KV-page residency this slot bills per step: each page costs
        ``1 / holders``, so a prefix page shared by N live requests bills
        once across all of them (plain paged mode: every page has one
        holder and this is exactly the page count)."""
        if self.prefix is None:
            return len(self._slot_pages[slot])
        return sum(1.0 / self._page_holders[p]
                   for p in self._slot_pages[slot])

    def _release_pages(self, slot: int, req: Request):
        """Paged mode: drop the slot's page references (private pages
        return to the pool; shared prefix pages survive in the radix
        index — eviction-aware reclaim still sees freed pages
        immediately) and return the worst-case GrpTRES hold."""
        if self.paging is None:
            return
        pages = self._slot_pages[slot]
        if pages:
            self.allocator.free(pages)
            if self.prefix is not None:
                for p in pages:
                    h = self._page_holders.get(p, 0) - 1
                    if h > 0:
                        self._page_holders[p] = h
                    else:
                        self._page_holders.pop(p, None)
        if self.max_batch_tokens is not None:
            # budgeted mode holds true pages, grown chunk-by-chunk
            self.admission.adjust_pages(req, -req._held_pages)
            req._held_pages = 0
        else:
            self.admission.adjust_pages(req, -req._est_pages)
        self._slot_pages[slot] = []
        self._ptab.clear(slot)

    def _vacate(self, victim: Request) -> int:
        """Shared eviction bookkeeping: clear the slot, free its pages,
        return the slot/page holds, and requeue the request with partial
        output retained.  Returns the freed slot index (O(1) via the
        request's slot tag)."""
        slot = victim._slot
        assert slot >= 0 and self.slots[slot] is victim, (slot, victim.rid)
        if self.spec is not None:
            self.spec.release(slot)
        self.slots[slot] = None
        victim._slot = -1
        self._release_pages(slot, victim)
        self.admission.release(victim)
        self.admission.requeue(victim)
        return slot

    def _evict(self, victim: Request) -> int:
        """Evict a running request from its slot; it requeues at the head
        of its QOS class in its tenant queue with partial output retained.
        A mid-prefill partial (budgeted mode) is likewise preemptible —
        eviction lands at a chunk boundary, so its already-written pages
        simply free and the resume prefill replays the prompt."""
        victim.preemptions += 1
        self.metrics.counter(
            METRIC_SERVE_PREEMPTIONS, "evicted decode slots").inc()
        part = self._prefill_slots.get(victim._slot)
        if part is not None and part.req is victim:
            slot = victim._slot
            self._requeue_partial(part, "PREEMPT")
            return slot
        self._trace_decode_end(victim, "PREEMPT")
        return self._vacate(victim)

    def _finish(self, slot: int):
        req = self.slots[slot]
        req.done = True
        if self.spec is not None or self.index_generated:
            seq = np.concatenate([np.asarray(req.prompt, np.int32),
                                  np.asarray(req.output, np.int32)])
            if self.spec is not None:
                self.spec.release(slot)
                self.spec.observe(seq)
            if self.index_generated and self.prefix is not None:
                # KV lines exist for every token but the last (its line
                # would have been written by the next decode), so index
                # seq[:-1]; pages past the prompt carry generated tokens
                self.prefix.insert(seq[:-1], self._slot_pages[slot],
                                   generated_from=len(req.prompt))
        self.slots[slot] = None
        req._slot = -1
        self._release_pages(slot, req)
        self.admission.release(req)
        self.metrics.counter("serve_requests_completed").inc()
        tr = self.tracer
        if tr is not None:
            self._trace_decode_end(req)
            trace = getattr(req, "_trace", None)
            root = trace.pop("root", None) if trace else None
            if root is not None:
                tr.event("FINISH", root)
                tr.end(root, tokens=len(req.output))
            if req._t_submit is not None:
                tr.slo.e2e(tr.clock() - req._t_submit, req.tenant, req.qos)

    def _maybe_finish(self, slot: int):
        req = self.slots[slot]
        if req is None:
            return
        if (req.eos_id is not None and req.output
                and req.output[-1] == req.eos_id) or self.remaining[slot] <= 0 \
                or self.pos[slot] >= self.cache_len - 1:
            self._finish(slot)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        """Host-side per-slot sampling (fused=False path).
        logits: (num_slots, V)."""
        temps = np.array([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.num_slots)], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        # split unconditionally — one key per generated token, exactly the
        # stream decode_n consumes, so host and fused paths stay
        # interchangeable even when greedy and sampled slots mix
        self._key, sub = jax.random.split(self._key)
        if (temps <= 0).all():
            return greedy.astype(np.int32)
        t = jnp.maximum(jnp.asarray(temps), 1e-4)[:, None]
        sampled = np.asarray(
            jax.random.categorical(sub, logits.astype(jnp.float32) / t,
                                   axis=-1))
        return np.where(temps > 0, sampled, greedy).astype(np.int32)

    # ------------------------------------------------------- page growth ----
    def _reclaim_one_victim(self, requester: Request) -> bool:
        """Pool-exhaustion scavenger reclaim: evict ONE running request
        the requester's QOS may preempt (lowest QOS first, worst
        fair-share standing, most recent admission — the same victim rule
        admission preemption uses), freeing its pages.  Returns whether a
        victim was evicted."""
        qos = self.admission.qos_table.get(requester.qos)
        if qos is None:
            return False
        victims = [r for r in self.slots
                   if r is not None and r is not requester
                   and qos.can_preempt(r.qos)]
        if not victims:
            return False
        self._evict(self.admission.pick_victim(victims))
        return True

    def _requeue_starved(self, slot: int):
        """A slot the pool starved out goes back to its tenant queue with
        partial output retained (resume-exact, like a preemption victim);
        page-budget admission re-admits it once pages free up."""
        self._trace_decode_end(self.slots[slot], "STARVED")
        self._vacate(self.slots[slot])
        self.metrics.counter(
            "serve_page_starvations",
            "slots requeued on page-pool exhaustion").inc()

    def _ensure_pages(self, active: list, steps: Optional[int] = None):
        """Grow each live slot's allocation to cover the coming chunk
        (on-demand growth at decode-time page boundaries).  The +2
        headroom keeps the slot's freeze boundary strictly beyond the
        chunk, so a fully-grown paged slot freezes exactly where the
        dense cache would — bit-identical stopping.  On pool exhaustion,
        reclaim via one-victim scavenger eviction; a slot that still
        cannot cover even its current position requeues starved (its
        ``limit`` would otherwise let it write the null page)."""
        ps = self.paging.page_size
        steps_cap = self.decode_chunk if steps is None else steps
        for i in list(active):
            req = self.slots[i]
            if req is None:                    # evicted by a reclaim below
                active.remove(i)
                continue
            # a nearly-finished slot only needs pages for the tokens it
            # may still generate — don't pin headroom it can never use
            steps = min(steps_cap, max(int(self.remaining[i]), 1))
            target = min(int(self.pos[i]) + steps + 2, self.cache_len)
            need = pages_for(target, ps) - len(self._slot_pages[i])
            if need <= 0:
                continue
            # growth pressure relief, in escalation order: LRU-evict
            # unpinned cached prefixes first, scavenger preemption only
            # after the index has nothing left to give
            got = self._alloc_or_evict(need)
            if got is None and self._reclaim_one_victim(req):
                got = self._alloc_or_evict(need)
            if got is None:                    # partial growth: best effort
                got = self.allocator.alloc(
                    min(need, self.pool_view.min_available()))
            if got:
                if self.prefix is not None:
                    for p in got:
                        self._page_holders[p] = \
                            self._page_holders.get(p, 0) + 1
                # classic mode reserved the worst-case footprint at
                # admission (this is a no-op there); budgeted mode grows
                # the TRUE hold page-by-page
                self._hold_pages(req, len(got))
                n0 = len(self._slot_pages[i])
                self._slot_pages[i].extend(got)
                self._ptab.set_range(i, n0, got)
            if self._capacity(i) <= int(self.pos[i]):
                # starved: not even the current token's page
                self._requeue_starved(i)
                active.remove(i)

    # --------------------------------------- chunked prefill (budgeted) ----
    def _start_prefill(self, slot: int, req: Request):
        """Admit a request as a *partial prefill*: it takes the slot (so
        the existing eviction/requeue paths see it) but decodes nothing
        until ``_step_budgeted`` has streamed its whole prompt through
        chunk dispatches.  With the prefix cache, matched pages map
        read-only immediately and ``pos_filled`` starts past them — the
        chunks only ever cover the suffix."""
        toks = self._resume_tokens(req)
        tr = self.tracer
        root = self._trace_root(req)
        span = None
        if tr is not None:
            if root is not None:
                tr.event("ADMIT", root, slot=slot)
            span = tr.begin("PREFILL", cat="prefill", parent=root,
                            tokens=len(toks), resume=bool(req.output),
                            chunked=True)
        shared = []
        if self.prefix is not None:
            shared = self.prefix.acquire(self.prefix.match(toks)) or []
            # reuse is decided (and counted) at admission: the pages are
            # pinned from here on, unlike the classic path there is no
            # later abandon-the-match fallback — chunks allocate one
            # bucket's worth at a time, so the all-or-nothing shortfall
            # that forces it cannot arise
            if shared:
                self.metrics.counter(
                    METRIC_SERVE_PREFIX_HITS,
                    "admissions reusing cached prefix pages").inc()
                self.metrics.counter(
                    METRIC_SERVE_PREFIX_REUSED_TOKENS,
                    "prompt tokens served from cached pages").inc(
                    len(shared) * self.paging.page_size)
                for p in shared:
                    self._page_holders[p] = \
                        self._page_holders.get(p, 0) + 1
            else:
                self.metrics.counter(
                    METRIC_SERVE_PREFIX_MISSES,
                    "admissions with no cached prefix").inc()
        self.slots[slot] = req
        req._slot = slot
        self.pos[slot] = 0
        self.last_tok[slot] = 0
        self.remaining[slot] = 0       # frozen until promotion
        part = _PartialPrefill(
            req=req, toks=toks, slot=slot,
            pos_filled=len(shared) * self.paging.page_size,
            pages=list(shared), n_shared=len(shared), span=span)
        self._hold_pages(req, len(shared))
        self._partials.append(part)
        self._prefill_slots[slot] = part

    def _pack_order(self) -> list:
        """Chunk-packing order: QOS rank first, then SHORTEST REMAINING
        prefill, then arrival.  Shortest-first is what kills head-of-line
        blocking — a 10-token interactive prompt finishes in one chunk
        even when a 10k-token batch prompt arrived first."""
        qos_t = self.admission.qos_table

        def rank(part):
            q = qos_t.get(part.req.qos)
            prio = q.priority if q is not None else 0
            return (-prio, len(part.toks) - part.pos_filled,
                    part.req._seq)

        return sorted(self._partials, key=rank)

    def _plan_chunk(self, part: _PartialPrefill, budget: int,
                    min_bucket: int = 1) -> Optional[_ChunkPlan]:
        """Pick the next chunk's bucket, grow the partial's pages to
        cover it, and lay out the per-line scatter targets.  Returns None
        when no bucket fits the budget or the pool starves the partial
        back to its tenant queue (pages freed, holdings returned).

        ``min_bucket`` declines chunks that neither reach that size nor
        finish the prompt: the drain loop uses it so a long prompt's tail
        never dribbles out in tiny dispatches (a whole dispatch for a few
        tokens), while a short prompt — which such a chunk COMPLETES, and
        whose first token it unblocks — still packs at any size."""
        rem = len(part.toks) - part.pos_filled
        assert rem > 0, (part.req.rid, part.pos_filled)
        bucket = 0
        for b in self.chunk_buckets:
            if b > budget:
                break
            bucket = b
            if b >= rem:                # smallest bucket covering the rest
                break
        if bucket == 0 or (bucket < min_bucket and bucket < rem):
            return None
        real = min(rem, bucket)
        start = part.pos_filled
        ps = self.paging.page_size
        need = pages_for(start + real, ps) - len(part.pages)
        if need > 0:
            got = self._alloc_or_evict(need)
            if got is None and self._reclaim_one_victim(part.req):
                got = self._alloc_or_evict(need)
            if got is None:
                # pool exhausted mid-prefill: starve the partial back to
                # the queue (page-budget admission re-admits it later)
                self._requeue_partial(part, "STARVED")
                self.metrics.counter(
                    "serve_page_starvations",
                    "slots requeued on page-pool exhaustion").inc()
                return None
            if self.prefix is not None:
                for p in got:
                    self._page_holders[p] = \
                        self._page_holders.get(p, 0) + 1
            part.pages.extend(got)
            self._hold_pages(part.req, len(got))
        row = np.full(self.paging.pages_per_seq, NULL_PAGE, np.int32)
        row[:len(part.pages)] = part.pages
        tokens = np.zeros(bucket, np.int32)
        tokens[:real] = part.toks[start:start + real]
        pages = np.full(bucket, NULL_PAGE, np.int32)
        offs = np.zeros(bucket, np.int32)
        for j in range(real):           # pad lines write the null page
            pages[j] = part.pages[(start + j) // ps]
            offs[j] = (start + j) % ps
        return _ChunkPlan(part=part, bucket=bucket, real=real, start=start,
                          tokens=tokens, row=row, pages=pages, offs=offs)

    def _dispatch_chunk(self, plan: _ChunkPlan):
        """Standalone chunk dispatch (no decode lanes to fuse with).
        Deliberately NOT synced: a non-final chunk's outputs feed only
        the (async, in-program) line scatter, so the host keeps planning
        while the device works; the promotion argmax syncs the final
        chunk.  The prefill histogram therefore times submission here —
        device time shows up in the PREFILL trace span (admit ->
        promotion)."""
        with self.metrics.timer("serve_prefill_seconds", "prefill latency"):
            logits, self.cache = self._chunk_fn(
                self.params, self.cache, jnp.asarray(plan.tokens)[None],
                jnp.asarray(plan.row)[None],
                jnp.asarray(plan.start, jnp.int32),
                jnp.asarray(plan.real - 1, jnp.int32),
                jnp.asarray(plan.pages), jnp.asarray(plan.offs))
        return logits

    def _finish_chunk(self, plan: _ChunkPlan, logits):
        """Advance the partial past a dispatched chunk (the KV lines were
        scattered inside the chunk/mixed program); the final chunk
        promotes the request to a live decode lane (its first output
        token is this chunk's last-position argmax — exactly the
        whole-prompt prefill's)."""
        part = plan.part
        part.pos_filled = plan.start + plan.real
        part.chunks += 1
        self.serve_stats["prefill_tokens"] += plan.real
        self.serve_stats["prefill_chunks"] += 1
        if part.pos_filled >= len(part.toks):
            self._partials.remove(part)
            self._promote(part, logits)

    def _promote(self, part: _PartialPrefill, logits):
        """Last chunk done: unfreeze the slot into a decode lane."""
        req, slot = part.req, part.slot
        tr = self.tracer
        root = self._trace_root(req)
        if part.span is not None:
            now = tr.clock()
            tr.end(part.span, ts=now, chunks=part.chunks,
                   pos_filled=part.pos_filled, prefix_pages=part.n_shared,
                   pages_allocated=len(part.pages) - part.n_shared)
        self._ptab.clear(slot)
        self._ptab.set_range(slot, 0, part.pages)
        self._slot_pages[slot] = part.pages
        if self.prefix is not None:
            # donate the complete prompt pages to the radix index;
            # holder refs were registered page-by-page as chunks grew
            self.prefix.insert(part.toks, part.pages)
        resume = bool(req.output)
        if resume:
            tok = int(req.output[-1])      # resume: last token re-decodes
        else:
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
        self.pos[slot] = len(part.toks)
        self.last_tok[slot] = tok
        self.remaining[slot] = req.max_new_tokens - len(req.output)
        if self.spec is not None:
            self.spec.begin(slot, np.append(part.toks, np.int32(tok)))
        self.admission.charge(req, kv_pages=self._billed_pages(slot))
        self.metrics.counter("serve_requests_admitted").inc()
        self.metrics.counter(
            METRIC_SERVE_TENANT_ADMITTED,
            "admissions per tenant").inc(tenant=req.tenant)
        if tr is not None:
            now = tr.clock()
            if resume:
                if root is not None:
                    tr.event("RESUME", root, slot=slot)
            else:
                # first output token came from the final chunk's argmax:
                # TTFT = admit -> that chunk's sync (resumes produced
                # their first token pre-eviction)
                if root is not None:
                    tr.event("first_token", root)
                if req._t_admit is not None:
                    tr.slo.ttft(now - req._t_admit, req.tenant, req.qos)
            req._t_last = now
            req._trace["decode"] = tr.begin(
                "DECODE", cat="decode", parent=root, slot=slot)
        del self._prefill_slots[slot]
        self._maybe_finish(slot)

    def _requeue_partial(self, part: _PartialPrefill, reason: str):
        """Abort a mid-prefill partial at a chunk boundary (preemption or
        page starvation): free its pages, return its chunk-granular
        holdings, clear the slot, and requeue — the resume prefill
        replays prompt + retained output exactly like a decode victim."""
        req, slot = part.req, part.slot
        tr = self.tracer
        if part.span is not None:
            tr.end(part.span, aborted=True, chunks=part.chunks,
                   pos_filled=part.pos_filled)
        root = self._trace_root(req)
        if tr is not None and root is not None:
            tr.event(reason, root)
        if part.pages:
            self.allocator.free(part.pages)
            if self.prefix is not None:
                for p in part.pages:
                    h = self._page_holders.get(p, 0) - 1
                    if h > 0:
                        self._page_holders[p] = h
                    else:
                        self._page_holders.pop(p, None)
        self._hold_pages(req, -req._held_pages)
        self.slots[slot] = None
        req._slot = -1
        self._slot_pages[slot] = []
        self._ptab.clear(slot)
        self._partials.remove(part)
        del self._prefill_slots[slot]
        self.admission.release(req)
        self.admission.requeue(req)

    def _decode_active(self) -> list:
        """Slots with a LIVE decode lane (occupied, not mid-prefill)."""
        return [i for i, r in enumerate(self.slots)
                if r is not None and i not in self._prefill_slots]

    def _step_budgeted(self) -> int:
        """One token-budgeted iteration (continuous batching): decode
        lanes claim budget first (``decode_chunk`` tokens each, dropping
        to 1 only when that alone would blow the budget), then prefill
        chunks pack into the remainder — the head chunk FUSED into the
        same dispatch as decode, any leftover budget drained through
        standalone chunk dispatches."""
        st = self.serve_stats
        st["iterations"] += 1
        T = self.max_batch_tokens
        decode_active = self._decode_active()
        d = self.decode_chunk
        # speculative lanes cost k+1 budget tokens each (worst case: all
        # drafts accepted plus the bonus); if that starves pending
        # prefills entirely, drop to the plain 1-token lane mix instead
        spec = self.speculate > 0
        lane = (self.speculate + 1) if spec else d
        if (self._partials and decode_active
                and lane * len(decode_active) > T):
            d, spec, lane = 1, False, 1
        if decode_active:
            self._ensure_pages(decode_active, steps=lane)
            decode_active = self._decode_active()
        budget = T
        head_plan = None
        if self._partials and decode_active:
            budget -= lane * len(decode_active)
            for part in self._pack_order():
                if budget < 1:
                    break
                if self._prefill_slots.get(part.slot) is not part:
                    continue            # starved away by an earlier plan
                head_plan = self._plan_chunk(part, budget)
                if head_plan is not None:
                    budget -= head_plan.bucket
                    break
            # planning may have reclaim-evicted a decode slot
            decode_active = self._decode_active()
        if decode_active:
            if spec:
                total, chunk_out = self._step_spec(decode_active,
                                                   chunk_plan=head_plan)
                st["decode_tokens"] += total
                if head_plan is not None:
                    self._finish_chunk(head_plan, chunk_out)
            elif head_plan is not None and d == self.decode_chunk:
                total, chunk_out = self._step_fused(
                    decode_active, num_tokens=d, chunk_plan=head_plan)
                st["decode_tokens"] += total
                self._finish_chunk(head_plan, chunk_out)
            else:
                # budget too tight to fuse a full decode_chunk alongside
                # the chunk — dispatch the chunk standalone (async), let
                # the decode queue behind it, THEN finish the chunk:
                # promotion mid-iteration would un-freeze a lane the
                # in-flight decode already treats as done
                c_logits = (self._dispatch_chunk(head_plan)
                            if head_plan is not None else None)
                total, _ = self._step_fused(decode_active, num_tokens=d)
                st["decode_tokens"] += total
                if head_plan is not None:
                    self._finish_chunk(head_plan, c_logits)
        elif head_plan is not None:
            # the planned chunk's decode companions vanished (reclaimed):
            # run it standalone
            self._finish_chunk(head_plan, self._dispatch_chunk(head_plan))
        # drain the remaining budget with standalone chunk dispatches
        # (declining runt chunks that don't finish a prompt — each costs
        # a whole dispatch either way)
        min_bucket = max(1, self.chunk_buckets[-1] // 4)
        while budget >= 1 and self._partials:
            progressed = False
            for part in self._pack_order():
                if budget < 1:
                    break
                if self._prefill_slots.get(part.slot) is not part:
                    continue
                plan = self._plan_chunk(part, budget,
                                        min_bucket=min_bucket)
                if plan is None:
                    continue
                self._finish_chunk(plan, self._dispatch_chunk(plan))
                budget -= plan.bucket
                progressed = True
            if not progressed:
                break
        return (len([r for r in self.slots if r is not None])
                + self.admission.pending())

    # -------------------------------------------------------------- step ----
    def step(self) -> int:
        """Admit + one batched decode dispatch (``decode_chunk`` tokens on
        the fused path, one on the host path).  Returns #active + #queued.

        Budgeted mode (``max_batch_tokens``) runs the token-budgeted
        continuous-batching iteration instead: decode lanes plus packed
        prefill chunks under one budget."""
        self._admit()
        self._update_pool_gauges()
        if self.max_batch_tokens is not None:
            return self._step_budgeted()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if self.paging is not None and active:
            # speculative rounds may advance a lane by up to k+1 AND fall
            # back to the plain fused chunk when no lane has drafts —
            # pre-grow pages for whichever path runs
            self._ensure_pages(
                active, steps=(max(self.decode_chunk, self.speculate + 1)
                               if self.speculate else None))
            # growth may have evicted/requeued slots at ANY index (a
            # reclaim victim can precede its requester) — rebuild rather
            # than trust the in-place edits
            active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return self.admission.pending()
        if self.speculate:
            self._step_spec(active)
        elif self.fused:
            self._step_fused(active)
        else:
            self._step_host(active)
        return (len([r for r in self.slots if r is not None])
                + self.admission.pending())

    def _step_fused(self, active: list, num_tokens: Optional[int] = None,
                    chunk_plan: Optional[_ChunkPlan] = None):
        """Device-resident chunk: one dispatch, one host sync.

        Budgeted mode passes ``num_tokens`` (tokens per lane this
        iteration) and optionally ``chunk_plan`` — a prefill chunk fused
        into the SAME dispatch.  Mid-prefill slots count as done (their
        lanes freeze; capacity 0 routes their writes to the null page).
        Returns ``(generated_tokens, chunk_outputs_or_None)``."""
        done = np.array([self.slots[i] is None or i in self._prefill_slots
                         for i in range(self.num_slots)])
        eos = np.array([
            (self.slots[i].eos_id if self.slots[i] is not None
             and self.slots[i].eos_id is not None else -1)
            for i in range(self.num_slots)], np.int32)
        temps = np.array([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.num_slots)], np.float32)
        tr = self.tracer
        csp = tr.begin("decode_chunk", cat="engine",
                       track=("serving:engine", "dispatch"),
                       active=len(active)) if tr is not None else None
        t0 = time.perf_counter()
        chunk_out = None
        if self.paging is not None:
            limit = np.array([
                self._capacity(i) if self.slots[i] is not None
                else self.cache_len
                for i in range(self.num_slots)], np.int32)
            fn = self._decode_n
            if num_tokens is not None and num_tokens != self.decode_chunk:
                fn = self._decode_n1   # budgeted mixed iterations: 1/lane
            if chunk_plan is not None:
                (toks, self.cache, token, pos, remaining, done_d,
                 self._key, chunk_out) = self._mixed_step(
                    self.params, self.cache, jnp.asarray(self.last_tok),
                    jnp.asarray(self.pos.astype(np.int32)),
                    jnp.asarray(self.remaining.astype(np.int32)),
                    jnp.asarray(done), jnp.asarray(eos),
                    jnp.asarray(temps), self._key,
                    jnp.asarray(self._dispatch_table()),
                    jnp.asarray(limit),
                    jnp.asarray(chunk_plan.tokens)[None],
                    jnp.asarray(chunk_plan.row)[None],
                    jnp.asarray(chunk_plan.start, jnp.int32),
                    jnp.asarray(chunk_plan.real - 1, jnp.int32),
                    jnp.asarray(chunk_plan.pages),
                    jnp.asarray(chunk_plan.offs))
            else:
                toks, self.cache, token, pos, remaining, done_d, \
                    self._key = fn(
                        self.params, self.cache,
                        jnp.asarray(self.last_tok),
                        jnp.asarray(self.pos.astype(np.int32)),
                        jnp.asarray(self.remaining.astype(np.int32)),
                        jnp.asarray(done), jnp.asarray(eos),
                        jnp.asarray(temps), self._key,
                        jnp.asarray(self._dispatch_table()),
                        jnp.asarray(limit))
        else:
            toks, self.cache, token, pos, remaining, done_d, self._key = \
                self._decode_n(
                    self.params, self.cache, jnp.asarray(self.last_tok),
                    jnp.asarray(self.pos.astype(np.int32)),
                    jnp.asarray(self.remaining.astype(np.int32)),
                    jnp.asarray(done), jnp.asarray(eos), jnp.asarray(temps),
                    self._key)
        # ONE sync per chunk: everything below is host-side numpy
        toks = np.asarray(toks)
        pos = np.asarray(pos)
        token = np.asarray(token)
        remaining = np.asarray(remaining)
        done_d = np.asarray(done_d)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        ts_sync = tr.clock() if tr is not None else 0.0
        charges = []
        tenant_tokens: dict[str, int] = {}
        total = 0
        for i in active:
            req = self.slots[i]
            n_gen = int(pos[i]) - int(self.pos[i])
            if n_gen:
                req.output.extend(int(t) for t in toks[i, :n_gen])
                if self.paging is not None:
                    # paged rent: pages actually pinned x steps — true HBM
                    # residency (shared prefix pages amortized across
                    # holders), so a short request stops paying for cache
                    # it never held
                    charges.append(
                        (req, n_gen, 0,
                         self._billed_pages(i) * n_gen))
                else:
                    # per-chunk charge: n tokens + KV-line rent summed over
                    # the chunk's steps (sum_{j=1..n} pos0+j), exactly the
                    # per-token path's total
                    kv = n_gen * int(self.pos[i]) + n_gen * (n_gen + 1) // 2
                    charges.append((req, n_gen, kv))
                tenant_tokens[req.tenant] = \
                    tenant_tokens.get(req.tenant, 0) + n_gen
                total += n_gen
                if tr is not None and req._t_last is not None:
                    # one host sync per chunk: spread the chunk's wall
                    # time evenly across its tokens (token-weighted)
                    tr.slo.itl((ts_sync - req._t_last) / n_gen,
                               req.tenant, req.qos, n=n_gen)
            if tr is not None:
                req._t_last = ts_sync
            self.pos[i] = pos[i]
            self.last_tok[i] = token[i]
            self.remaining[i] = remaining[i]
            if self.spec is not None and n_gen:
                # keep draft contexts in sync when this chunk ran as the
                # empty-draft fallback of the speculative step
                self.spec.advance(i, toks[i, :n_gen].astype(np.int32))
            if done_d[i]:
                hit_eos = (req.eos_id is not None and req.output
                           and req.output[-1] == req.eos_id)
                if (self.paging is not None and not hit_eos
                        and self.remaining[i] > 0
                        and self._capacity(i) < self.cache_len):
                    # froze at its allocation boundary, not a real stop:
                    # partial growth ran out of pages mid-chunk
                    self._requeue_starved(i)
                else:
                    self._finish(i)
        if csp is not None:
            tr.end(csp, ts=ts_sync, tokens=total)
        self.admission.charge_bulk(charges)
        self.metrics.counter("serve_tokens_generated").inc(total)
        tok_counter = self.metrics.counter(
            METRIC_SERVE_TENANT_TOKENS, "generated tokens per tenant")
        for tenant, n in tenant_tokens.items():
            tok_counter.inc(n, tenant=tenant)
        return total, chunk_out

    def _step_spec(self, active: list,
                   chunk_plan: Optional[_ChunkPlan] = None):
        """One speculative draft-and-verify round (paged + fused only).

        Per live lane the draft source proposes up to ``speculate``
        tokens; ONE batched verify dispatch scores ``last_tok`` plus all
        drafts (row ``j``'s logits bitwise-identical to a sequential
        decode at ``pos+j``), then the host accepts the longest agreeing
        run under greedy — or rejection-samples under temperature — and
        replays ``decode_n``'s exact stop walk over the emitted run
        (EOS / budget / allocation boundary), so stopping is
        bit-identical too.  Rejected proposals' KV lines are dead on
        arrival: masked until the next round's scatter overwrites them
        (pos only advances past ACCEPTED lines), the same null-page
        lifetime argument bucket pad lines ride on.

        When no lane has any draft, falls back to the plain fused chunk
        (classic mode) or a 1-token lane mix (budgeted mode) — identical
        output either way, but no S-row dispatch for 1-token progress.
        Returns ``(generated_tokens, chunk_outputs_or_None)`` like
        ``_step_fused``."""
        k = self.speculate
        S = k + 1
        ps = self.paging.page_size
        drafts: dict[int, np.ndarray] = {}
        kinds: dict[int, str] = {}
        for i in active:
            d = np.asarray(self.spec.draft(i, k), np.int32).ravel()[:k]
            kinds[i] = getattr(self.spec, "last_kind", self.spec.kind)
            # drop drafts the lane has no room to verify: every USED
            # verify row must read only lines inside the allocation
            room = self._capacity(i) - 1 - int(self.pos[i])
            if len(d) > room:
                d = d[:max(room, 0)]
            drafts[i] = d
        if chunk_plan is None and all(len(drafts[i]) == 0 for i in active):
            if self.max_batch_tokens is not None:
                return self._step_fused(active, num_tokens=1)
            return self._step_fused(active)
        st = self.spec_stats
        proposed = sum(len(drafts[i]) for i in active)
        tokens = np.zeros((self.num_slots, S), np.int32)
        pos0 = np.zeros(self.num_slots, np.int32)
        pages = np.full((self.num_slots, S), NULL_PAGE, np.int32)
        offs = np.zeros((self.num_slots, S), np.int32)
        for i in active:
            p0 = int(self.pos[i])
            di = drafts[i]
            tokens[i, 0] = self.last_tok[i]
            tokens[i, 1:1 + len(di)] = di
            pos0[i] = p0
            sp = self._slot_pages[i]
            cap = len(sp) * ps
            for j in range(S):
                if p0 + j < cap:
                    pages[i, j] = sp[(p0 + j) // ps]
                    offs[i, j] = (p0 + j) % ps
            # columns past the allocation (and every column of frozen /
            # empty / mid-prefill lanes) scatter to the null page
        any_temp = any(self.slots[i].temperature > 0 for i in active)
        tr = self.tracer
        csp = tr.begin("SPECULATE", cat="engine",
                       track=("serving:engine", "dispatch"),
                       active=len(active), k=k,
                       proposed=proposed) if tr is not None else None
        t0 = time.perf_counter()
        chunk_out = None
        args = (self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(pos0), jnp.asarray(pages), jnp.asarray(offs),
                jnp.asarray(self._dispatch_table()))
        if chunk_plan is not None:
            greedy, logits, self.cache, chunk_out = self._mixed_verify(
                *args,
                jnp.asarray(chunk_plan.tokens)[None],
                jnp.asarray(chunk_plan.row)[None],
                jnp.asarray(chunk_plan.start, jnp.int32),
                jnp.asarray(chunk_plan.real - 1, jnp.int32),
                jnp.asarray(chunk_plan.pages),
                jnp.asarray(chunk_plan.offs))
        else:
            greedy, logits, self.cache = self._verify_fn(*args)
        # ONE host sync per round; raw logits transfer only under
        # temperature (greedy needs just the device argmax)
        greedy = np.asarray(greedy)
        logits_np = (np.asarray(logits).astype(np.float32)
                     if any_temp else None)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        ts_sync = tr.clock() if tr is not None else 0.0
        charges = []
        tenant_tokens: dict[str, int] = {}
        total = 0
        accepted_total = 0
        for i in active:
            req = self.slots[i]
            di = drafts[i]
            nd = len(di)
            if req.temperature > 0:
                t = max(req.temperature, 1e-4)
                rows = logits_np[i, :nd + 1] / t
                rows = rows - rows.max(axis=-1, keepdims=True)
                p = np.exp(rows)
                p /= p.sum(axis=-1, keepdims=True)
                cand = rejection_sample(self._spec_rng, p, di)
            else:
                tg = greedy[i]
                m = 0
                while m < nd and tg[m] == di[m]:
                    m += 1
                cand = tg[:m + 1]
            accepted_total += len(cand) - 1
            st["proposed_by"][kinds[i]] = \
                st["proposed_by"].get(kinds[i], 0) + nd
            # decode_n's stop walk, host-side: emit the token, then
            # freeze on EOS / remaining / allocation boundary
            boundary = self._capacity(i) - 1
            p0 = int(self.pos[i])
            rem = int(self.remaining[i])
            emitted = []
            stopped = False
            for tkn in cand:
                emitted.append(int(tkn))
                rem -= 1
                if ((req.eos_id is not None
                     and emitted[-1] == req.eos_id) or rem <= 0
                        or p0 + len(emitted) >= boundary):
                    stopped = True
                    break
            n_gen = len(emitted)
            req.output.extend(emitted)
            charges.append(
                (req, n_gen, 0, self._billed_pages(i) * n_gen))
            tenant_tokens[req.tenant] = \
                tenant_tokens.get(req.tenant, 0) + n_gen
            total += n_gen
            if tr is not None:
                if req._t_last is not None:
                    tr.slo.itl((ts_sync - req._t_last) / n_gen,
                               req.tenant, req.qos, n=n_gen)
                req._t_last = ts_sync
            self.pos[i] = p0 + n_gen
            self.last_tok[i] = emitted[-1]
            self.remaining[i] = rem
            self.spec.advance(i, np.asarray(emitted, np.int32))
            if n_gen == k + 1 and hasattr(self.spec, "set_pending"):
                # fully-accepted round: the model draft's k-step scan
                # never wrote draft k-1's own KV line — catch up later
                self.spec.set_pending(i, int(di[k - 1]))
            if stopped:
                hit_eos = (req.eos_id is not None and req.output
                           and req.output[-1] == req.eos_id)
                if (not hit_eos and self.remaining[i] > 0
                        and self._capacity(i) < self.cache_len):
                    # froze at its allocation boundary, not a real stop
                    self._requeue_starved(i)
                else:
                    self._finish(i)
        if csp is not None:
            tr.end(csp, ts=ts_sync, tokens=total, accepted=accepted_total)
        self.admission.charge_bulk(charges)
        self.metrics.counter("serve_tokens_generated").inc(total)
        tok_counter = self.metrics.counter(
            METRIC_SERVE_TENANT_TOKENS, "generated tokens per tenant")
        for tenant, n in tenant_tokens.items():
            tok_counter.inc(n, tenant=tenant)
        st["rounds"] += 1
        st["proposed"] += proposed
        st["accepted"] += accepted_total
        st["emitted"] += total
        self.metrics.counter(
            METRIC_SPEC_PROPOSED, "draft tokens proposed").inc(proposed)
        self.metrics.counter(
            METRIC_SPEC_ACCEPTED,
            "draft tokens accepted by the target").inc(accepted_total)
        if st["proposed"]:
            self.metrics.gauge(
                METRIC_SPEC_ACCEPT_RATE,
                "running draft acceptance rate").set(
                st["accepted"] / st["proposed"])
        return total, chunk_out

    def _step_host(self, active: list):
        """Original per-token host loop (baseline / reference path)."""
        token = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos.astype(np.int32))
        t0 = time.perf_counter()
        if self.paging is not None:
            logits, self.cache = self._step(
                self.params, self.cache, token, pos,
                jnp.asarray(self._dispatch_table()))
        else:
            logits, self.cache = self._step(self.params, self.cache, token,
                                            pos)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        nxt = self._sample(logits)
        tr = self.tracer
        ts_sync = tr.clock() if tr is not None else 0.0
        tenant_tokens: dict[str, int] = {}
        for i in active:
            req = self.slots[i]
            req.output.append(int(nxt[i]))
            if tr is not None:
                if req._t_last is not None:
                    tr.slo.itl(ts_sync - req._t_last, req.tenant, req.qos)
                req._t_last = ts_sync
            self.pos[i] += 1
            self.last_tok[i] = nxt[i]
            self.remaining[i] -= 1
            # one generated token + rent on the KV residency this slot
            # holds (dense lines, or the pages actually pinned)
            if self.paging is not None:
                self.admission.charge(req, tokens=1,
                                      kv_pages=self._billed_pages(i))
            else:
                self.admission.charge(req, tokens=1,
                                      kv_tokens=int(self.pos[i]))
            tenant_tokens[req.tenant] = tenant_tokens.get(req.tenant, 0) + 1
            self._maybe_finish(i)
        self.metrics.counter("serve_tokens_generated").inc(len(active))
        tok_counter = self.metrics.counter(
            METRIC_SERVE_TENANT_TOKENS, "generated tokens per tenant")
        for tenant, n in tenant_tokens.items():
            tok_counter.inc(n, tenant=tenant)

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if self.step() == 0:
                break
