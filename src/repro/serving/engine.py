"""Batched decode engine: slot-based continuous batching over a shared KV
cache (the TensorRT-role module from DESIGN.md's assumption log).

A fixed number of *slots* share one batched cache pytree.  Requests queue
behind a multi-tenant :class:`~repro.serving.admission.AdmissionController`;
when a slot frees, the next request is chosen by the same
``2^(-usage/shares)`` fair-share priority the batch scheduler uses, then
prefilled (its cache slice written into the batch cache at the slot index)
and joins the batched decode loop.  Finished sequences (EOS or
max_new_tokens) free their slot immediately — the engine never waits for
the whole batch, which is the throughput property continuous batching
exists for.

The decode hot loop is **device-resident** (the fast path): sampling and
stop handling run inside the jitted step (``models.model.decode_n``), and
one dispatch generates ``decode_chunk`` tokens per slot via ``lax.scan``.
The host syncs ``tokens/pos/remaining/done`` once per chunk, then does
admission / ledger / metrics work exactly as before — so QOS preemption
and fair-share picks happen at chunk boundaries.  ``fused=False`` keeps
the original one-token host loop (reference + benchmark baseline).

Prefill is **bucketed** when ``prefill_buckets`` is set (full-attention,
non-sliding-window configs): prompts pad to the next bucket length so the
jitted prefill compiles once per bucket instead of once per distinct
prompt length, and the cache slice lands in the batch cache through one
pre-jitted donated ``dynamic_update_slice`` insert.

Multi-tenancy rides entirely on the host side: admission picks, GrpTRES
slot caps, QOS preemption (a blocked high request evicts one scavenger
slot; the victim requeues with its partial output retained and resumes
exactly where it stopped), and per-chunk batched ledger charges are all
O(tenants) Python per chunk.

**Paged KV cache** (``kv_page_size > 0``, opt-in): instead of pinning
``cache_len`` dense lines per slot, all slots share one device page pool
(``models.paging``).  A request holds exactly ``ceil(tokens/page_size)``
pages, grows one page at a time at decode-time page boundaries (the host
pre-allocates each chunk's worth before dispatch), and frees everything
back to the pool on finish/evict — so the same HBM budget serves far
more concurrent short requests.  Admission turns page-budget-aware: a
request is only picked when its prefill fits the free pool, GrpTRES can
cap ``kv_pages`` per tenant, and the ledger bills ``kv_pages`` residency
(true HBM held) instead of dense ``kv_tokens``.  Pool exhaustion at
growth time triggers the same one-victim scavenger eviction QOS
preemption uses; if nothing is evictable the starved slot truncates at
its allocation boundary instead of corrupting neighbours.  Greedy fused
decode is bit-identical to the dense cache (the gathered logical view
feeds the exact same masked attention math).

**Prefix cache** (``prefix_cache=True``, needs paging): a radix index
(``serving.prefix``) maps complete prompt-token pages to physical pages.
On admission the engine looks up the longest cached prefix, maps those
pages READ-ONLY into the new request's page table (copy-on-write: refs,
not copies — decode writes only ever land past the shared region on
privately-owned pages) and prefills **only the suffix**
(``models.model.prefill_suffix``, riding the same buckets).  Finished
requests donate their complete prompt pages to the index; under
capacity pressure the index LRU-evicts unpinned prefixes back to the
free pool *before* the scavenger victim path fires.  Shared pages bill
``gres/kv_page`` residency once, amortized across current holders, so
``sshare --tres`` keeps reporting true HBM use, and greedy decode stays
bit-identical to the no-reuse path.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import init_cache, prefill
from repro.models.model import decode_n, decode_step, prefill_suffix
from repro.models.paging import (
    NULL_PAGE, PageAllocator, PagedKVConfig, pages_for,
)
from repro.monitoring import MetricsRegistry, Tracer
from repro.monitoring.metrics import (
    METRIC_SERVE_PREEMPTIONS, METRIC_SERVE_PREFIX_EVICTIONS,
    METRIC_SERVE_PREFIX_HITS, METRIC_SERVE_PREFIX_MISSES,
    METRIC_SERVE_PREFIX_REUSED_TOKENS, METRIC_SERVE_TENANT_ADMITTED,
    METRIC_SERVE_TENANT_TOKENS,
)
from repro.serving.admission import (
    SERVING_TRES_WEIGHTS, AdmissionController,
)
from repro.serving.prefix import PrefixCache


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0           # 0 => greedy
    tenant: str = "default"            # account in the shared ledger
    qos: str = "normal"                # service tier (see repro.policy.qos)
    # filled by the engine
    output: list = field(default_factory=list)
    done: bool = False
    preemptions: int = 0               # times evicted mid-decode
    _seq: int = field(default=0, repr=False)   # admission arrival order
    _slot: int = field(default=-1, repr=False)  # current decode slot (-1 = none)
    _est_pages: int = field(default=0, repr=False)  # paged: worst-case pages
    # lifecycle tracing (populated only when the engine has a tracer)
    _trace: dict = field(default_factory=dict, repr=False)  # open spans
    _t_submit: Optional[float] = field(default=None, repr=False)
    _t_admit: Optional[float] = field(default=None, repr=False)
    _t_last: Optional[float] = field(default=None, repr=False)  # last token


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 cache_len: int = 1024, run: Optional[RunConfig] = None,
                 metrics: Optional[MetricsRegistry] = None, seed: int = 0,
                 admission: Optional[AdmissionController] = None,
                 decode_chunk: int = 1, fused: bool = True,
                 prefill_buckets: Union[None, str, Sequence[int]] = None,
                 kv_page_size: int = 0,
                 kv_pages: Optional[int] = None,
                 prefix_cache: bool = False,
                 tracer: Optional[Tracer] = None):
        self.cfg = cfg
        self.params = params
        self.run = run or RunConfig(remat="none")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.metrics = metrics or MetricsRegistry()
        self.admission = admission if admission is not None \
            else AdmissionController()
        # request-lifecycle tracer (opt-in; None = zero overhead).  The
        # admission controller writes QUEUED spans / queue-wait series
        # into the same tracer unless it already has its own.
        self.tracer = tracer
        if tracer is not None and self.admission.tracer is None:
            self.admission.tracer = tracer
        self.decode_chunk = max(1, int(decode_chunk))
        self.fused = fused
        self.paging = self._resolve_paging(kv_page_size, kv_pages)
        if self.paging is not None:
            # one page bills like the lines it holds, keeping fair-share
            # comparable across page sizes and with dense engines on the
            # same ledger; setdefault so an operator-set weight wins
            w = self.admission.tree.tres_weights
            w.setdefault("gres/kv_page", self.paging.page_size *
                         w.get("gres/kv_token",
                               SERVING_TRES_WEIGHTS["gres/kv_token"]))
            self.allocator = PageAllocator(self.paging.num_pages)
            self.page_tables = np.full(
                (num_slots, self.paging.pages_per_seq), NULL_PAGE, np.int32)
            self._slot_pages: list[list[int]] = [[] for _ in
                                                 range(num_slots)]
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            if self.paging is None:
                raise ValueError(
                    "prefix_cache=True needs the paged KV cache: pass "
                    "kv_page_size > 0 (CLI: --prefix-cache implies "
                    "--kv-paging)")
            self.prefix = PrefixCache(self.allocator, self.paging.page_size)
            # active-request holders per physical page, for amortized
            # residency billing (a page shared by h requests bills 1/h
            # to each, so the ledger charges true HBM once)
            self._page_holders: dict[int, int] = {}
        self.cache = init_cache(cfg, num_slots, cache_len,
                                paging=self.paging)
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.pos = np.zeros(num_slots, np.int64)       # next position per slot
        self.last_tok = np.zeros(num_slots, np.int32)
        self.remaining = np.zeros(num_slots, np.int64)
        self._key = jax.random.PRNGKey(seed)
        self._buckets = self._resolve_buckets(prefill_buckets)
        self._step = self._build_step()
        self._decode_n = self._build_decode_n()
        self._insert = self._build_insert()
        self._prefill_fn = self._build_prefill()
        self._suffix_prefill_fn = (self._build_suffix_prefill()
                                   if self.prefix is not None else None)

    def _resolve_paging(self, kv_page_size: int,
                        kv_pages: Optional[int]) -> Optional[PagedKVConfig]:
        """Paged layout, or None (dense default).  Paging needs a cache
        without position-dependent physical layout: full attention (no
        SSM state to page) and no sliding-window ring.  ``kv_pages``
        overrides the pool size; the default matches the dense HBM
        budget (num_slots * cache_len lines) plus the null page, so
        dense and paged engines are HBM-comparable out of the box."""
        if not kv_page_size:
            return None
        # name the offending config field: "full-attention only" alone
        # sends operators hunting through the whole ModelConfig
        if self.cfg.ssm is not None:
            raise ValueError(
                "kv_page_size: paged KV cache needs a full-attention "
                f"config, but cfg.ssm={self.cfg.ssm!r} — SSM recurrent "
                "state is not line-addressable, so it cannot be paged")
        if self.cfg.attn_every != 1:
            raise ValueError(
                "kv_page_size: paged KV cache needs a full-attention "
                f"config, but cfg.attn_every={self.cfg.attn_every} "
                "interleaves non-attention sublayers whose state has no "
                "page layout")
        if self.cfg.sliding_window is not None:
            raise ValueError(
                "kv_page_size: paged KV cache does not support "
                f"cfg.sliding_window={self.cfg.sliding_window} — the "
                "windowed ring cache's wrapped slot layout has no "
                "page-table equivalent yet")
        assert self.cache_len % kv_page_size == 0, \
            (self.cache_len, kv_page_size)
        if kv_pages is not None:
            assert kv_pages >= 2, "pool needs the null page + 1 usable page"
            return PagedKVConfig(page_size=kv_page_size, num_pages=kv_pages,
                                 pages_per_seq=self.cache_len // kv_page_size)
        return PagedKVConfig.for_budget(self.num_slots * self.cache_len,
                                        kv_page_size, self.cache_len)

    # ------------------------------------------------------------ jitted ----
    def _build_step(self):
        cfg, run = self.cfg, self.run

        if self.paging is not None:
            @jax.jit
            def step_paged(params, cache, token, pos, page_table):
                logits, cache = decode_step(params, cache, token, pos, cfg,
                                            run, page_table=page_table)
                return logits[:, 0], cache

            return step_paged

        @jax.jit
        def step(params, cache, token, pos):
            # per-slot positions: (B,) — decode_step handles scalar or vector
            logits, cache = decode_step(params, cache, token, pos, cfg, run)
            return logits[:, 0], cache

        return step

    def _build_decode_n(self):
        cfg, run = self.cfg, self.run
        chunk, cache_len = self.decode_chunk, self.cache_len

        if self.paging is not None:
            @functools.partial(jax.jit, donate_argnums=(1,))
            def step_n_paged(params, cache, token, pos, remaining, done,
                             eos, temps, key, page_table, limit):
                return decode_n(params, cache, token, pos, remaining, done,
                                eos, temps, key, cfg, run, chunk, cache_len,
                                page_table=page_table, limit=limit)

            return step_n_paged

        @functools.partial(jax.jit, donate_argnums=(1,))
        def step_n(params, cache, token, pos, remaining, done, eos, temps,
                   key):
            return decode_n(params, cache, token, pos, remaining, done, eos,
                            temps, key, cfg, run, chunk, cache_len)

        return step_n

    def _build_insert(self):
        if self.paging is not None:
            ps = self.paging.page_size

            @functools.partial(jax.jit, donate_argnums=(0,))
            def insert_paged(pool_cache, one_cache, page_ids):
                # scatter the request's prefilled lines into its pages;
                # pad-tail pages ride on the null page (id 0), whose
                # garbage is never read unmasked
                def put(pool_leaf, one_leaf):
                    g, _, length = one_leaf.shape[:3]
                    n = page_ids.shape[0]
                    lines = one_leaf[:, 0]
                    if n * ps > length:
                        lines = jnp.pad(
                            lines, ((0, 0), (0, n * ps - length),
                                    (0, 0), (0, 0)))
                    pages = lines.reshape(g, n, ps, *lines.shape[2:])
                    return pool_leaf.at[:, page_ids].set(
                        pages.astype(pool_leaf.dtype))
                return jax.tree.map(put, pool_cache, one_cache)

            return insert_paged

        @functools.partial(jax.jit, donate_argnums=(0,))
        def insert(batch_cache, one_cache, slot):
            def put(batch_leaf, one_leaf):
                return jax.lax.dynamic_update_slice_in_dim(
                    batch_leaf, one_leaf.astype(batch_leaf.dtype), slot,
                    axis=1)
            return jax.tree.map(put, batch_cache, one_cache)

        return insert

    def _build_prefill(self):
        cfg, run, cache_len = self.cfg, self.run, self.cache_len
        # paged mode: prefill only materializes the prompt's own lines
        # (cache_len=None -> S slots); the page scatter does the placement
        paged = self.paging is not None

        @jax.jit
        def prefill_fn(params, tokens, last_pos):
            return prefill(params, {"tokens": tokens}, cfg, run,
                           cache_len=None if paged else cache_len,
                           last_pos=last_pos)

        return prefill_fn

    def _build_suffix_prefill(self):
        """Jitted suffix prefill for prefix-cache hits: compiles once per
        (bucketed) suffix length; ``start`` and the page table are traced
        so any prefix depth reuses the same program."""
        cfg, run = self.cfg, self.run

        @jax.jit
        def suffix_fn(params, cache, tokens, page_table, start, last_pos):
            return prefill_suffix(params, {"tokens": tokens}, cache,
                                  page_table, start, cfg, run,
                                  last_pos=last_pos)

        return suffix_fn

    def _resolve_buckets(self, spec):
        """Power-of-two prompt-length buckets, or None (exact-length
        prefill).  Bucketing pads the prompt tail, which is only sound
        when pad tokens cannot leak into real state: full attention with
        causal masking (no SSM recurrence to pollute) and a non-ring
        cache (no sliding window), otherwise it silently degrades to the
        exact path."""
        if not spec:
            return None
        attn_only = self.cfg.attn_every == 1 and self.cfg.ssm is None
        if not attn_only or self.cfg.sliding_window is not None:
            return None
        if spec == "auto":
            out, b = [], 32
            while b < self.cache_len:
                out.append(b)
                b *= 2
            out.append(self.cache_len)
            return tuple(out)
        out = tuple(sorted({int(b) for b in spec}))
        assert out and 0 < out[0] and out[-1] <= self.cache_len, out
        if out[-1] < self.cache_len:       # any resume prompt must fit
            out = out + (self.cache_len,)
        return out

    @property
    def prefill_buckets(self):
        return self._buckets

    def prefill_compilations(self) -> int:
        """Distinct prefill programs compiled so far — one per bucket on
        the bucketed path.  The exact-length path runs the eager
        (unjitted) prefill and never touches this cache, so it reports
        0 there."""
        return int(self._prefill_fn._cache_size())

    # ----------------------------------------------------------- tracing ----
    def _trace_root(self, req: Request):
        trace = getattr(req, "_trace", None)
        return trace.get("root") if trace else None

    def _trace_decode_end(self, req: Request, reason: Optional[str] = None):
        """Close the request's current DECODE span (finish, preemption,
        or page starvation); ``reason`` also lands as a root-span event,
        so PREEMPT/STARVED transitions are visible on the timeline."""
        tr = self.tracer
        if tr is None:
            return
        trace = getattr(req, "_trace", None)
        if not trace:
            return
        dec = trace.pop("decode", None)
        if dec is not None:
            tr.end(dec, tokens=len(req.output),
                   **({"stop": reason} if reason else {}))
        root = trace.get("root")
        if root is not None and reason:
            tr.event(reason, root)

    # ------------------------------------------------------------ public ----
    def submit(self, req: Request):
        # generation past the cache boundary truncates in _maybe_finish,
        # which also guarantees a preemption victim's resume prefill
        # (prompt + partial output) still fits the cache
        assert len(req.prompt) < self.cache_len, "prompt exceeds cache"
        tr = self.tracer
        if tr is not None:
            req._t_submit = tr.clock()
            root = tr.begin(
                f"request {req.rid}", cat="request",
                track=(f"serving:{req.tenant}", f"req {req.rid}"),
                rid=req.rid, tenant=req.tenant, qos=req.qos,
                prompt_tokens=len(req.prompt),
                max_new_tokens=req.max_new_tokens)
            req._trace = {"root": root}
            tr.event("SUBMIT", root)
        if self.paging is not None:
            # worst-case page footprint, for GrpTRES kv_pages caps
            req._est_pages = pages_for(
                min(len(req.prompt) + req.max_new_tokens + 1,
                    self.cache_len), self.paging.page_size)
            # a footprint the pool can never hold would queue forever
            # (page-budget admission keeps vetoing it): refuse loudly
            assert req._est_pages <= self.paging.usable_pages, \
                (f"request {req.rid}: needs {req._est_pages} pages, pool "
                 f"has {self.paging.usable_pages}")
        self.admission.submit(req)

    def active(self) -> int:
        """Requests currently holding a decode slot."""
        return sum(r is not None for r in self.slots)

    def _capacity(self, slot: int) -> int:
        """KV lines slot may write before growing (paged) / cache_len."""
        if self.paging is None:
            return self.cache_len
        return len(self._slot_pages[slot]) * self.paging.page_size

    def _resume_tokens(self, req) -> np.ndarray:
        """The token sequence a (possibly resumed) request prefills:
        prompt plus retained partial output, minus the last token (which
        re-decodes)."""
        if req.output:
            return np.concatenate(
                [req.prompt, np.asarray(req.output[:-1], np.int32)])
        return np.asarray(req.prompt, np.int32)

    def _fits_pages(self, req) -> bool:
        """Page-budget admission predicate: the resume/prefill pages must
        fit the free pool right now (decode growth is handled later).
        With the prefix cache, cached prefix pages cost nothing and
        LRU-evictable cached pages count as free — so a request whose
        prompt is mostly cached admits into a pool that looks full."""
        toks = self._resume_tokens(req)
        need = pages_for(len(toks), self.paging.page_size)
        budget = self.allocator.available()
        if self.prefix is not None and need > budget:
            # matched pages cost nothing, and evictable cached pages
            # count as free — EXCLUDING the match itself: placement pins
            # it before evicting, so a page cannot serve as both shared
            # mapping and eviction fodder (counting it twice would admit
            # requests that then bounce off allocation forever)
            matched = len(self.prefix.match(toks))
            need -= matched
            budget += max(self.prefix.evictable_pages() - matched, 0)
        return need <= budget

    def pending(self) -> int:
        return self.admission.pending()

    @property
    def queue(self) -> list:
        """Flattened view of all tenant queues (compat/diagnostics)."""
        return [r for t in self.admission.tenants.values() for r in t.queue]

    def _free_slots(self):
        return [i for i, r in enumerate(self.slots) if r is None]

    def _admit(self):
        """Fill free slots from the admission controller; then let blocked
        high-QOS requests preempt one preemptable slot each.  In paged
        mode the pick is additionally gated on the prefill fitting the
        free page pool (page-budget admission)."""
        eligible = self._fits_pages if self.paging is not None else None
        for slot in self._free_slots():
            req = self.admission.next_request(eligible=eligible)
            if req is None:
                return
            self._prefill_into(slot, req)
        # QOS preemption: each blocked preempting request evicts exactly
        # one victim slot (bounded per pass against cyclic QOS tables)
        for _ in range(self.num_slots):
            running = [r for r in self.slots if r is not None]
            pick = self.admission.next_preempting(running)
            if pick is None:
                return
            req, victim = pick
            slot = self._evict(victim)
            self._prefill_into(slot, req)

    def _alloc_or_evict(self, need: int):
        """Allocate ``need`` pages, LRU-evicting unpinned cached prefixes
        to make room when the free pool is short (the capacity-pressure
        valve that fires BEFORE scavenger preemption)."""
        got = self.allocator.alloc(need)
        if got is None and self.prefix is not None:
            freed = self.prefix.evict(need - self.allocator.available())
            if freed:
                self.metrics.counter(
                    METRIC_SERVE_PREFIX_EVICTIONS,
                    "cached prefix pages LRU-evicted").inc(freed)
                got = self.allocator.alloc(need)
        return got

    def _prefill_into(self, slot: int, req: Request):
        """Prefill a request into a free slot.  A preempted request
        resumes: its prompt *and* retained partial output are prefilled,
        so decode continues from exactly where the eviction stopped.

        Paged mode allocates exactly ``ceil(len(toks)/page_size)`` pages
        first (the bucketed pad tail allocates and charges NOTHING — it
        scatters onto the null page) and bails back to the queue if the
        pool cannot hold the prefill.  With the prefix cache, the longest
        cached prefix maps read-only (one allocator ref per page), only
        the suffix allocates/prefills, and the request's complete prompt
        pages join the radix index afterwards."""
        toks = self._resume_tokens(req)
        tr = self.tracer
        root = self._trace_root(req)
        resume = bool(req.output)
        psp = None
        if tr is not None:
            if root is not None:
                tr.event("ADMIT", root, slot=slot)
            psp = tr.begin("PREFILL", cat="prefill", parent=root,
                           tokens=len(toks), resume=resume)
        pages = shared = None
        if self.paging is not None:
            ps = self.paging.page_size
            if self.prefix is not None:
                # acquire BEFORE the private alloc: matched pages are
                # unpinned until then, and the eviction below must not
                # free what we are about to map
                shared = self.prefix.acquire(self.prefix.match(toks))
            n_shared = len(shared) if shared else 0
            priv = self._alloc_or_evict(
                pages_for(len(toks), ps) - n_shared)
            if priv is None and shared:
                # the shortfall may only be coverable by the matched
                # pages themselves: abandon the reuse (unpin, making the
                # match eviction fodder) and retry as a plain prefill —
                # correctness beats sharing
                self.allocator.free(shared)
                shared, n_shared = [], 0
                priv = self._alloc_or_evict(pages_for(len(toks), ps))
            if priv is None:
                # preemption admitted past the page gate but the pool
                # still can't hold the prefill: back to the queue
                if shared:
                    self.allocator.free(shared)      # unpin the match
                if psp is not None:
                    tr.end(psp, aborted=True)
                self.admission.release(req)
                self.admission.requeue(req)
                return
            pages = (shared or []) + priv
            if self.prefix is not None:
                # count hits/misses only for PLACED admissions, so a
                # requeue bounce cannot inflate the reuse figures
                if shared:
                    self.metrics.counter(
                        METRIC_SERVE_PREFIX_HITS,
                        "admissions reusing cached prefix pages").inc()
                    self.metrics.counter(
                        METRIC_SERVE_PREFIX_REUSED_TOKENS,
                        "prompt tokens served from cached pages").inc(
                        n_shared * ps)
                else:
                    self.metrics.counter(
                        METRIC_SERVE_PREFIX_MISSES,
                        "admissions with no cached prefix").inc()
        with self.metrics.timer("serve_prefill_seconds", "prefill latency"):
            if shared:
                # prefix hit: prefill ONLY the suffix, attending to the
                # shared pages through a prefix-only page-table row.  The
                # row width buckets to the next power of two >= the match
                # depth, so the gather/attention cost scales with the
                # ACTUAL prefix, not cache_len (compiles once per
                # (suffix bucket, prefix bucket) pair)
                start = n_shared * self.paging.page_size
                suffix = toks[start:]
                P = len(suffix)
                L = P if self._buckets is None else next(
                    b for b in self._buckets if b >= P)
                padded = np.zeros(L, np.int32)
                padded[:P] = suffix
                pb = 1
                while pb < n_shared:
                    pb *= 2
                row = np.full(pb, NULL_PAGE, np.int32)
                row[:n_shared] = shared
                logits, cache1 = self._suffix_prefill_fn(
                    self.params, self.cache, jnp.asarray(padded)[None],
                    jnp.asarray(row)[None], jnp.asarray(start, jnp.int32),
                    jnp.asarray(P - 1, jnp.int32))
            elif self._buckets is not None:
                P = len(toks)
                L = next(b for b in self._buckets if b >= P)
                padded = np.zeros(L, np.int32)
                padded[:P] = toks
                logits, cache1 = self._prefill_fn(
                    self.params, jnp.asarray(padded)[None],
                    jnp.asarray(P - 1, jnp.int32))
            else:
                L = len(toks)
                prompt = jnp.asarray(toks, jnp.int32)[None]
                logits, cache1 = prefill(
                    self.params, {"tokens": prompt}, self.cfg, self.run,
                    cache_len=None if self.paging is not None
                    else self.cache_len)
            # sync inside the timed region: dispatch is async, and the
            # very next consumer (argmax below) would absorb the device
            # wait — serve_prefill_seconds must report real latency
            jax.block_until_ready(logits)
        if self.paging is not None:
            # scatter the prefilled lines into the privately-owned pages
            # (suffix-only on a prefix hit — shared pages are READ-ONLY
            # and never written); the bucketed pad tail's pages are the
            # null page
            ps = self.paging.page_size
            page_ids = np.full(pages_for(L, ps), NULL_PAGE, np.int32)
            page_ids[:len(priv)] = priv
            self.cache = self._insert(self.cache, cache1,
                                      jnp.asarray(page_ids))
            self.page_tables[slot] = NULL_PAGE
            self.page_tables[slot, :len(pages)] = pages
            self._slot_pages[slot] = pages
            if self.prefix is not None:
                # donate the complete prompt pages to the radix index
                # (the index takes its own refs) and register this
                # request as a holder of everything it maps
                self.prefix.insert(toks, pages)
                for p in pages:
                    self._page_holders[p] = \
                        self._page_holders.get(p, 0) + 1
            # GrpTRES holds the request's WORST-CASE footprint for its
            # whole residency (SLURM-style reservation): decode growth
            # then cannot push a tenant past its kv_pages cap
            self.admission.adjust_pages(req, req._est_pages)
        else:
            # write this request's cache slice into the batch cache through
            # the pre-jitted donated insert (one compile, zero retraces)
            self.cache = self._insert(self.cache, cache1, slot)
        if req.output:
            tok = int(req.output[-1])      # resume: last token re-decodes
        else:
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
        self.slots[slot] = req
        req._slot = slot
        self.pos[slot] = len(toks)
        self.last_tok[slot] = tok
        self.remaining[slot] = req.max_new_tokens - len(req.output)
        # the prefilled KV residency the tenant pays for: dense lines, or
        # (paged) the pages actually pinned — amortized across holders
        # when the prefix cache shares them
        if self.paging is not None:
            self.admission.charge(req, kv_pages=self._billed_pages(slot))
        else:
            self.admission.charge(req, kv_tokens=len(toks))
        self.metrics.counter("serve_requests_admitted").inc()
        self.metrics.counter(
            METRIC_SERVE_TENANT_ADMITTED,
            "admissions per tenant").inc(tenant=req.tenant)
        if tr is not None:
            now = tr.clock()
            attrs = {"bucket": int(L)}
            if self.paging is not None:
                n_sh = len(shared) if shared else 0
                attrs.update(prefix_pages=n_sh,
                             pages_allocated=len(priv))
            tr.end(psp, ts=now, **attrs)
            if resume:
                if root is not None:
                    tr.event("RESUME", root, slot=slot)
            else:
                # the first output token comes from the prefill argmax,
                # so TTFT = admit -> end of the prefill sync (resumes
                # already produced their first token pre-eviction)
                if root is not None:
                    tr.event("first_token", root)
                if req._t_admit is not None:
                    tr.slo.ttft(now - req._t_admit, req.tenant, req.qos)
            req._t_last = now
            req._trace["decode"] = tr.begin(
                "DECODE", cat="decode", parent=root, slot=slot)
        self._maybe_finish(slot)

    def _billed_pages(self, slot: int) -> float:
        """KV-page residency this slot bills per step: each page costs
        ``1 / holders``, so a prefix page shared by N live requests bills
        once across all of them (plain paged mode: every page has one
        holder and this is exactly the page count)."""
        if self.prefix is None:
            return len(self._slot_pages[slot])
        return sum(1.0 / self._page_holders[p]
                   for p in self._slot_pages[slot])

    def _release_pages(self, slot: int, req: Request):
        """Paged mode: drop the slot's page references (private pages
        return to the pool; shared prefix pages survive in the radix
        index — eviction-aware reclaim still sees freed pages
        immediately) and return the worst-case GrpTRES hold."""
        if self.paging is None:
            return
        pages = self._slot_pages[slot]
        if pages:
            self.allocator.free(pages)
            if self.prefix is not None:
                for p in pages:
                    h = self._page_holders.get(p, 0) - 1
                    if h > 0:
                        self._page_holders[p] = h
                    else:
                        self._page_holders.pop(p, None)
        self.admission.adjust_pages(req, -req._est_pages)
        self._slot_pages[slot] = []
        self.page_tables[slot] = NULL_PAGE

    def _vacate(self, victim: Request) -> int:
        """Shared eviction bookkeeping: clear the slot, free its pages,
        return the slot/page holds, and requeue the request with partial
        output retained.  Returns the freed slot index (O(1) via the
        request's slot tag)."""
        slot = victim._slot
        assert slot >= 0 and self.slots[slot] is victim, (slot, victim.rid)
        self.slots[slot] = None
        victim._slot = -1
        self._release_pages(slot, victim)
        self.admission.release(victim)
        self.admission.requeue(victim)
        return slot

    def _evict(self, victim: Request) -> int:
        """Evict a running request from its slot; it requeues at the head
        of its QOS class in its tenant queue with partial output retained."""
        victim.preemptions += 1
        self._trace_decode_end(victim, "PREEMPT")
        slot = self._vacate(victim)
        self.metrics.counter(
            METRIC_SERVE_PREEMPTIONS, "evicted decode slots").inc()
        return slot

    def _finish(self, slot: int):
        req = self.slots[slot]
        req.done = True
        self.slots[slot] = None
        req._slot = -1
        self._release_pages(slot, req)
        self.admission.release(req)
        self.metrics.counter("serve_requests_completed").inc()
        tr = self.tracer
        if tr is not None:
            self._trace_decode_end(req)
            trace = getattr(req, "_trace", None)
            root = trace.pop("root", None) if trace else None
            if root is not None:
                tr.event("FINISH", root)
                tr.end(root, tokens=len(req.output))
            if req._t_submit is not None:
                tr.slo.e2e(tr.clock() - req._t_submit, req.tenant, req.qos)

    def _maybe_finish(self, slot: int):
        req = self.slots[slot]
        if req is None:
            return
        if (req.eos_id is not None and req.output
                and req.output[-1] == req.eos_id) or self.remaining[slot] <= 0 \
                or self.pos[slot] >= self.cache_len - 1:
            self._finish(slot)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        """Host-side per-slot sampling (fused=False path).
        logits: (num_slots, V)."""
        temps = np.array([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.num_slots)], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        # split unconditionally — one key per generated token, exactly the
        # stream decode_n consumes, so host and fused paths stay
        # interchangeable even when greedy and sampled slots mix
        self._key, sub = jax.random.split(self._key)
        if (temps <= 0).all():
            return greedy.astype(np.int32)
        t = jnp.maximum(jnp.asarray(temps), 1e-4)[:, None]
        sampled = np.asarray(
            jax.random.categorical(sub, logits.astype(jnp.float32) / t,
                                   axis=-1))
        return np.where(temps > 0, sampled, greedy).astype(np.int32)

    # ------------------------------------------------------- page growth ----
    def _reclaim_one_victim(self, requester: Request) -> bool:
        """Pool-exhaustion scavenger reclaim: evict ONE running request
        the requester's QOS may preempt (lowest QOS first, worst
        fair-share standing, most recent admission — the same victim rule
        admission preemption uses), freeing its pages.  Returns whether a
        victim was evicted."""
        qos = self.admission.qos_table.get(requester.qos)
        if qos is None:
            return False
        victims = [r for r in self.slots
                   if r is not None and r is not requester
                   and qos.can_preempt(r.qos)]
        if not victims:
            return False
        self._evict(self.admission.pick_victim(victims))
        return True

    def _requeue_starved(self, slot: int):
        """A slot the pool starved out goes back to its tenant queue with
        partial output retained (resume-exact, like a preemption victim);
        page-budget admission re-admits it once pages free up."""
        self._trace_decode_end(self.slots[slot], "STARVED")
        self._vacate(self.slots[slot])
        self.metrics.counter(
            "serve_page_starvations",
            "slots requeued on page-pool exhaustion").inc()

    def _ensure_pages(self, active: list):
        """Grow each live slot's allocation to cover the coming chunk
        (on-demand growth at decode-time page boundaries).  The +2
        headroom keeps the slot's freeze boundary strictly beyond the
        chunk, so a fully-grown paged slot freezes exactly where the
        dense cache would — bit-identical stopping.  On pool exhaustion,
        reclaim via one-victim scavenger eviction; a slot that still
        cannot cover even its current position requeues starved (its
        ``limit`` would otherwise let it write the null page)."""
        ps = self.paging.page_size
        for i in list(active):
            req = self.slots[i]
            if req is None:                    # evicted by a reclaim below
                active.remove(i)
                continue
            # a nearly-finished slot only needs pages for the tokens it
            # may still generate — don't pin headroom it can never use
            steps = min(self.decode_chunk, max(int(self.remaining[i]), 1))
            target = min(int(self.pos[i]) + steps + 2, self.cache_len)
            need = pages_for(target, ps) - len(self._slot_pages[i])
            if need <= 0:
                continue
            # growth pressure relief, in escalation order: LRU-evict
            # unpinned cached prefixes first, scavenger preemption only
            # after the index has nothing left to give
            got = self._alloc_or_evict(need)
            if got is None and self._reclaim_one_victim(req):
                got = self._alloc_or_evict(need)
            if got is None:                    # partial growth: best effort
                got = self.allocator.alloc(
                    min(need, self.allocator.available()))
            if got:
                if self.prefix is not None:
                    for p in got:
                        self._page_holders[p] = \
                            self._page_holders.get(p, 0) + 1
                # no adjust_pages here: the tenant's GrpTRES hold already
                # reserved the worst-case footprint at admission
                n0 = len(self._slot_pages[i])
                self._slot_pages[i].extend(got)
                self.page_tables[i, n0:n0 + len(got)] = got
            if self._capacity(i) <= int(self.pos[i]):
                # starved: not even the current token's page
                self._requeue_starved(i)
                active.remove(i)

    # -------------------------------------------------------------- step ----
    def step(self) -> int:
        """Admit + one batched decode dispatch (``decode_chunk`` tokens on
        the fused path, one on the host path).  Returns #active + #queued."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if self.paging is not None and active:
            self._ensure_pages(active)
            # growth may have evicted/requeued slots at ANY index (a
            # reclaim victim can precede its requester) — rebuild rather
            # than trust the in-place edits
            active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return self.admission.pending()
        if self.fused:
            self._step_fused(active)
        else:
            self._step_host(active)
        return (len([r for r in self.slots if r is not None])
                + self.admission.pending())

    def _step_fused(self, active: list):
        """Device-resident chunk: one dispatch, one host sync."""
        done = np.array([self.slots[i] is None for i in
                         range(self.num_slots)])
        eos = np.array([
            (self.slots[i].eos_id if self.slots[i] is not None
             and self.slots[i].eos_id is not None else -1)
            for i in range(self.num_slots)], np.int32)
        temps = np.array([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.num_slots)], np.float32)
        tr = self.tracer
        csp = tr.begin("decode_chunk", cat="engine",
                       track=("serving:engine", "dispatch"),
                       active=len(active)) if tr is not None else None
        t0 = time.perf_counter()
        if self.paging is not None:
            limit = np.array([
                self._capacity(i) if self.slots[i] is not None
                else self.cache_len
                for i in range(self.num_slots)], np.int32)
            toks, self.cache, token, pos, remaining, done_d, self._key = \
                self._decode_n(
                    self.params, self.cache, jnp.asarray(self.last_tok),
                    jnp.asarray(self.pos.astype(np.int32)),
                    jnp.asarray(self.remaining.astype(np.int32)),
                    jnp.asarray(done), jnp.asarray(eos), jnp.asarray(temps),
                    self._key, jnp.asarray(self.page_tables),
                    jnp.asarray(limit))
        else:
            toks, self.cache, token, pos, remaining, done_d, self._key = \
                self._decode_n(
                    self.params, self.cache, jnp.asarray(self.last_tok),
                    jnp.asarray(self.pos.astype(np.int32)),
                    jnp.asarray(self.remaining.astype(np.int32)),
                    jnp.asarray(done), jnp.asarray(eos), jnp.asarray(temps),
                    self._key)
        # ONE sync per chunk: everything below is host-side numpy
        toks = np.asarray(toks)
        pos = np.asarray(pos)
        token = np.asarray(token)
        remaining = np.asarray(remaining)
        done_d = np.asarray(done_d)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        ts_sync = tr.clock() if tr is not None else 0.0
        charges = []
        tenant_tokens: dict[str, int] = {}
        total = 0
        for i in active:
            req = self.slots[i]
            n_gen = int(pos[i]) - int(self.pos[i])
            if n_gen:
                req.output.extend(int(t) for t in toks[i, :n_gen])
                if self.paging is not None:
                    # paged rent: pages actually pinned x steps — true HBM
                    # residency (shared prefix pages amortized across
                    # holders), so a short request stops paying for cache
                    # it never held
                    charges.append(
                        (req, n_gen, 0,
                         self._billed_pages(i) * n_gen))
                else:
                    # per-chunk charge: n tokens + KV-line rent summed over
                    # the chunk's steps (sum_{j=1..n} pos0+j), exactly the
                    # per-token path's total
                    kv = n_gen * int(self.pos[i]) + n_gen * (n_gen + 1) // 2
                    charges.append((req, n_gen, kv))
                tenant_tokens[req.tenant] = \
                    tenant_tokens.get(req.tenant, 0) + n_gen
                total += n_gen
                if tr is not None and req._t_last is not None:
                    # one host sync per chunk: spread the chunk's wall
                    # time evenly across its tokens (token-weighted)
                    tr.slo.itl((ts_sync - req._t_last) / n_gen,
                               req.tenant, req.qos, n=n_gen)
            if tr is not None:
                req._t_last = ts_sync
            self.pos[i] = pos[i]
            self.last_tok[i] = token[i]
            self.remaining[i] = remaining[i]
            if done_d[i]:
                hit_eos = (req.eos_id is not None and req.output
                           and req.output[-1] == req.eos_id)
                if (self.paging is not None and not hit_eos
                        and self.remaining[i] > 0
                        and self._capacity(i) < self.cache_len):
                    # froze at its allocation boundary, not a real stop:
                    # partial growth ran out of pages mid-chunk
                    self._requeue_starved(i)
                else:
                    self._finish(i)
        if csp is not None:
            tr.end(csp, ts=ts_sync, tokens=total)
        self.admission.charge_bulk(charges)
        self.metrics.counter("serve_tokens_generated").inc(total)
        tok_counter = self.metrics.counter(
            METRIC_SERVE_TENANT_TOKENS, "generated tokens per tenant")
        for tenant, n in tenant_tokens.items():
            tok_counter.inc(n, tenant=tenant)

    def _step_host(self, active: list):
        """Original per-token host loop (baseline / reference path)."""
        token = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos.astype(np.int32))
        t0 = time.perf_counter()
        if self.paging is not None:
            logits, self.cache = self._step(
                self.params, self.cache, token, pos,
                jnp.asarray(self.page_tables))
        else:
            logits, self.cache = self._step(self.params, self.cache, token,
                                            pos)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        nxt = self._sample(logits)
        tr = self.tracer
        ts_sync = tr.clock() if tr is not None else 0.0
        tenant_tokens: dict[str, int] = {}
        for i in active:
            req = self.slots[i]
            req.output.append(int(nxt[i]))
            if tr is not None:
                if req._t_last is not None:
                    tr.slo.itl(ts_sync - req._t_last, req.tenant, req.qos)
                req._t_last = ts_sync
            self.pos[i] += 1
            self.last_tok[i] = nxt[i]
            self.remaining[i] -= 1
            # one generated token + rent on the KV residency this slot
            # holds (dense lines, or the pages actually pinned)
            if self.paging is not None:
                self.admission.charge(req, tokens=1,
                                      kv_pages=self._billed_pages(i))
            else:
                self.admission.charge(req, tokens=1,
                                      kv_tokens=int(self.pos[i]))
            tenant_tokens[req.tenant] = tenant_tokens.get(req.tenant, 0) + 1
            self._maybe_finish(i)
        self.metrics.counter("serve_tokens_generated").inc(len(active))
        tok_counter = self.metrics.counter(
            METRIC_SERVE_TENANT_TOKENS, "generated tokens per tenant")
        for tenant, n in tenant_tokens.items():
            tok_counter.inc(n, tenant=tenant)

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if self.step() == 0:
                break
