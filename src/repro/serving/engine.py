"""Batched decode engine: slot-based continuous batching over a shared KV
cache (the TensorRT-role module from DESIGN.md's assumption log).

A fixed number of *slots* share one batched cache pytree.  Requests queue
behind a multi-tenant :class:`~repro.serving.admission.AdmissionController`;
when a slot frees, the next request is chosen by the same
``2^(-usage/shares)`` fair-share priority the batch scheduler uses, then
prefilled (its cache slice written into the batch cache at the slot index)
and joins the batched decode loop.  Finished sequences (EOS or
max_new_tokens) free their slot immediately — the engine never waits for
the whole batch, which is the throughput property continuous batching
exists for.

The decode hot loop is **device-resident** (the fast path): sampling and
stop handling run inside the jitted step (``models.model.decode_n``), and
one dispatch generates ``decode_chunk`` tokens per slot via ``lax.scan``.
The host syncs ``tokens/pos/remaining/done`` once per chunk, then does
admission / ledger / metrics work exactly as before — so QOS preemption
and fair-share picks happen at chunk boundaries.  ``fused=False`` keeps
the original one-token host loop (reference + benchmark baseline).

Prefill is **bucketed** when ``prefill_buckets`` is set (full-attention,
non-sliding-window configs): prompts pad to the next bucket length so the
jitted prefill compiles once per bucket instead of once per distinct
prompt length, and the cache slice lands in the batch cache through one
pre-jitted donated ``dynamic_update_slice`` insert.

Multi-tenancy rides entirely on the host side: admission picks, GrpTRES
slot caps, QOS preemption (a blocked high request evicts one scavenger
slot; the victim requeues with its partial output retained and resumes
exactly where it stopped), and per-chunk batched ledger charges are all
O(tenants) Python per chunk.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import init_cache, prefill
from repro.models.model import decode_n, decode_step
from repro.monitoring import MetricsRegistry
from repro.monitoring.metrics import (
    METRIC_SERVE_PREEMPTIONS, METRIC_SERVE_TENANT_ADMITTED,
    METRIC_SERVE_TENANT_TOKENS,
)
from repro.serving.admission import AdmissionController


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0           # 0 => greedy
    tenant: str = "default"            # account in the shared ledger
    qos: str = "normal"                # service tier (see repro.policy.qos)
    # filled by the engine
    output: list = field(default_factory=list)
    done: bool = False
    preemptions: int = 0               # times evicted mid-decode
    _seq: int = field(default=0, repr=False)   # admission arrival order
    _slot: int = field(default=-1, repr=False)  # current decode slot (-1 = none)


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int = 8,
                 cache_len: int = 1024, run: Optional[RunConfig] = None,
                 metrics: Optional[MetricsRegistry] = None, seed: int = 0,
                 admission: Optional[AdmissionController] = None,
                 decode_chunk: int = 1, fused: bool = True,
                 prefill_buckets: Union[None, str, Sequence[int]] = None):
        self.cfg = cfg
        self.params = params
        self.run = run or RunConfig(remat="none")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.metrics = metrics or MetricsRegistry()
        self.admission = admission if admission is not None \
            else AdmissionController()
        self.decode_chunk = max(1, int(decode_chunk))
        self.fused = fused
        self.cache = init_cache(cfg, num_slots, cache_len)
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.pos = np.zeros(num_slots, np.int64)       # next position per slot
        self.last_tok = np.zeros(num_slots, np.int32)
        self.remaining = np.zeros(num_slots, np.int64)
        self._key = jax.random.PRNGKey(seed)
        self._buckets = self._resolve_buckets(prefill_buckets)
        self._step = self._build_step()
        self._decode_n = self._build_decode_n()
        self._insert = self._build_insert()
        self._prefill_fn = self._build_prefill()

    # ------------------------------------------------------------ jitted ----
    def _build_step(self):
        cfg, run = self.cfg, self.run

        @jax.jit
        def step(params, cache, token, pos):
            # per-slot positions: (B,) — decode_step handles scalar or vector
            logits, cache = decode_step(params, cache, token, pos, cfg, run)
            return logits[:, 0], cache

        return step

    def _build_decode_n(self):
        cfg, run = self.cfg, self.run
        chunk, cache_len = self.decode_chunk, self.cache_len

        @functools.partial(jax.jit, donate_argnums=(1,))
        def step_n(params, cache, token, pos, remaining, done, eos, temps,
                   key):
            return decode_n(params, cache, token, pos, remaining, done, eos,
                            temps, key, cfg, run, chunk, cache_len)

        return step_n

    def _build_insert(self):
        @functools.partial(jax.jit, donate_argnums=(0,))
        def insert(batch_cache, one_cache, slot):
            def put(batch_leaf, one_leaf):
                return jax.lax.dynamic_update_slice_in_dim(
                    batch_leaf, one_leaf.astype(batch_leaf.dtype), slot,
                    axis=1)
            return jax.tree.map(put, batch_cache, one_cache)

        return insert

    def _build_prefill(self):
        cfg, run, cache_len = self.cfg, self.run, self.cache_len

        @jax.jit
        def prefill_fn(params, tokens, last_pos):
            return prefill(params, {"tokens": tokens}, cfg, run,
                           cache_len=cache_len, last_pos=last_pos)

        return prefill_fn

    def _resolve_buckets(self, spec):
        """Power-of-two prompt-length buckets, or None (exact-length
        prefill).  Bucketing pads the prompt tail, which is only sound
        when pad tokens cannot leak into real state: full attention with
        causal masking (no SSM recurrence to pollute) and a non-ring
        cache (no sliding window), otherwise it silently degrades to the
        exact path."""
        if not spec:
            return None
        attn_only = self.cfg.attn_every == 1 and self.cfg.ssm is None
        if not attn_only or self.cfg.sliding_window is not None:
            return None
        if spec == "auto":
            out, b = [], 32
            while b < self.cache_len:
                out.append(b)
                b *= 2
            out.append(self.cache_len)
            return tuple(out)
        out = tuple(sorted({int(b) for b in spec}))
        assert out and 0 < out[0] and out[-1] <= self.cache_len, out
        if out[-1] < self.cache_len:       # any resume prompt must fit
            out = out + (self.cache_len,)
        return out

    @property
    def prefill_buckets(self):
        return self._buckets

    def prefill_compilations(self) -> int:
        """Distinct prefill programs compiled so far — one per bucket on
        the bucketed path.  The exact-length path runs the eager
        (unjitted) prefill and never touches this cache, so it reports
        0 there."""
        return int(self._prefill_fn._cache_size())

    # ------------------------------------------------------------ public ----
    def submit(self, req: Request):
        # generation past the cache boundary truncates in _maybe_finish,
        # which also guarantees a preemption victim's resume prefill
        # (prompt + partial output) still fits the cache
        assert len(req.prompt) < self.cache_len, "prompt exceeds cache"
        self.admission.submit(req)

    def pending(self) -> int:
        return self.admission.pending()

    @property
    def queue(self) -> list:
        """Flattened view of all tenant queues (compat/diagnostics)."""
        return [r for t in self.admission.tenants.values() for r in t.queue]

    def _free_slots(self):
        return [i for i, r in enumerate(self.slots) if r is None]

    def _admit(self):
        """Fill free slots from the admission controller; then let blocked
        high-QOS requests preempt one preemptable slot each."""
        for slot in self._free_slots():
            req = self.admission.next_request()
            if req is None:
                return
            self._prefill_into(slot, req)
        # QOS preemption: each blocked preempting request evicts exactly
        # one victim slot (bounded per pass against cyclic QOS tables)
        for _ in range(self.num_slots):
            running = [r for r in self.slots if r is not None]
            pick = self.admission.next_preempting(running)
            if pick is None:
                return
            req, victim = pick
            slot = self._evict(victim)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        """Prefill a request into a free slot.  A preempted request
        resumes: its prompt *and* retained partial output are prefilled,
        so decode continues from exactly where the eviction stopped."""
        if req.output:
            toks = np.concatenate(
                [req.prompt, np.asarray(req.output[:-1], np.int32)])
        else:
            toks = np.asarray(req.prompt, np.int32)
        with_timer = self.metrics.histogram(
            "serve_prefill_seconds", "prefill latency")
        t0 = time.perf_counter()
        try:
            if self._buckets is not None:
                P = len(toks)
                L = next(b for b in self._buckets if b >= P)
                padded = np.zeros(L, np.int32)
                padded[:P] = toks
                logits, cache1 = self._prefill_fn(
                    self.params, jnp.asarray(padded)[None],
                    jnp.asarray(P - 1, jnp.int32))
            else:
                prompt = jnp.asarray(toks, jnp.int32)[None]
                logits, cache1 = prefill(
                    self.params, {"tokens": prompt}, self.cfg, self.run,
                    cache_len=self.cache_len)
        finally:
            with_timer.observe(time.perf_counter() - t0)
        # write this request's cache slice into the batch cache through
        # the pre-jitted donated insert (one compile, zero retraces)
        self.cache = self._insert(self.cache, cache1, slot)
        if req.output:
            tok = int(req.output[-1])      # resume: last token re-decodes
        else:
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
        self.slots[slot] = req
        req._slot = slot
        self.pos[slot] = len(toks)
        self.last_tok[slot] = tok
        self.remaining[slot] = req.max_new_tokens - len(req.output)
        # the prefilled KV lines are residency the tenant pays for
        self.admission.charge(req, kv_tokens=len(toks))
        self.metrics.counter("serve_requests_admitted").inc()
        self.metrics.counter(
            METRIC_SERVE_TENANT_ADMITTED,
            "admissions per tenant").inc(tenant=req.tenant)
        self._maybe_finish(slot)

    def _evict(self, victim: Request) -> int:
        """Evict a running request from its slot; it requeues at the head
        of its QOS class in its tenant queue with partial output retained.
        Returns the freed slot index (O(1) via the request's slot tag)."""
        slot = victim._slot
        assert slot >= 0 and self.slots[slot] is victim, (slot, victim.rid)
        self.slots[slot] = None
        victim._slot = -1
        victim.preemptions += 1
        self.admission.release(victim)
        self.admission.requeue(victim)
        self.metrics.counter(
            METRIC_SERVE_PREEMPTIONS, "evicted decode slots").inc()
        return slot

    def _finish(self, slot: int):
        req = self.slots[slot]
        req.done = True
        self.slots[slot] = None
        req._slot = -1
        self.admission.release(req)
        self.metrics.counter("serve_requests_completed").inc()

    def _maybe_finish(self, slot: int):
        req = self.slots[slot]
        if req is None:
            return
        if (req.eos_id is not None and req.output
                and req.output[-1] == req.eos_id) or self.remaining[slot] <= 0 \
                or self.pos[slot] >= self.cache_len - 1:
            self._finish(slot)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        """Host-side per-slot sampling (fused=False path).
        logits: (num_slots, V)."""
        temps = np.array([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.num_slots)], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        # split unconditionally — one key per generated token, exactly the
        # stream decode_n consumes, so host and fused paths stay
        # interchangeable even when greedy and sampled slots mix
        self._key, sub = jax.random.split(self._key)
        if (temps <= 0).all():
            return greedy.astype(np.int32)
        t = jnp.maximum(jnp.asarray(temps), 1e-4)[:, None]
        sampled = np.asarray(
            jax.random.categorical(sub, logits.astype(jnp.float32) / t,
                                   axis=-1))
        return np.where(temps > 0, sampled, greedy).astype(np.int32)

    # -------------------------------------------------------------- step ----
    def step(self) -> int:
        """Admit + one batched decode dispatch (``decode_chunk`` tokens on
        the fused path, one on the host path).  Returns #active + #queued."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return self.admission.pending()
        if self.fused:
            self._step_fused(active)
        else:
            self._step_host(active)
        return (len([r for r in self.slots if r is not None])
                + self.admission.pending())

    def _step_fused(self, active: list):
        """Device-resident chunk: one dispatch, one host sync."""
        done = np.array([self.slots[i] is None for i in
                         range(self.num_slots)])
        eos = np.array([
            (self.slots[i].eos_id if self.slots[i] is not None
             and self.slots[i].eos_id is not None else -1)
            for i in range(self.num_slots)], np.int32)
        temps = np.array([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.num_slots)], np.float32)
        t0 = time.perf_counter()
        toks, self.cache, token, pos, remaining, done_d, self._key = \
            self._decode_n(
                self.params, self.cache, jnp.asarray(self.last_tok),
                jnp.asarray(self.pos.astype(np.int32)),
                jnp.asarray(self.remaining.astype(np.int32)),
                jnp.asarray(done), jnp.asarray(eos), jnp.asarray(temps),
                self._key)
        # ONE sync per chunk: everything below is host-side numpy
        toks = np.asarray(toks)
        pos = np.asarray(pos)
        token = np.asarray(token)
        remaining = np.asarray(remaining)
        done_d = np.asarray(done_d)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        charges = []
        tenant_tokens: dict[str, int] = {}
        total = 0
        for i in active:
            req = self.slots[i]
            n_gen = int(pos[i]) - int(self.pos[i])
            if n_gen:
                req.output.extend(int(t) for t in toks[i, :n_gen])
                # per-chunk charge: n tokens + KV-line rent summed over the
                # chunk's steps (sum_{j=1..n} pos0+j), exactly the per-token
                # path's total
                kv = n_gen * int(self.pos[i]) + n_gen * (n_gen + 1) // 2
                charges.append((req, n_gen, kv))
                tenant_tokens[req.tenant] = \
                    tenant_tokens.get(req.tenant, 0) + n_gen
                total += n_gen
            self.pos[i] = pos[i]
            self.last_tok[i] = token[i]
            self.remaining[i] = remaining[i]
            if done_d[i]:
                self._finish(i)
        self.admission.charge_bulk(charges)
        self.metrics.counter("serve_tokens_generated").inc(total)
        tok_counter = self.metrics.counter(
            METRIC_SERVE_TENANT_TOKENS, "generated tokens per tenant")
        for tenant, n in tenant_tokens.items():
            tok_counter.inc(n, tenant=tenant)

    def _step_host(self, active: list):
        """Original per-token host loop (baseline / reference path)."""
        token = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos.astype(np.int32))
        t0 = time.perf_counter()
        logits, self.cache = self._step(self.params, self.cache, token, pos)
        self.metrics.histogram("serve_decode_seconds",
                               "batched decode-step latency").observe(
            time.perf_counter() - t0)
        nxt = self._sample(logits)
        tenant_tokens: dict[str, int] = {}
        for i in active:
            req = self.slots[i]
            req.output.append(int(nxt[i]))
            self.pos[i] += 1
            self.last_tok[i] = nxt[i]
            self.remaining[i] -= 1
            # one generated token + rent on the KV lines this slot holds
            self.admission.charge(req, tokens=1, kv_tokens=int(self.pos[i]))
            tenant_tokens[req.tenant] = tenant_tokens.get(req.tenant, 0) + 1
            self._maybe_finish(i)
        self.metrics.counter("serve_tokens_generated").inc(len(active))
        tok_counter = self.metrics.counter(
            METRIC_SERVE_TENANT_TOKENS, "generated tokens per tenant")
        for tenant, n in tenant_tokens.items():
            tok_counter.inc(n, tenant=tenant)

    def run_to_completion(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if self.step() == 0:
                break
