from repro.data.pipeline import (
    DataConfig, PackedStream, PrefetchLoader, EOS, PAD,
)

__all__ = ["DataConfig", "PackedStream", "PrefetchLoader", "EOS", "PAD"]
