"""Deterministic synthetic LM data pipeline with per-host sharding, sequence
packing and background prefetch.

The paper's cluster stores datasets on shared storage (§3.1.4) and every node
reads its slice; here the "shared dataset" is a deterministic token stream
(seeded xorshift over document ids), so every host can materialize exactly
its shard with no files — same access pattern, no I/O dependency.  Documents
have Zipf-ish lengths and are *packed* into fixed-length training sequences
with loss masking across document boundaries, which is what production LM
pipelines do.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    num_hosts: int = 1
    host_id: int = 0
    pack: bool = True

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        assert 0 <= self.host_id < self.num_hosts

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts


def _doc_tokens(doc_id: int, cfg: DataConfig) -> np.ndarray:
    """Deterministic pseudo-document: length + content from the doc id."""
    rng = np.random.default_rng((cfg.seed << 32) ^ doc_id)
    length = int(rng.pareto(2.0) * cfg.mean_doc_len / 2) + 8
    length = min(length, 4 * cfg.mean_doc_len)
    # reserve ids 0/1 as pad/eos
    return rng.integers(2, cfg.vocab_size, length, dtype=np.int32)


EOS = 1
PAD = 0


class PackedStream:
    """Packs the deterministic document stream into (seq_len+1)-token rows.

    Each host consumes a disjoint, strided shard of the document id space —
    `host_id + k * num_hosts` — so global determinism holds for any host
    count (the multi-host analogue of a shared filesystem read).
    """

    def __init__(self, cfg: DataConfig, start_doc: int = 0):
        self.cfg = cfg
        self._doc = start_doc * cfg.num_hosts + cfg.host_id
        self._buf = np.empty(0, np.int32)

    def state(self) -> dict:
        """Checkpointable position (resume without replaying)."""
        return {"doc": self._doc, "buf": self._buf.copy()}

    def restore(self, state: dict):
        self._doc = state["doc"]
        self._buf = state["buf"].copy()

    def _fill(self, need: int):
        parts = [self._buf]
        have = len(self._buf)
        while have < need:
            toks = _doc_tokens(self._doc, self.cfg)
            self._doc += self.cfg.num_hosts
            parts.append(toks)
            parts.append(np.array([EOS], np.int32))
            have += len(toks) + 1
        self._buf = np.concatenate(parts)

    def next_batch(self) -> dict:
        """{tokens (B,S), labels (B,S), loss_mask (B,S)} for this host."""
        cfg = self.cfg
        B, S = cfg.host_batch, cfg.seq_len
        need = B * (S + 1)
        self._fill(need)
        rows = self._buf[:need].reshape(B, S + 1)
        self._buf = self._buf[need:]
        tokens = rows[:, :-1]
        labels = rows[:, 1:]
        # no loss on predicting the token after EOS (next doc's first token)
        mask = (tokens != EOS).astype(np.float32)
        return {"tokens": tokens.copy(), "labels": labels.copy(),
                "loss_mask": mask}


class PrefetchLoader:
    """Background-thread prefetch (the pipeline's I/O overlap)."""

    def __init__(self, stream: PackedStream, depth: int = 2):
        self.stream = stream
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.stream.next_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
