"""Sharded checkpointing: manifest + per-leaf .npy shards + step management.

The paper's storage guidance (§3.1.4 — NAS/DFS for "model checkpoints")
maps to a directory layout any distributed filesystem serves:

    <dir>/step_000100/MANIFEST.json     pytree structure + leaf metadata
    <dir>/step_000100/<leaf>.npy        one array per pytree leaf
    <dir>/step_000100/data_state.npz    data-pipeline position
    <dir>/LATEST                        atomic pointer to the newest step

Writes go to a temp dir and are renamed into place, so a crash mid-save
never corrupts the LATEST checkpoint (the property the guide's "save your
checkpoints to NAS" advice exists to protect).  ``keep`` bounds disk use.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Optional

import jax
import numpy as np

_LEAF_RE = re.compile(r"[^\w.-]+")


def _leaf_name(path) -> str:
    return _LEAF_RE.sub("_", jax.tree_util.keystr(path)).strip("_")


def save(directory: str, step: int, tree, data_state: Optional[dict] = None,
         keep: int = 3) -> str:
    """Save a pytree checkpoint; returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(directory, name)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    tmp = tempfile.mkdtemp(dir=directory, prefix=f".{name}.tmp")
    try:
        manifest = {"step": step, "treedef": None, "leaves": []}
        names = []
        for path, leaf in flat:
            nm = _leaf_name(path)
            assert nm not in names, f"leaf name collision: {nm}"
            names.append(nm)
            arr = np.asarray(leaf)
            dtype_name = str(arr.dtype)
            if dtype_name == "bfloat16":        # np.save can't cast ml_dtypes
                np.save(os.path.join(tmp, nm + ".npy"),
                        arr.view(np.uint16))
            else:
                np.save(os.path.join(tmp, nm + ".npy"), arr)
            manifest["leaves"].append(
                {"name": nm, "shape": list(arr.shape), "dtype": dtype_name})
        # treedef round-trips through the same tree structure: store key paths
        manifest["treedef"] = [_leaf_name(p) for p, _ in flat]
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if data_state is not None:
            np.savez(os.path.join(tmp, "data_state.npz"), **data_state)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _write_latest(directory, name)
    _gc(directory, keep)
    return final


def _write_latest(directory: str, name: str):
    tmp = os.path.join(directory, ".LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(name)
    os.rename(tmp, os.path.join(directory, "LATEST"))


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            return int(f.read().strip().split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(directory: str, tree_like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like`` (ShapeDtypeStructs ok).

    Returns (tree, data_state|None).  With ``shardings`` the arrays are
    device_put per-leaf to the target sharding (resharding restore).
    """
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint under {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    names = [_leaf_name(p) for p, _ in flat]
    assert names == manifest["treedef"], (
        "checkpoint tree mismatch:\n"
        f"  want {names[:5]}...\n  have {manifest['treedef'][:5]}...")
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat))
    stored_dtype = {l["name"]: l["dtype"] for l in manifest["leaves"]}
    leaves = []
    for (p, like), sh in zip(flat, sh_flat):
        nm = _leaf_name(p)
        arr = np.load(os.path.join(path, nm + ".npy"))
        if stored_dtype.get(nm) == "bfloat16":   # stored as a uint16 view
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want_dtype = getattr(like, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    ds_path = os.path.join(path, "data_state.npz")
    data_state = dict(np.load(ds_path, allow_pickle=False)) \
        if os.path.exists(ds_path) else None
    return tree, data_state
