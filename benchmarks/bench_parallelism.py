"""Parallelism-catalog benchmark — the paper's §7 comparison table, measured:
per strategy x ZeRO stage, the per-device parameter/optimizer bytes on the
production mesh (from the sharding specs — no allocation), plus train-step
wall time per strategy on the reduced configs (CPU, 1 device)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import RunConfig, get_config, get_reduced_config
from repro.configs.base import InputShape
from repro.core import sharding as shd
from repro.models import init_params, make_batch
from repro.models.spec import model_spec, ParamSpec
from repro.optim import OptimizerConfig, init_opt_state
from repro.training import make_train_step


class SpecMesh:
    """Shape-only stand-in for the production mesh (16 data x 16 model)."""
    shape = {"data": 16, "model": 16}
    axis_names = ("data", "model")


def _bytes_per_device(cfg, run, kind="param") -> int:
    """Max per-device bytes implied by the PartitionSpec policy."""
    from repro.core.parallelism import get_strategy
    strategy = get_strategy(run.strategy)
    mesh = SpecMesh()
    total = 0

    def walk(tree):
        nonlocal total
        if isinstance(tree, ParamSpec):
            if kind == "param":
                fsdp = strategy.fsdp and run.zero_stage >= 3
            else:
                fsdp = strategy.fsdp or run.zero_stage >= 1
            spec = shd.param_pspec(tree, mesh, strategy, fsdp_override=fsdp)
            shard = 1
            for dim, ax in zip(tree.shape, spec):
                if ax is not None:
                    shard *= mesh.shape[ax]
            n = int(np.prod(tree.shape)) // shard
            total += n * (4 if kind != "param" else 4)
        elif isinstance(tree, dict):
            for v in tree.values():
                walk(v)
        elif isinstance(tree, list):
            for v in tree:
                walk(v)

    walk(model_spec(cfg))
    return total


def bench_strategy_bytes(results: list):
    """The §7.2 ZeRO memory table for three real architectures."""
    for arch in ("qwen2-7b", "dbrx-132b", "mamba2-780m"):
        cfg = get_config(arch)
        rows = []
        for name, run in [
            ("dp", RunConfig(strategy="dp", zero_stage=0)),
            ("tp", RunConfig(strategy="tp", zero_stage=0)),
            ("zero1", RunConfig(strategy="fsdp", zero_stage=1)),
            ("zero3", RunConfig(strategy="fsdp", zero_stage=3)),
            ("fsdp_tp", RunConfig(strategy="fsdp_tp", zero_stage=3)),
        ]:
            p = _bytes_per_device(cfg, run, "param")
            o = 2 * _bytes_per_device(cfg, run, "opt")
            rows.append((name, p, o))
            results.append((f"bytes_per_device_{arch}_{name}",
                            0.0, f"param={p/2**30:.2f}GiB "
                                 f"opt={o/2**30:.2f}GiB"))
        # sanity: ZeRO-3 params <= DP params; composed <= TP
        byname = {r[0]: r for r in rows}
        assert byname["zero3"][1] <= byname["dp"][1]
        assert byname["fsdp_tp"][1] <= byname["tp"][1]


def bench_train_step_wall(results: list):
    """Reduced-config step time per strategy (1 CPU device — relative
    numbers only; the real measurement is the dry-run roofline)."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh(1, 1)
    shape = InputShape("bench", 128, 4, "train")
    for arch in ("stablelm-3b", "qwen2-moe-a2.7b", "mamba2-780m"):
        cfg = get_reduced_config(arch)
        opt = OptimizerConfig(warmup_steps=2, decay_steps=100)
        run = RunConfig(strategy="dp", microbatches=1, remat="none")
        step = make_train_step(cfg, run, mesh, opt)
        params = init_params(cfg, 0)
        state = init_opt_state(params, opt)
        batch = make_batch(cfg, shape, 0)
        params, state, _ = step(params, state, batch)     # compile + donate
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            params, state, m = step(params, state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / reps
        tok_s = shape.global_batch * shape.seq_len / dt
        results.append((f"train_step_reduced_{arch}", dt * 1e6,
                        f"{tok_s:,.0f} tok/s"))


def run(results: list):
    bench_strategy_bytes(results)
    bench_train_step_wall(results)
