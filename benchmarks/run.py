"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/claim:
  bench_scheduler    §3.2.3  scheduling throughput, FIFO vs backfill
  bench_parallelism  §7      ZeRO/TP per-device bytes, step wall time
  bench_serving      §3.2.1  (TensorRT role) decode throughput, prefill
  bench_kernels      §3.1.2  Pallas kernels vs oracle (interpret)
  bench_roofline     —       §Roofline table from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV rows plus the roofline table.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: scheduler parallelism serving kernels "
                         "roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON artifact")
    ap.add_argument("--compare", default=None, metavar="BASELINE_JSON",
                    help="regression gate: exit 1 if any benchmark runs "
                         ">20%% slower than the named --json baseline")
    ap.add_argument("--compare-threshold", type=float, default=0.20,
                    help="allowed fractional slowdown vs baseline "
                         "(default 0.20)")
    ap.add_argument("--update-baseline", nargs="?", const=BASELINE_PATH,
                    default=None, metavar="PATH",
                    help="write this run's results as the JSON baseline "
                         "--compare reads (default benchmarks/"
                         "baseline.json) — replaces hand-editing the CI "
                         "baseline; with --compare, the gate runs against "
                         "the OLD baseline first")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_kernels, bench_parallelism, bench_roofline, bench_scheduler,
        bench_serving,
    )
    suites = {
        "scheduler": bench_scheduler,
        "parallelism": bench_parallelism,
        "serving": bench_serving,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
    }
    picked = args.only or list(suites)
    results: list[tuple[str, float, str]] = []
    t0 = time.perf_counter()
    for name in picked:
        mod = suites[name]
        t = time.perf_counter()
        mod.run(results)
        print(f"[suite {name}: {time.perf_counter() - t:.1f}s]",
              file=sys.stderr)

    print("name,us_per_call,derived")
    for name, us, derived, *_ in results:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        write_results(results, args.json)
    print(f"\n{len(results)} benchmarks in "
          f"{time.perf_counter() - t0:.1f}s", file=sys.stderr)
    rc = 0
    if args.compare:
        rc = compare_against(results, args.compare, args.compare_threshold)
    if args.update_baseline:
        # update AFTER the gate so the comparison ran against the old
        # baseline; the refresh happens even on a failed gate (the caller
        # decided this run is the new reference by passing the flag)
        write_results(results, args.update_baseline)
    return rc


#: where the CI regression gate looks for its committed baseline
BASELINE_PATH = "benchmarks/baseline.json"


def write_results(results, path: str):
    """Serialize results in the artifact/baseline JSON schema (shared by
    --json, --update-baseline, and the --compare reader).  A benchmark
    may append a 4th tuple element — a dict of named latency percentiles
    (e.g. ``ttft_p99_ms``) — which lands under a ``percentiles`` key and
    becomes part of the --compare regression gate."""
    import json
    rows = []
    for name, us, derived, *rest in results:
        row = {"name": name, "us_per_call": round(us, 1),
               "derived": derived}
        if rest and rest[0]:
            row["percentiles"] = rest[0]
        rows.append(row)
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"[wrote {path}]", file=sys.stderr)


def compare_against(results, baseline_path: str,
                    threshold: float = 0.20) -> int:
    """CI regression gate: compare this run against a ``--json`` baseline
    artifact and fail (exit 1) on any >``threshold`` slowdown — i.e. a
    >20%% throughput drop by default.  Percentile keys a benchmark
    records (e.g. ``serving_latency_slo``'s ``ttft_p99_ms``) gate at the
    same threshold when present on BOTH sides, so a p99 TTFT regression
    fails CI even if mean throughput held.  Benchmarks (or percentile
    keys) present on only one side are reported but never fail the gate
    (suites evolve)."""
    import json
    with open(baseline_path) as f:
        rows = json.load(f)
    base = {row["name"]: row["us_per_call"] for row in rows}
    base_pct = {row["name"]: row.get("percentiles", {}) for row in rows}
    regressions = []

    def check(label, old, new):
        ratio = new / old if old > 0 else 1.0
        verdict = "REGRESSION" if ratio > 1.0 + threshold else "ok"
        print(f"[compare] {label}: {old:.1f} -> {new:.1f} "
              f"({ratio:.2f}x) {verdict}", file=sys.stderr)
        if ratio > 1.0 + threshold:
            regressions.append((label, old, new, ratio))

    for name, us, _, *rest in results:
        old = base.get(name)
        if old is None:
            print(f"[compare] {name}: no baseline (new benchmark)",
                  file=sys.stderr)
            continue
        check(name, old, us)
        pct = rest[0] if rest else {}
        old_pct = base_pct.get(name, {})
        shared = set(pct) & set(old_pct)
        if (pct or old_pct) and not shared:
            # a renamed/retyped percentile key silently un-gates the
            # benchmark — name both sides so the drift is visible
            print(f"[compare] WARNING {name}: no shared percentile keys "
                  f"— percentile gate skipped (current: "
                  f"{sorted(pct) or '-'}, baseline: "
                  f"{sorted(old_pct) or '-'})", file=sys.stderr)
        for key in sorted(shared):
            check(f"{name}.{key}", old_pct[key], pct[key])
    missing = sorted(set(base) - {row[0] for row in results})
    if missing:
        # a bench present in the baseline but absent from this run would
        # otherwise sail through the gate unexamined (a renamed bench, or
        # a partial `--only` run against a full baseline) — name the
        # missing keys loudly, but never fail on them (suites evolve and
        # CI legitimately gates subsets)
        print(f"[compare] WARNING: {len(missing)} baseline bench(es) not "
              f"in this run, gate skipped for: {', '.join(missing)}",
              file=sys.stderr)
    if regressions:
        print(f"[compare] FAIL: {len(regressions)} regression(s) beyond "
              f"{threshold:.0%}", file=sys.stderr)
        return 1
    print("[compare] gate passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
