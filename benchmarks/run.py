"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/claim:
  bench_scheduler    §3.2.3  scheduling throughput, FIFO vs backfill
  bench_parallelism  §7      ZeRO/TP per-device bytes, step wall time
  bench_serving      §3.2.1  (TensorRT role) decode throughput, prefill
  bench_kernels      §3.1.2  Pallas kernels vs oracle (interpret)
  bench_roofline     —       §Roofline table from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV rows plus the roofline table.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: scheduler parallelism serving kernels "
                         "roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON artifact")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_kernels, bench_parallelism, bench_roofline, bench_scheduler,
        bench_serving,
    )
    suites = {
        "scheduler": bench_scheduler,
        "parallelism": bench_parallelism,
        "serving": bench_serving,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
    }
    picked = args.only or list(suites)
    results: list[tuple[str, float, str]] = []
    t0 = time.perf_counter()
    for name in picked:
        mod = suites[name]
        t = time.perf_counter()
        mod.run(results)
        print(f"[suite {name}: {time.perf_counter() - t:.1f}s]",
              file=sys.stderr)

    print("name,us_per_call,derived")
    for name, us, derived in results:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump([{"name": name, "us_per_call": round(us, 1),
                        "derived": derived}
                       for name, us, derived in results], f, indent=2)
        print(f"[wrote {args.json}]", file=sys.stderr)
    print(f"\n{len(results)} benchmarks in "
          f"{time.perf_counter() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
