"""Roofline summary — renders EXPERIMENTS.md §Roofline from the dry-run
JSONs under results/dryrun/.  One row per (arch x shape): the three terms,
the bottleneck, and MODEL_FLOPS/HLO_FLOPs."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_records(mesh="single"):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(mesh="single") -> str:
    recs = load_records(mesh)
    if not recs:
        return "(no dry-run records — run repro.launch.dryrun first)"
    hdr = (f"{'arch':<22} {'shape':<12} {'C(s)':>9} {'M(s)':>9} {'N(s)':>9} "
           f"{'bound':<7} {'useful':>6} {'peakGB':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        rl = r["roofline"]
        peak = r.get("memory", {}).get("peak_bytes", 0) / 2**30
        lines.append(
            f"{r['arch']:<22} {r['shape']:<12} "
            f"{rl['compute_s']:>9.3g} {rl['memory_s']:>9.3g} "
            f"{rl['collective_s']:>9.3g} {rl['bottleneck']:<7} "
            f"{r['useful_flops_ratio']:>6.2f} {peak:>7.2f}")
    return "\n".join(lines)


def run(results: list):
    recs = load_records()
    for r in recs:
        rl = r["roofline"]
        dom = max(("compute_s", "memory_s", "collective_s"),
                  key=lambda k: rl[k])
        results.append((f"roofline_{r['arch']}_{r['shape']}",
                        rl[dom] * 1e6,
                        f"bound={rl['bottleneck']} "
                        f"useful={r['useful_flops_ratio']:.2f}"))
    if recs:
        print()
        print(table())
