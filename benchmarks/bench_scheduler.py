"""Scheduler benchmarks — the paper's §3.2.3 claims ("scalability, fairness,
backfill") quantified: scheduling throughput, and utilization/makespan of
FIFO vs EASY vs conservative backfill on a synthetic trace."""
from __future__ import annotations

import time

import numpy as np

from repro.cluster import Cluster, Node, Partition, ResourceRequest


def _cluster(n_nodes=64, mode="easy"):
    nodes = [Node(name=f"n{i:03d}", cpus=16, mem_mb=65536, gres={"tpu": 4},
                  coord=(i // 8, i % 8)) for i in range(n_nodes)]
    parts = [Partition(name="p", nodes=tuple(n.name for n in nodes),
                       default=True)]
    return Cluster(nodes, parts, sched_mode=mode)


def _trace(rng, n_jobs=200):
    """Mixed trace: many small short jobs + some wide long ones.  Node
    counts must tile a rectangle of the 8x8 host grid (TPU contiguity) or
    they would pend forever."""
    jobs = []
    for i in range(n_jobs):
        wide = rng.random() < 0.2
        nodes = int(rng.choice([8, 16, 32])) if wide \
            else int(rng.choice([1, 2, 3, 4]))
        rt = float(rng.integers(300, 3600)) if wide \
            else float(rng.integers(30, 600))
        jobs.append((nodes, rt, int(rng.integers(0, 10))))
    return jobs


def bench_scheduling_throughput(results: list):
    c = _cluster()
    rng = np.random.default_rng(0)
    jobs = _trace(rng, 400)
    t0 = time.perf_counter()
    for i, (n, rt, prio) in enumerate(jobs):
        c.submit(f"j{i}", ResourceRequest(
            nodes=n, gres_per_node={"tpu": 4}, time_limit_s=7200),
            run_time_s=rt, priority=prio)
    n_events = 0
    while c.tick():
        n_events += 1
    dt = time.perf_counter() - t0
    results.append(("scheduler_submit_and_drain_400_jobs",
                    dt * 1e6 / 400, f"{400 / dt:,.0f} jobs/s"))


def bench_backfill_modes(results: list):
    """Makespan + utilization per §3.2.3 scheduler mode, same trace."""
    rng = np.random.default_rng(1)
    jobs = _trace(rng, 150)
    out = {}
    for mode in ("fifo", "easy", "conservative"):
        c = _cluster(mode=mode)
        t0 = time.perf_counter()
        for i, (n, rt, prio) in enumerate(jobs):
            c.submit(f"j{i}", ResourceRequest(
                nodes=n, gres_per_node={"tpu": 4}, time_limit_s=7200),
                run_time_s=rt, priority=prio)
        stuck = c.run()
        assert not stuck, f"{mode}: {len(stuck)} jobs never ran"
        dt = time.perf_counter() - t0
        makespan = max(r.end for r in c.accounting)
        busy = sum(r.elapsed * len(r.nodes) for r in c.accounting)
        util = busy / (makespan * len(c.nodes))
        out[mode] = (makespan, util)
        results.append((f"scheduler_makespan_{mode}", dt * 1e6,
                        f"makespan={makespan:,.0f}s util={util:.1%}"))
    # backfill must beat FIFO on this trace
    assert out["easy"][0] <= out["fifo"][0] * 1.001, out
    return out


def bench_node_clone(results: list):
    """The per-pass working copy: Node.clone() vs copy.deepcopy (the clone
    replaced deepcopy in scheduler.schedule_pass)."""
    import copy

    from repro.cluster import Node
    nodes = [Node(name=f"n{i:03d}", cpus=16, mem_mb=65536, gres={"tpu": 4},
                  coord=(i // 8, i % 8)) for i in range(64)]
    for n in nodes[::2]:
        n.allocate(1, 4, 8192, {"tpu": 2})
    reps = 200
    t0 = time.perf_counter()
    for _ in range(reps):
        _ = {n.name: copy.deepcopy(n) for n in nodes}
    t_deep = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        _ = {n.name: n.clone() for n in nodes}
    t_clone = time.perf_counter() - t0
    results.append(("scheduler_node_clone_64_nodes", t_clone * 1e6 / reps,
                    f"deepcopy={t_deep * 1e6 / reps:,.0f}us "
                    f"speedup={t_deep / t_clone:.1f}x"))


def bench_dirty_set_256_nodes(results: list):
    """schedule_pass used to clone *all* nodes into its working copy; the
    ShadowNodes copy-on-write view clones only the dirty set (nodes touched
    by tentative placements).  256 mostly-busy nodes, 8 startable jobs:
    the pass clones 8 nodes, not 256."""
    from repro.cluster.job import Job, JobState
    from repro.cluster.scheduler import ShadowNodes, schedule_pass

    n_nodes, n_busy, n_pending = 256, 248, 8
    nodes = {
        f"n{i:03d}": Node(name=f"n{i:03d}", cpus=16, mem_mb=65536,
                          gres={"tpu": 4}, coord=(i // 16, i % 16))
        for i in range(n_nodes)}
    part = Partition(name="p", nodes=tuple(nodes), default=True)
    r = ResourceRequest(nodes=1, gres_per_node={"tpu": 4}, time_limit_s=7200)
    running, pending = [], []
    for jid, nm in enumerate(list(nodes)[:n_busy], start=1):
        job = Job(job_id=jid, name=f"r{jid}", user="u", partition="p", req=r,
                  run_time_s=3600.0)
        job.state = JobState.RUNNING
        job.start_time = 0.0
        job.nodes_alloc = (nm,)
        nodes[nm].allocate(jid, r.cpus_per_node, r.mem_mb_per_node,
                           r.gres_per_node)
        running.append(job)
    for k in range(n_pending):
        pending.append(Job(job_id=1000 + k, name=f"p{k}", user="u",
                           partition="p", req=r, run_time_s=600.0))

    reps = 30
    t0 = time.perf_counter()
    for _ in range(reps):
        decision = schedule_pass(1.0, pending, running, nodes, {"p": part})
    t_pass = (time.perf_counter() - t0) / reps
    assert len(decision.starts) == n_pending, decision

    # the eliminated overhead: the old full clone of the whole inventory
    t0 = time.perf_counter()
    for _ in range(reps):
        _ = {nm: nd.clone() for nm, nd in nodes.items()}
    t_full = (time.perf_counter() - t0) / reps

    shadow = ShadowNodes(nodes)
    for job_id, alloc in decision.starts:
        for nm in alloc:
            shadow.mutate(nm)
    results.append((
        "scheduler_pass_256_nodes_dirty_set", t_pass * 1e6,
        f"dirty={shadow.dirty_count}/{n_nodes} nodes cloned; "
        f"full-clone overhead {t_full * 1e6:,.0f}us/pass "
        f"({(t_pass + t_full) / t_pass:.1f}x pass speedup)"))


def bench_fairshare_scenario(results: list):
    """Two accounts at a 10:1 share ratio submitting identical mixed-QOS
    demand: report queue-wait fairness (mean wait per account) and the
    scheduler pass latency under the multifactor engine."""
    from repro.cluster import commands

    c = _cluster(n_nodes=16)
    commands.sacctmgr_add_account(c, "prod", fairshare=10)
    commands.sacctmgr_add_account(c, "research", fairshare=1)
    commands.sacctmgr_add_user(c, "alice", "prod")
    commands.sacctmgr_add_user(c, "bob", "research")

    rng = np.random.default_rng(2)
    users = [("alice", "high"), ("alice", "normal"),
             ("bob", "normal"), ("bob", "scavenger")]
    t0 = time.perf_counter()
    n_jobs = 120
    for i in range(n_jobs):
        user, qos = users[int(rng.integers(0, len(users)))]
        n = int(rng.choice([1, 2, 4]))
        c.submit(f"j{i}", ResourceRequest(
            nodes=n, gres_per_node={"tpu": 4}, time_limit_s=7200),
            run_time_s=float(rng.integers(60, 900)), user=user, qos=qos,
            ckpt_interval_s=60.0)
    stuck = c.run()
    dt = time.perf_counter() - t0
    assert not stuck, f"{len(stuck)} jobs never ran"

    waits: dict[str, list[float]] = {"prod": [], "research": []}
    final = {}
    for r in c.accounting:              # last segment per job
        final[r.job_id] = r
    for r in final.values():
        waits[r.account].append(r.start - r.submit)
    mean = {a: (sum(w) / len(w) if w else 0.0) for a, w in waits.items()}
    results.append((
        "scheduler_fairshare_2acct_10to1",
        dt * 1e6 / n_jobs,
        f"wait prod={mean['prod']:,.0f}s research={mean['research']:,.0f}s "
        f"preemptions={c.preemptions_total}"))


def run(results: list):
    bench_scheduling_throughput(results)
    bench_backfill_modes(results)
    bench_node_clone(results)
    bench_dirty_set_256_nodes(results)
    bench_fairshare_scenario(results)
