"""Pallas kernel benchmarks (interpret mode on CPU): correctness-at-size
plus call latency vs the pure-jnp oracle.  Interpret mode executes the
kernel body in Python, so latency here validates plumbing, not TPU speed —
the TPU claim lives in the BlockSpec arithmetic documented in kernels/."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import attention_ref, ssd_ref


def bench_flash_attention(results: list):
    rng = np.random.default_rng(0)
    B, S, H, K, Dh = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, Dh)), jnp.float32)
    t0 = time.perf_counter()
    out = ops.flash_attention(q, k, v, block_q=128, block_k=128,
                              interpret=True)
    dt = time.perf_counter() - t0
    ref = attention_ref(q, k, v)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 2e-4, err
    results.append(("flash_attention_512_interpret", dt * 1e6,
                    f"max_err={err:.2e}"))


def bench_ssd_scan(results: list):
    rng = np.random.default_rng(1)
    B, S, H, P, N = 1, 512, 4, 32, 32
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt_ = jnp.asarray(rng.random((B, S, H)) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(rng.random((H,)) + 0.5, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    t0 = time.perf_counter()
    y = ops.ssd_scan(x, dt_, A, Bm, Cm, chunk=128, interpret=True)
    el = time.perf_counter() - t0
    ref = ssd_ref(x, dt_, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(y - ref)))
    assert err < 2e-3, err
    results.append(("ssd_scan_512_interpret", el * 1e6,
                    f"max_err={err:.2e}"))


def run(results: list):
    bench_flash_attention(results)
    bench_ssd_scan(results)
