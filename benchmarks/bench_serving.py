"""Serving benchmarks: device-resident fused decode vs the per-token host
loop (the fast-path claim), batched-decode throughput scaling with slot
count (the continuous-batching claim), bucketed-prefill compile counts,
paged-KV concurrent capacity at a fixed HBM budget (the PagedAttention
claim), radix prefix-cache prefill reduction for shared system prompts
(the SGLang-RadixAttention claim), speculative decoding throughput on
repeat-heavy single-stream workloads (the draft-and-verify claim),
tensor-parallel concurrent capacity at a fixed per-device HBM budget
(the sharded-KV-pool claim), and prefill latency vs prompt length."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.serving import DecodeEngine, Request


def _throughput(cfg, params, slots: int, **engine_kw):
    """Returns (tokens/sec, wall seconds) for 8 requests x 16 new tokens."""
    rng = np.random.default_rng(0)
    eng = DecodeEngine(cfg, params, num_slots=slots, cache_len=128,
                       **engine_kw)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 16).astype(
                        np.int32), max_new_tokens=16)
            for i in range(8)]
    for r in reqs:
        eng.submit(r)
    eng.step()                          # absorb compile time
    warm = int(eng.metrics.counter("serve_tokens_generated").value())
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    # count only tokens generated inside the timed window (the fused
    # warm-up step emits a whole chunk, so including it would flatter
    # the fused numbers)
    toks = int(eng.metrics.counter("serve_tokens_generated").value()) - warm
    return toks / dt, dt


def bench_decode_throughput(results: list):
    """Host loop vs fused chunk at 1 and 4 slots.  Claims asserted:
    batching scales (4 slots > 1.3x 1 slot on the host path) and the
    device-resident fast path is >= 2x the per-token host loop at 4
    slots."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    host, fused = {}, {}
    for slots in (1, 4):
        host[slots], dt = _throughput(cfg, params, slots, fused=False)
        results.append((f"decode_throughput_slots{slots}", dt * 1e6,
                        f"{host[slots]:,.0f} tok/s host loop"))
    for slots in (1, 4):
        fused[slots], dt = _throughput(cfg, params, slots, decode_chunk=8,
                                       prefill_buckets="auto")
        results.append((f"decode_throughput_fused_slots{slots}", dt * 1e6,
                        f"{fused[slots]:,.0f} tok/s fused chunk=8 "
                        f"({fused[slots] / host[slots]:.1f}x host)"))
    # batching must help, and the fused path must beat per-token dispatch
    assert host[4] > host[1] * 1.3, (host, fused)
    assert fused[4] >= host[4] * 2.0, (host, fused)


def bench_prefill_bucketed(results: list):
    """Mixed prompt lengths through bucketed prefill: compilations are
    bounded by the bucket count, not the number of distinct lengths."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(2)
    eng = DecodeEngine(cfg, params, num_slots=4, cache_len=128,
                       decode_chunk=8, prefill_buckets="auto")
    lengths = [int(p) for p in rng.integers(4, 100, 20)]
    t0 = time.perf_counter()
    for i, plen in enumerate(lengths):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(
                np.int32), max_new_tokens=2))
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    compiles = eng.prefill_compilations()
    buckets = eng.prefill_buckets
    results.append(("prefill_bucketed", dt * 1e6,
                    f"{compiles} prefill compiles for {len(lengths)} "
                    f"prompts ({len(set(lengths))} distinct lengths, "
                    f"{len(buckets)} buckets)"))
    assert compiles <= len(buckets), (compiles, buckets)


def bench_paged_capacity(results: list):
    """The paged-KV headline claim: at the SAME HBM budget, page tables
    serve >= 2x the concurrent short requests a dense per-slot cache can,
    because a short request holds ceil(tokens/page) pages instead of
    pinning cache_len lines.  Budget: 4 dense slots x 128 lines = 512
    lines = 32 usable 16-line pages."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    cache_len, page = 128, 16
    budget_lines = 4 * cache_len

    def peak_concurrency(**engine_kw):
        rng = np.random.default_rng(3)
        eng = DecodeEngine(cfg, params, cache_len=cache_len,
                           decode_chunk=4, prefill_buckets="auto",
                           **engine_kw)
        for i in range(24):                 # short: ~2 pages each
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab_size, 12).astype(
                    np.int32), max_new_tokens=12))
        peak, t0 = 0, time.perf_counter()
        for _ in range(2_000):
            n = eng.step()
            peak = max(peak, eng.active())
            if n == 0:
                break
        return peak, time.perf_counter() - t0, eng

    # dense: the budget caps the engine at 4 whole-cache slots
    dense_peak, dense_dt, _ = peak_concurrency(
        num_slots=budget_lines // cache_len)
    # paged: same budget in pages; slots bounded by the page pool instead
    paged_peak, paged_dt, eng = peak_concurrency(
        num_slots=16, kv_page_size=page,
        kv_pages=budget_lines // page + 1)
    results.append(("serving_paged_capacity", paged_dt * 1e6,
                    f"peak {paged_peak} concurrent vs {dense_peak} dense "
                    f"at equal budget ({paged_peak / dense_peak:.1f}x, "
                    f"high-water {eng.allocator.high_water}/"
                    f"{eng.paging.usable_pages} pages)"))
    assert paged_peak >= 2 * dense_peak, (paged_peak, dense_peak)


def bench_prefix_reuse(results: list):
    """The prefix-cache headline claim: 16 requests sharing a long system
    prompt (400 of 408 prompt tokens common) spend >= 2x less wall time
    in prefill when the radix index maps the shared pages read-only and
    only the per-request suffix runs — measured >= 3x target — at the
    same HBM budget, with greedy outputs bit-identical to the no-reuse
    path."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(5)
    cache_len, page = 512, 16
    system = rng.integers(2, cfg.vocab_size, 400).astype(np.int32)
    tails = [rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
             for _ in range(16)]

    def serve(prefix_cache):
        eng = DecodeEngine(cfg, params, num_slots=8, cache_len=cache_len,
                           decode_chunk=4, prefill_buckets="auto",
                           kv_page_size=page, prefix_cache=prefix_cache)
        # warm-up: compiles the prefill programs (full + suffix buckets)
        # and, with reuse on, seeds the radix index — so the timed window
        # measures prefill math, not compilation
        for rid, tail in ((100, tails[0]), (101, tails[1])):
            eng.submit(Request(rid=rid,
                               prompt=np.concatenate([system, tail]),
                               max_new_tokens=2))
        eng.run_to_completion()
        hist = eng.metrics.histogram("serve_prefill_seconds")
        base = hist.sum()
        reqs = [Request(rid=i, prompt=np.concatenate([system, tail]),
                        max_new_tokens=8)
                for i, tail in enumerate(tails)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        return hist.sum() - base, [r.output for r in reqs], eng

    full_t, full_out, _ = serve(False)
    reuse_t, reuse_out, eng = serve(True)
    speedup = full_t / reuse_t
    reused = int(eng.metrics.counter("serve_prefix_reused_tokens").value())
    results.append(("serving_prefix_reuse", reuse_t * 1e6,
                    f"prefill {speedup:.1f}x faster with prefix reuse "
                    f"({full_t * 1e3:.0f} -> {reuse_t * 1e3:.0f} ms for 16 "
                    f"shared-prompt requests, {reused} tokens reused)"))
    # greedy decode must not notice the reuse — bit-identical outputs
    assert reuse_out == full_out, "prefix reuse changed greedy output"
    assert speedup >= 2.0, (full_t, reuse_t)


def bench_latency_slo(results: list):
    """Request-lifecycle tracing on a bursty two-tenant mixed-length
    workload: records TTFT/ITL percentiles from the tracer's derived SLO
    histograms into the bench JSON (so ``run.py --compare`` can gate on
    p99 TTFT regressions) and asserts the tracer costs < 5% tok/s vs
    tracing disabled.  The tracer attaches to an already-warm engine, so
    both measurements run the same compiled programs."""
    from repro.monitoring import Tracer
    from repro.monitoring.trace import METRIC_SERVE_ITL, METRIC_SERVE_TTFT
    from repro.serving import AdmissionController

    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(7)
    admission = AdmissionController()
    admission.add_tenant("interactive", shares=4)
    admission.add_tenant("batch", shares=1)
    eng = DecodeEngine(cfg, params, num_slots=4, cache_len=128,
                       decode_chunk=8, prefill_buckets="auto",
                       admission=admission)

    def make_requests():
        reqs = []
        for i in range(12):
            short = i % 2 == 0
            reqs.append(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    8 if short else 48).astype(np.int32),
                max_new_tokens=16,
                tenant="interactive" if short else "batch",
                qos="high" if short else "normal"))
        return reqs

    def serve_burst():
        reqs = make_requests()
        warm = int(eng.metrics.counter("serve_tokens_generated").value())
        t0 = time.perf_counter()
        for w in range(2):                       # two bursts: real queueing
            for r in reqs[w * 6:(w + 1) * 6]:
                eng.submit(r)
            if w == 0:
                eng.step()
                eng.step()
        eng.run_to_completion()
        dt = time.perf_counter() - t0
        toks = int(
            eng.metrics.counter("serve_tokens_generated").value()) - warm
        return toks / dt, dt

    serve_burst()                                # absorb compile time
    base_tps = max(serve_burst()[0] for _ in range(2))
    tracer = Tracer()
    eng.tracer = tracer                          # attach post-warm-up
    eng.admission.tracer = tracer
    traced = [serve_burst() for _ in range(2)]
    traced_tps = max(t for t, _ in traced)
    ttft = tracer.metrics.histogram(METRIC_SERVE_TTFT)
    itl = tracer.metrics.histogram(METRIC_SERVE_ITL)
    labels = {"tenant": "interactive", "qos": "high"}
    assert ttft.count(**labels) > 0 and itl.count(**labels) > 0, \
        "tracer recorded no interactive-tier SLO samples"
    percentiles = {
        "ttft_p50_ms": round(ttft.quantile(0.5, **labels) * 1e3, 3),
        "ttft_p99_ms": round(ttft.quantile(0.99, **labels) * 1e3, 3),
        "itl_p50_ms": round(itl.quantile(0.5, **labels) * 1e3, 3),
        "itl_p99_ms": round(itl.quantile(0.99, **labels) * 1e3, 3),
    }
    results.append(("serving_latency_slo", traced[-1][1] * 1e6,
                    f"{traced_tps:,.0f} tok/s traced vs {base_tps:,.0f} "
                    f"untraced ({1 - traced_tps / base_tps:+.1%} overhead), "
                    f"interactive TTFT p99 {percentiles['ttft_p99_ms']:.1f}ms",
                    percentiles))
    assert traced_tps >= 0.95 * base_tps, (base_tps, traced_tps)


def bench_chunked_prefill_ttft(results: list):
    """The continuous-batching headline claim: under a bursty two-tenant
    mixed-length workload, token-budgeted serving (``max_batch_tokens``)
    improves short-request p99 TTFT >= 2x over classic paged serving —
    a long prompt's whole-prompt prefill no longer head-of-line blocks
    the wave's short prompts, because the budgeted engine admits it as a
    partial and packs its prefill chunk-by-chunk AFTER the shorts —
    while aggregate throughput stays within 10% and greedy outputs stay
    bit-identical.  The long prompt (700 tokens, 1024 cache) sits just
    past half its power-of-two prefill bucket, so classic serving also
    pays ~1.5x padding compute per prefill that the chunked path — which
    only ever computes real tokens — never does."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    cache_len, page = 1024, 16
    waves, shorts_per_wave = 4, 3

    def make_wave(w, rng):
        # the long submits FIRST so classic admission picks it first
        # (arrival order breaks the fair-share tie) and its whole-prompt
        # prefill blocks the wave's shorts — the HOL scenario
        reqs = [Request(rid=w * 10,
                        prompt=rng.integers(
                            2, cfg.vocab_size, 700).astype(np.int32),
                        max_new_tokens=4, tenant="batch")]
        for i in range(shorts_per_wave):
            reqs.append(Request(
                rid=w * 10 + 1 + i,
                prompt=rng.integers(2, cfg.vocab_size,
                                    8 + 2 * i).astype(np.int32),
                max_new_tokens=64, tenant="interactive"))
        return reqs

    def serve(max_batch_tokens):
        from repro.serving import AdmissionController
        admission = AdmissionController()
        admission.add_tenant("interactive", shares=4)
        admission.add_tenant("batch", shares=1)
        eng = DecodeEngine(cfg, params, num_slots=1 + shorts_per_wave,
                           cache_len=cache_len, decode_chunk=8,
                           prefill_buckets="auto", kv_page_size=page,
                           admission=admission,
                           max_batch_tokens=max_batch_tokens)
        rng = np.random.default_rng(11)
        for r in make_wave(9, rng):      # warm-up wave: absorb compiles
            eng.submit(r)
        eng.run_to_completion()
        warm = int(eng.metrics.counter("serve_tokens_generated").value())
        ttfts, outputs = [], {}
        t0 = time.perf_counter()
        for w in range(waves):
            wave = make_wave(w, rng)
            t_submit = time.perf_counter()
            for r in wave:
                eng.submit(r)
            pending = {r.rid: r for r in wave if r.tenant == "interactive"}
            while eng.step() > 0:
                now = time.perf_counter()
                for rid in [i for i, r in pending.items() if r.output]:
                    ttfts.append(now - t_submit)
                    del pending[rid]
            outputs.update((r.rid, list(r.output)) for r in wave)
        dt = time.perf_counter() - t0
        toks = int(
            eng.metrics.counter("serve_tokens_generated").value()) - warm
        return np.asarray(sorted(ttfts)), toks / dt, outputs

    ttft_base, tps_base, out_base = serve(None)
    ttft_chunk, tps_chunk, out_chunk = serve(128)
    p99_base = float(np.quantile(ttft_base, 0.99))
    p99_chunk = float(np.quantile(ttft_chunk, 0.99))
    speedup = p99_base / p99_chunk
    results.append((
        "serving_chunked_prefill", p99_chunk * 1e6,
        f"short-request p99 TTFT {speedup:.1f}x better with chunked "
        f"prefill ({p99_base * 1e3:.0f} -> {p99_chunk * 1e3:.0f} ms), "
        f"{tps_chunk:,.0f} vs {tps_base:,.0f} tok/s",
        {"ttft_p99_ms_budgeted": round(p99_chunk * 1e3, 3),
         "ttft_p99_ms_classic": round(p99_base * 1e3, 3)}))
    # greedy decode must not notice the chunking — bit-identical outputs
    assert out_chunk == out_base, "chunked prefill changed greedy output"
    assert speedup >= 2.0, (p99_base, p99_chunk)
    assert tps_chunk >= 0.9 * tps_base, (tps_base, tps_chunk)


def bench_speculative_tokps(results: list):
    """The speculative-decoding headline claim: on a repeat-heavy
    single-stream workload (the regime where batching cannot help —
    one request, lanes idle), prompt-lookup draft-and-verify with k=4
    lifts decode throughput >= 1.3x over the fused non-speculative
    engine (measured ~3x), with greedy output bit-identical: a verify
    round scores all drafts in ONE dispatch whose rows reproduce the
    sequential decode logits exactly, so wrong drafts cost speed, never
    tokens.  The acceptance rate lands in the bench JSON so ``run.py
    --compare`` can catch draft-quality regressions separately from
    raw tok/s."""
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(0)
    base = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompt = np.concatenate([base] * 6)      # looped phrase: drafts match

    def serve(speculate):
        eng = DecodeEngine(cfg, params, num_slots=1, cache_len=256,
                           decode_chunk=8, prefill_buckets="auto",
                           kv_page_size=16, speculate=speculate)
        # warm-up request absorbs compiles (and, with speculation on,
        # feeds the cross-request n-gram index like a steady state would)
        eng.submit(Request(rid=99, prompt=prompt.copy(),
                           max_new_tokens=96))
        eng.run_to_completion()
        warm = int(eng.metrics.counter("serve_tokens_generated").value())
        req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=96)
        t0 = time.perf_counter()
        eng.submit(req)
        eng.run_to_completion()
        dt = time.perf_counter() - t0
        toks = int(eng.metrics.counter(
            "serve_tokens_generated").value()) - warm
        return toks / dt, dt, list(req.output), eng

    base_tps, _, base_out, _ = serve(0)
    spec_tps, spec_dt, spec_out, eng = serve(4)
    st = eng.spec_stats
    rate = st["accepted"] / st["proposed"] if st["proposed"] else 0.0
    speedup = spec_tps / base_tps
    results.append((
        "serving_speculative_tokps", spec_dt * 1e6,
        f"{spec_tps:,.0f} tok/s speculative (k=4, ngram) vs "
        f"{base_tps:,.0f} non-speculative ({speedup:.1f}x), "
        f"accepted {st['accepted']}/{st['proposed']} drafts ({rate:.0%})",
        # gated keys are lower-is-better (the gate fails on increases):
        # per-token latency catches throughput regressions, draft waste
        # (rejected fraction) catches draft-quality regressions even
        # when raw tok/s holds
        {"spec_tok_ms": round(1e3 / spec_tps, 3),
         "spec_draft_waste": round(1.0 - rate, 3)}))
    # speculation must never change greedy output — and must pay its way
    assert spec_out == base_out, "speculation changed greedy output"
    assert speedup >= 1.3, (base_tps, spec_tps)


def bench_tp_capacity(results: list):
    """The tensor-parallel serving headline claim: sharding the paged KV
    pool along KV heads puts HALF of every page on each of 2 devices, so
    the SAME per-device HBM budget backs 2x the logical pages and
    >= 1.8x the concurrent short requests — with greedy outputs
    bit-identical to TP=1.  TP >= 2 needs real devices and this process
    pinned the platform to one at import, so the measurement runs in a
    subprocess with 2 forced host devices (the repo's multi-device CPU
    recipe); this process parses its JSON report."""
    import json
    import os
    import subprocess
    import sys
    code = r'''
import dataclasses, json, time
import numpy as np, jax
assert len(jax.devices()) == 2, jax.devices()
from repro.configs import get_reduced_config
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.serving import DecodeEngine, Request

# float32: TP reductions run in f32, so greedy decode is bit-identical
# across TP degrees for f32 models; bf16 activations quantize logits to
# ~1e-2 ulps and a reassociated sum can flip an exact near-tie argmax
cfg = dataclasses.replace(get_reduced_config("stablelm-3b"),
                          dtype="float32")
params = init_params(cfg, 0)
cache_len, page = 128, 16
budget_lines = 512                      # per-DEVICE HBM budget in KV lines

def serve(mesh, usable_pages, n_req, max_new):
    rng = np.random.default_rng(3)
    eng = DecodeEngine(cfg, params, num_slots=n_req, cache_len=cache_len,
                       decode_chunk=4, prefill_buckets="auto",
                       kv_page_size=page, kv_pages=usable_pages + 1,
                       mesh=mesh)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 12).astype(
                        np.int32), max_new_tokens=max_new)
            for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    peak, t0 = 0, time.perf_counter()
    for _ in range(5_000):
        n = eng.step()
        peak = max(peak, eng.active())
        if n == 0:
            break
    return (peak, time.perf_counter() - t0,
            [list(r.output) for r in reqs], eng)

# bit-identity on a starvation-free workload (pool covers every request
# on both sides): the guarantee is per-schedule — a starved pool
# requeues, and the resume re-prefills the partial through a different
# (bucketed) program whose f32 reassociation is not bitwise the
# incremental decode, independent of TP
_, _, base_out, _ = serve(None, 64, 8, 12)
_, _, tp_out, _ = serve(make_mesh(1, 2), 64, 8, 12)

# capacity at equal per-device HBM: one device's budget IS the pool;
# two shards hold half a page each, so the same budget backs 2x pages
base_peak, base_dt, _, _ = serve(None, budget_lines // page, 48, 24)
tp_peak, tp_dt, _, eng = serve(make_mesh(1, 2),
                               2 * (budget_lines // page), 48, 24)
print(json.dumps({
    "base_peak": base_peak, "base_dt": base_dt,
    "tp_peak": tp_peak, "tp_dt": tp_dt,
    "identical": tp_out == base_out,
    "high_water": eng.allocator.high_water,
    "tp_pages": eng.paging.usable_pages,
    "plan": eng.tp_stats()["plan"],
}))
'''
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    rep = json.loads(r.stdout.splitlines()[-1])
    ratio = rep["tp_peak"] / rep["base_peak"]
    results.append(("serving_tp_capacity", rep["tp_dt"] * 1e6,
                    f"peak {rep['tp_peak']} concurrent on 2 shards vs "
                    f"{rep['base_peak']} on one device at equal per-device "
                    f"HBM ({ratio:.1f}x, high-water {rep['high_water']}/"
                    f"{rep['tp_pages']} pages, {rep['plan']})"))
    # sharding must never change greedy output — and must buy capacity
    assert rep["identical"], "TP=2 changed greedy output"
    assert ratio >= 1.8, (rep["base_peak"], rep["tp_peak"])


def bench_router_scaling(results: list):
    """The elastic-serving claims.

    (a) Aggregate throughput: 2 replicas >= 1.8x 1 replica at equal
    per-replica HBM.  Replicas share nothing — a parallel deployment's
    wall clock is the *busiest* replica's compute time — so aggregate
    tok/s is tokens / max per-replica busy seconds, each replica's
    dispatches timed for real.  In-process the dispatches serialize, so
    this is also an honest router-balance gate: a skewed router piles
    the work (and the busy seconds) onto one replica and the ratio
    collapses to ~1x.  Greedy outputs must stay bit-identical to the
    single-replica run.

    (b) Affinity hit rate: prefix-affinity routing >= 1.5x round-robin's
    prefix-cache hit rate on 16 requests drawn from two 400-token
    system-prompt groups, with each replica's page pool sized to hold
    roughly ONE group's prefix.  Affinity pins each group to one
    replica's radix index (one cold miss per group); round-robin
    interleaves both groups onto both replicas and LRU-thrashes the
    pools.
    """
    from repro.monitoring import MetricsRegistry
    from repro.serving import Router
    from repro.serving.router import HashRing, affinity_key

    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)

    # ------------------------------------------ (a) throughput scaling ----
    def serve(n_replicas):
        metrics = MetricsRegistry()

        def make_engine(admission):
            return DecodeEngine(cfg, params, num_slots=4, cache_len=128,
                                metrics=metrics, admission=admission,
                                decode_chunk=8, prefill_buckets="auto")

        # round-robin + a uniform workload = exact per-replica balance,
        # so part (a) measures scaling, not placement luck
        router = Router(make_engine, replicas=n_replicas, policy="rr",
                        metrics=metrics)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 16).astype(
                            np.int32), max_new_tokens=16)
                for i in range(16)]
        wrng = np.random.default_rng(1)
        warm = [Request(rid=100 + i,
                        prompt=wrng.integers(0, cfg.vocab_size, 16).astype(
                            np.int32), max_new_tokens=16)
                for i in range(2 * n_replicas)]
        for r in warm:                  # absorb per-engine compiles
            router.submit(r)
        router.run_to_completion()
        for rep in router.replicas.values():
            rep.busy_s = 0.0
        for r in reqs:
            router.submit(r)
        router.run_to_completion()
        toks = sum(len(r.output) for r in reqs)
        busy = max(router.busy_seconds().values())
        return toks / busy, busy, [list(r.output) for r in reqs]

    tps1, busy1, out1 = serve(1)
    tps2, busy2, out2 = serve(2)
    ratio = tps2 / tps1
    results.append(("serving_router_scaling", busy2 * 1e6,
                    f"{tps2:,.0f} agg tok/s on 2 replicas vs {tps1:,.0f} "
                    f"on 1 ({ratio:.1f}x, busiest-replica wall)"))
    assert out2 == out1, "2-replica routing changed greedy output"
    assert ratio >= 1.8, (tps1, tps2)

    # -------------------------------------- (b) affinity vs round-robin ----
    page, cache_len = 16, 512
    # two 400-token system prompts, seed-searched (deterministically) so
    # the ring maps them to DIFFERENT replicas — the bench measures
    # routing policy, not a hash collision
    ring = HashRing()
    ring.add(0)
    ring.add(1)
    grng = np.random.default_rng(17)
    groups = []
    while len(groups) < 2:
        g = grng.integers(2, cfg.vocab_size, 400).astype(np.int32)
        if ring.lookup(affinity_key(g, page)) == len(groups):
            groups.append(g)

    def hit_rate(policy):
        metrics = MetricsRegistry()

        def make_engine(admission):
            # 27 usable pages: ONE group's 25-page prefix + a working
            # margin, so holding both groups is impossible and the
            # interleaved (round-robin) arrival order must LRU-thrash
            return DecodeEngine(cfg, params, num_slots=2,
                                cache_len=cache_len, metrics=metrics,
                                admission=admission, decode_chunk=4,
                                prefill_buckets="auto", kv_page_size=page,
                                kv_pages=28, prefix_cache=True)

        router = Router(make_engine, replicas=2, policy=policy,
                        metrics=metrics)
        rng = np.random.default_rng(13)
        reqs = []
        for i in range(16):
            tail = rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
            reqs.append(Request(
                rid=i, prompt=np.concatenate([groups[(i // 2) % 2], tail]),
                max_new_tokens=8))
        t0 = time.perf_counter()
        for r in reqs:
            router.submit(r)
        router.run_to_completion()
        dt = time.perf_counter() - t0
        hits = int(metrics.counter("serve_prefix_hits").value())
        misses = int(metrics.counter("serve_prefix_misses").value())
        reused = int(metrics.counter(
            "serve_prefix_reused_tokens").value())
        frac = reused / sum(len(r.prompt) for r in reqs)
        return hits / (hits + misses), frac, dt

    # no cross-policy output assert here: the round-robin run thrashes
    # the pool BY DESIGN, and a starvation requeue re-prefills through a
    # different bucketed program whose f32 reassociation is not bitwise
    # the incremental decode (same per-schedule caveat bench_tp_capacity
    # documents) — the bit-identity acceptance gate lives in part (a)
    # and tests/test_router.py on starvation-free workloads
    aff_rate, aff_frac, aff_dt = hit_rate("affinity")
    rr_rate, rr_frac, _ = hit_rate("rr")
    results.append(("serving_router_affinity", aff_dt * 1e6,
                    f"prefix hit rate {aff_rate:.0%} affinity vs "
                    f"{rr_rate:.0%} round-robin (reused prompt tokens "
                    f"{aff_frac:.0%} vs {rr_frac:.0%}; 2 replicas, two "
                    f"400-token system prompts)"))
    # >= 1.5x round-robin, and good in absolute terms (one cold miss
    # per group per replica is 14/16 = 88%)
    assert aff_rate >= max(1.5 * rr_rate, 0.5), (aff_rate, rr_rate)


def bench_prefill_latency(results: list):
    import jax.numpy as jnp
    from repro.configs import RunConfig
    from repro.models.model import prefill
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    run = RunConfig(remat="none")
    rng = np.random.default_rng(1)
    for plen in (32, 128, 512):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, plen)),
                           jnp.int32)
        import jax
        f = jax.jit(lambda p, t: prefill(p, {"tokens": t}, cfg, run,
                                         cache_len=1024)[0])
        f(params, toks)                 # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            jax.block_until_ready(f(params, toks))
        dt = (time.perf_counter() - t0) / reps
        results.append((f"prefill_latency_p{plen}", dt * 1e6,
                        f"{plen / dt:,.0f} tok/s"))


def run(results: list):
    bench_decode_throughput(results)
    bench_prefill_bucketed(results)
    bench_paged_capacity(results)
    bench_prefix_reuse(results)
    bench_latency_slo(results)
    bench_chunked_prefill_ttft(results)
    bench_speculative_tokps(results)
    bench_tp_capacity(results)
    bench_router_scaling(results)
    bench_prefill_latency(results)
