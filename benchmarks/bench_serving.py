"""Serving benchmarks: batched-decode throughput scaling with slot count
(the continuous-batching claim), and prefill latency vs prompt length."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.serving import DecodeEngine, Request


def bench_decode_throughput(results: list):
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    rng = np.random.default_rng(0)
    out = {}
    for slots in (1, 4):
        eng = DecodeEngine(cfg, params, num_slots=slots, cache_len=128)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 16).astype(
                            np.int32), max_new_tokens=16)
                for i in range(8)]
        for r in reqs:
            eng.submit(r)
        eng.step()                      # absorb compile time
        t0 = time.perf_counter()
        eng.run_to_completion()
        dt = time.perf_counter() - t0
        toks = int(eng.metrics.counter("serve_tokens_generated").value())
        out[slots] = toks / dt
        results.append((f"decode_throughput_slots{slots}", dt * 1e6,
                        f"{toks / dt:,.0f} tok/s"))
    # batching must help
    assert out[4] > out[1] * 1.3, out


def bench_prefill_latency(results: list):
    import jax.numpy as jnp
    from repro.configs import RunConfig
    from repro.models.model import prefill
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    run = RunConfig(remat="none")
    rng = np.random.default_rng(1)
    for plen in (32, 128, 512):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, plen)),
                           jnp.int32)
        import jax
        f = jax.jit(lambda p, t: prefill(p, {"tokens": t}, cfg, run,
                                         cache_len=1024)[0])
        f(params, toks)                 # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            jax.block_until_ready(f(params, toks))
        dt = (time.perf_counter() - t0) / reps
        results.append((f"prefill_latency_p{plen}", dt * 1e6,
                        f"{plen / dt:,.0f} tok/s"))


def run(results: list):
    bench_decode_throughput(results)
    bench_prefill_latency(results)
